"""Preemptive scheduling (repro.serve): token-exactness through
recompute and offload preemption storms, victim selection, the
offload-vs-recompute cost model, allocator integrity under preemption
(including a hypothesis property test), and the serve-side wall-clock
measure path."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.memory_model import PreemptionCost
from repro.models import lm
from repro.serve import (Engine, EngineOptions, PagedKVCache, RequestState,
                         dense_greedy_reference as ref_decode)

PROMPT_LENS = (13, 29, 7, 21, 5)
MAX_NEW = (6, 4, 8, 5, 7)


def _cfg():
    return dataclasses.replace(get_config("llama3-8b").reduced(),
                               compute_dtype="float32")


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.Generator(np.random.Philox(key=7))
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in PROMPT_LENS]
    refs = [ref_decode(params, cfg, p, m)
            for p, m in zip(prompts, MAX_NEW)]
    return cfg, params, prompts, refs


def _engine(cfg, params, **over):
    # pool of 11 real pages vs ~28 pages of total demand: on-demand
    # admission packs 3 slots in and page exhaustion preempts repeatedly
    kw = dict(page_size=4, max_slots=3, max_seq_len=64, chunk=16,
              min_bucket=8, num_pages=12)
    kw.update(over)
    return Engine(cfg, params, options=EngineOptions(**kw))


def _run_all(eng, prompts, refs):
    for p, m in zip(prompts, MAX_NEW):
        eng.submit(p, max_new_tokens=m, arrival_s=0.0)
    eng.run_until_idle()
    outs = [r.output for r in sorted(eng.done, key=lambda r: r.rid)]
    assert outs == refs


def _assert_drained(kv: PagedKVCache):
    """Free-list integrity: every page back, no aliasing, no leftovers."""
    assert sorted(kv._free) == list(range(1, kv.num_pages))
    assert len(set(kv._free)) == len(kv._free)
    assert not any(kv._slot_pages)
    assert (kv.page_table == 0).all() and (kv.lens == 0).all()
    assert kv.offloaded_count == 0 and kv.host_bytes == 0


# ---------------------------------------------------------------------------
# Token-exactness through preemption (the tentpole invariant)
# ---------------------------------------------------------------------------

def test_preempt_recompute_token_exact(setup):
    cfg, params, prompts, refs = setup
    eng = _engine(cfg, params, preempt="recompute")
    _run_all(eng, prompts, refs)
    assert eng.preempts["recompute"] > 0          # the storm happened
    assert eng.preempts["offload"] == 0
    assert eng.stats()["resumes"] == sum(r.preempt_count
                                         for r in eng.done)
    assert any(r.preempt_count > 0 for r in eng.done)
    _assert_drained(eng.kv)


def test_preempt_offload_token_exact(setup):
    cfg, params, prompts, refs = setup
    eng = _engine(cfg, params, preempt="offload")
    _run_all(eng, prompts, refs)
    assert eng.preempts["offload"] > 0
    s = eng.stats()
    assert s["swap_out_bytes"] > 0
    assert s["swap_in_bytes"] == s["swap_out_bytes"]  # all restored
    _assert_drained(eng.kv)


def test_preempt_auto_respects_host_gate(setup):
    """auto on this CPU backend (no pinned_host) must degrade to
    recompute-only — the same capacity mask the train-side strategy
    selector applies."""
    cfg, params, prompts, refs = setup
    eng = _engine(cfg, params, preempt="auto")
    _run_all(eng, prompts, refs)
    assert eng.preempts["recompute"] > 0
    assert eng.preempts["offload"] == 0


def test_preempt_auto_cost_model_offload(setup):
    """With offload force-allowed and recompute made expensive, the
    per-victim cost model must choose offload."""
    cfg, params, prompts, refs = setup
    eng = _engine(cfg, params, preempt="auto", allow_offload=True)
    eng._flops_per_token = 1e15        # re-prefill "costs" ~hours
    choices, orig = [], eng._preempt_mode

    def spy(req):
        mode = orig(req)
        choices.append((int(eng.kv.lens[req.slot]), mode))
        return mode

    eng._preempt_mode = spy
    _run_all(eng, prompts, refs)
    assert eng.preempts["offload"] > 0
    # whenever the victim had cached KV to save, offload won; a victim
    # with an empty cache has nothing to swap and recomputes for free
    assert all(mode == ("offload" if cached else "recompute")
               for cached, mode in choices)
    _assert_drained(eng.kv)


def test_victim_is_lowest_priority_then_youngest(setup):
    # 12 real pages: both prompts admit (4 + 8 pages, pool full) and
    # the first decode growth forces a preemption
    cfg, params, prompts, refs = setup
    eng = _engine(cfg, params, preempt="recompute", max_slots=2,
                  num_pages=13)
    hi = eng.submit(prompts[0], max_new_tokens=MAX_NEW[0], priority=1)
    lo = eng.submit(prompts[1], max_new_tokens=MAX_NEW[1], priority=0)
    eng.run_until_idle()
    assert hi.preempt_count == 0                  # protected
    assert lo.preempt_count > 0                   # sacrificed
    assert [hi.output, lo.output] == [refs[0], refs[1]]


def test_preempted_state_round_trip(setup):
    """A victim visibly passes through PREEMPTED and back."""
    cfg, params, prompts, refs = setup
    eng = _engine(cfg, params, preempt="recompute", max_slots=2,
                  num_pages=13)
    r0 = eng.submit(prompts[0], max_new_tokens=MAX_NEW[0])
    r1 = eng.submit(prompts[1], max_new_tokens=MAX_NEW[1])
    seen = set()
    while eng.has_work:
        eng.step()
        seen.update(r.state for r in (r0, r1))
    assert RequestState.PREEMPTED in seen
    assert r0.state == r1.state == RequestState.DONE


def test_overload_preemption_admits_earlier(setup):
    """The overload acceptance property, measured deterministically in
    engine steps (no wall clock): under a burst of decode-heavy requests
    over a constrained pool, preemptive prompt-only admission emits
    first tokens strictly earlier than the admission-blocking baseline
    (whose full prompt+max_new reservation fits only one request at a
    time), while staying token-exact."""
    cfg, params, _, _ = setup
    rng = np.random.Generator(np.random.Philox(key=23))
    # 3-page prompts with 6-page total budgets over 8 real pages:
    # blocking serializes completely, preemptive packs 2 prompts + growth
    prompts = [rng.integers(0, cfg.vocab_size, size=12, dtype=np.int32)
               for _ in range(4)]
    refs = [ref_decode(params, cfg, p, 12) for p in prompts]

    def first_token_steps(policy):
        eng = _engine(cfg, params, num_pages=9, preempt=policy)
        firsts = {}
        for p in prompts:
            eng.submit(p, max_new_tokens=12, arrival_s=0.0,
                       on_token=lambda t, r:
                       firsts.setdefault(r.rid, eng.step_count))
        eng.run_until_idle()
        outs = [r.output for r in sorted(eng.done, key=lambda r: r.rid)]
        assert outs == refs
        return sorted(firsts.values())

    blocking = first_token_steps("never")
    preemptive = first_token_steps("recompute")
    # strictly earlier at the median and for the worst request
    assert preemptive[len(preemptive) // 2] < blocking[len(blocking) // 2]
    assert preemptive[-1] < blocking[-1]


# ---------------------------------------------------------------------------
# Cost model (core.memory_model.PreemptionCost)
# ---------------------------------------------------------------------------

def test_preemption_cost_crossover():
    base = dict(tokens_cached=64, bytes_held=1 << 20, flops=200e12,
                host_bw=32e9)
    # tiny model: re-prefill is nearly free -> recompute
    cheap = PreemptionCost(flops_per_token=2e6, **base)
    assert cheap.choice == "recompute"
    # huge model: re-prefill dwarfs a 1 MiB swap -> offload
    heavy = PreemptionCost(flops_per_token=2e12, **base)
    assert heavy.choice == "offload"
    assert heavy.recompute_s > heavy.offload_s
    # both costs scale linearly in cached state
    twice = PreemptionCost(flops_per_token=2e12,
                           **dict(base, tokens_cached=128,
                                  bytes_held=2 << 20))
    assert twice.offload_s == pytest.approx(2 * heavy.offload_s)
    assert twice.recompute_s == pytest.approx(2 * heavy.recompute_s)


# ---------------------------------------------------------------------------
# Allocator integrity (property test)
# ---------------------------------------------------------------------------

def test_allocator_property_preemption_storm(setup):
    """Random alloc/grow/offload/restore/free op sequences keep the page
    allocator consistent: pages are never aliased, never leaked, and the
    sink page is never handed out."""
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    cfg, params, _, _ = setup
    from hypothesis import HealthCheck, given, settings

    NP, PS, SLOTS, MPS = 8, 2, 3, 4

    def check(kv, held, offl):
        free = set(kv._free)
        bound = [p for pages in kv._slot_pages for p in pages]
        assert 0 not in free and 0 not in bound
        assert len(bound) == len(set(bound))
        assert free | set(bound) == set(range(1, NP))
        for slot in range(SLOTS):
            n = len(kv._slot_pages[slot])
            assert list(kv.page_table[slot, :n]) == kv._slot_pages[slot]
            assert (kv.page_table[slot, n:] == 0).all()
        assert kv.offloaded_count == len(offl)

    @settings(max_examples=30, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=st.lists(st.tuples(st.integers(0, 4),
                                  st.integers(0, SLOTS - 1),
                                  st.integers(1, MPS * PS)),
                        min_size=1, max_size=60))
    def run(ops):
        kv = PagedKVCache(cfg, num_pages=NP, page_size=PS,
                          max_slots=SLOTS, max_pages_per_seq=MPS,
                          dtype=np.float32)
        held, offl, rid = {}, {}, 0
        for op, slot, tokens in ops:
            if op == 0 and slot not in held and kv.can_admit(tokens):
                kv.alloc_slot(slot, tokens)
                held[slot] = tokens
            elif op == 1 and slot in held:
                if len(kv._slot_pages[slot]) < MPS:
                    kv.grow_slot(slot)          # may be a no-op when dry
            elif op == 2 and slot in held and kv.slot_page_count(slot):
                cached = kv.slot_capacity(slot)     # page-aligned length
                kv.lens[slot] = cached
                kv.offload_slot(slot, rid)
                offl[rid] = (cached, kv.offloaded_pages(rid))
                del held[slot]
                rid += 1
            elif op == 3 and offl:
                r, (cached, pages) = next(iter(offl.items()))
                free_slots = [s for s in range(SLOTS) if s not in held]
                if free_slots and kv.can_restore(r):
                    s = free_slots[0]
                    kv.restore_slot(r, s, cached)
                    held[s] = cached
                    del offl[r]
            elif op == 4 and slot in held:
                kv.free_slot(slot)
                del held[slot]
            check(kv, held, offl)

    run()


def test_offload_trims_pages_grown_ahead_of_lens(setup):
    """PR 3 gotcha regression: a slot can hold MORE pages than
    ``pages_for(lens)`` — decode growth (or a prefill ``_ensure``) ran
    ahead of a chunk that was then preempted away. Offload must trim the
    unwritten tail back to the free list (not swap garbage), and the
    restore must land on exactly ``pages_for(lens)`` pages."""
    cfg, _, _, _ = setup
    kv = PagedKVCache(cfg, num_pages=8, page_size=2, max_slots=2,
                      max_pages_per_seq=4, dtype=np.float32)
    kv.alloc_slot(0, 3)                  # 2 pages for 3 tokens
    kv.grow_slot(0)
    kv.grow_slot(0)                      # grown ahead: 4 pages held
    kv.lens[0] = 3                       # ...but only 3 tokens cached
    assert kv.slot_page_count(0) == 4 > kv.pages_for(int(kv.lens[0]))
    nbytes = kv.offload_slot(0, rid=1)
    assert kv.offloaded_pages(1) == 2    # tail trimmed, not swapped
    assert nbytes == 2 * kv.page_bytes
    assert kv.free_pages == kv.num_pages - 1   # every page came back
    kv.restore_slot(1, 0, 3)             # lens-aligned restore succeeds
    assert kv.slot_page_count(0) == 2
    kv.free_slot(0)
    _assert_drained(kv)


def test_offload_restore_preserves_page_contents(setup):
    """Swap-out/swap-in round-trips exact page contents even when the
    restore lands on different physical pages."""
    cfg, _, _, _ = setup
    kv = PagedKVCache(cfg, num_pages=8, page_size=2, max_slots=2,
                      max_pages_per_seq=3, dtype=np.float32)
    kv.alloc_slot(0, 6)
    pages0 = list(kv._slot_pages[0])
    # write a recognizable pattern into slot 0's pages
    import jax.numpy as jnp
    from repro.models import kv_cache as KV
    pat = KV.extract_pages(kv.pools, pages0)
    pat = jax.tree_util.tree_map(
        lambda h: np.arange(h.size, dtype=h.dtype).reshape(h.shape), pat)
    kv.pools = KV.insert_pages(kv.pools, pages0, pat)
    kv.lens[0] = 6
    kv.offload_slot(0, rid=42)
    kv.alloc_slot(0, 6)                 # steal the just-freed pages
    kv.restore_slot(42, 1, 6)           # forced onto other physical pages
    assert kv._slot_pages[1] != pages0
    got = KV.extract_pages(kv.pools, kv._slot_pages[1])
    jax.tree_util.tree_map(np.testing.assert_array_equal, got, pat)


# ---------------------------------------------------------------------------
# Serve-side wall-clock resolution
# ---------------------------------------------------------------------------

def _moe_cfg():
    cfg = get_config("moe-gpt3-s").reduced()
    moe = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    return dataclasses.replace(cfg, compute_dtype="float32", moe=moe)


def test_injected_measure_fn_drives_resolution():
    cfg = _moe_cfg()
    calls = []

    def fake(b, n, strategy):
        calls.append((b, n, strategy.value))
        return 1.0 / n                   # prefer the largest feasible n

    eng = Engine(cfg, options=EngineOptions(
        page_size=4, max_slots=2, max_seq_len=32, chunk=8, min_bucket=8,
        measure_fn=fake))
    eng.submit(np.arange(6, dtype=np.int32) % cfg.vocab_size,
               max_new_tokens=2)
    eng.run_until_idle()
    assert calls and all(b == 8 for b, _, _ in calls)
    (n, _), = set(eng.adaptive.resolutions.values())
    assert n == max(n_ for _, n_, _ in calls)


def test_wallclock_measure_times_real_candidates():
    """measure="wallclock" forced on CPU: candidates are compiled through
    the prefill LRU and timed; serving stays token-exact afterwards."""
    cfg = _moe_cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    opts = EngineOptions(page_size=4, max_slots=2, max_seq_len=32,
                         chunk=8, min_bucket=8, measure="wallclock",
                         measure_steps=1)
    eng = Engine(cfg, params, options=opts)
    prompt = np.arange(6, dtype=np.int32) % cfg.vocab_size
    ref = ref_decode(params, cfg, prompt, 3)
    r = eng.submit(prompt, max_new_tokens=3)
    eng.run_until_idle()
    assert r.output == ref
    assert eng.adaptive.resolutions          # bucket resolved by timing
    assert eng.prefill_rejits >= 2           # >1 candidate was compiled
