"""Telemetry (repro.obs): the shared nearest-rank quantile (property-
tested against a definitional reference), the metrics registry and its
Prometheus exposition, the Chrome-trace schema over a real preemption
storm, the live /metrics exporter agreeing with ``Engine.stats()``, and
the zero-cost contract of disabled telemetry (identical jit traces and
identical tokens with the tracer on or off)."""
import dataclasses
import json
import math
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.obs import (MetricsServer, NullTracer, Recorder, Registry,
                       Tracer, quantile)
from repro.serve import Engine, EngineOptions

PROMPT_LENS = (13, 29, 7, 21, 5)
MAX_NEW = (6, 4, 8, 5, 7)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                              compute_dtype="float32")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.Generator(np.random.Philox(key=7))
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in PROMPT_LENS]
    return cfg, params, prompts


def _run(cfg, params, prompts, *, obs=None, **over):
    # same constrained pool as tests/test_preemption.py: ~28 pages of
    # demand over 11 usable pages, so recompute preemptions fire
    kw = dict(page_size=4, max_slots=3, max_seq_len=64, chunk=16,
              min_bucket=8, num_pages=12, preempt="recompute", obs=obs)
    kw.update(over)
    eng = Engine(cfg, params, options=EngineOptions(**kw))
    for p, m in zip(prompts, MAX_NEW):
        eng.submit(p, max_new_tokens=m, arrival_s=0.0)
    eng.run_until_idle()
    return eng


# ---------------------------------------------------------------------------
# quantile: the one shared nearest-rank implementation
# ---------------------------------------------------------------------------

def _reference_quantile(xs, p):
    """Definitional nearest-rank: the smallest sample whose empirical
    CDF reaches p/100 (p0 = min, p100 = max)."""
    s = sorted(xs)
    n = len(s)
    for i, v in enumerate(s):
        if (i + 1) / n >= p / 100.0 - 1e-12:
            return v
    return s[-1]


def test_quantile_pinned_examples():
    # the Engine.stats() bug this replaced: int(p/100*n) indexed one
    # rank too high, so p50 of a 2-element list returned the max
    assert quantile([1.0, 2.0], 50) == 1.0
    assert quantile([1.0, 2.0], 100) == 2.0
    assert quantile([2.0, 1.0], 0) == 1.0
    assert quantile([5.0], 99) == 5.0
    assert quantile([], 50) == 0.0
    assert quantile([3, 1, 4, 1, 5], 50) == 3.0      # unsorted input ok


try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @given(st.lists(st.floats(allow_nan=False, allow_infinity=False,
                              width=32),
                    min_size=1, max_size=200),
           st.integers(min_value=0, max_value=100))
    @settings(max_examples=200, deadline=None)
    def test_quantile_matches_reference(xs, p):
        got = quantile(xs, p)
        assert got == _reference_quantile(xs, p)
        assert got in xs                  # nearest-rank never interpolates
        # ceil(p/100*n) is the textbook closed form of the same rank
        rank = max(1, math.ceil(p / 100.0 * len(xs)))
        assert got == sorted(xs)[rank - 1]


# ---------------------------------------------------------------------------
# registry + Prometheus exposition
# ---------------------------------------------------------------------------

def test_registry_render_and_snapshot():
    reg = Registry()
    c = reg.counter("repro_test_total", "things done")
    c.inc()
    c.inc(2)
    g = reg.gauge("repro_test_gauge", "a level")
    g.set(3.5)
    g.inc()
    g.dec(0.5)
    h = reg.histogram("repro_test_seconds", "a timing")
    for v in (1, 2, 3, 4):
        h.observe(v)
    text = reg.render()
    assert "# HELP repro_test_total things done" in text
    assert "# TYPE repro_test_total counter" in text
    assert "repro_test_total 3" in text.splitlines()
    assert "repro_test_gauge 4" in text.splitlines()
    assert "# TYPE repro_test_seconds summary" in text
    assert 'repro_test_seconds{quantile="0.5"} 2' in text
    assert 'repro_test_seconds{quantile="0.99"} 4' in text
    assert "repro_test_seconds_sum 10" in text
    assert "repro_test_seconds_count 4" in text

    snap = reg.snapshot()
    json.dumps(snap)                       # JSON-serializable end to end
    assert snap["repro_test_total"] == 3
    assert snap["repro_test_gauge"] == 4
    assert snap["repro_test_seconds"] == {
        "count": 4, "sum": 10, "p50": 2, "p90": 4, "p99": 4}


def test_registry_labels_and_idempotent_registration():
    reg = Registry()
    fam = reg.counter("repro_modes_total", "by mode", labels=("mode",))
    fam.labels(mode="a").inc()
    fam.labels(mode="b").inc(2)
    # idempotent: re-declaring returns the same family object
    assert reg.counter("repro_modes_total", "by mode",
                       labels=("mode",)) is fam
    text = reg.render()
    assert 'repro_modes_total{mode="a"} 1' in text
    assert 'repro_modes_total{mode="b"} 2' in text
    assert reg.snapshot()["repro_modes_total"] == {
        'mode="a"': 1, 'mode="b"': 2}
    # kind and label-set mismatches are registration bugs, not merges
    with pytest.raises(AssertionError):
        reg.gauge("repro_modes_total", "by mode", labels=("mode",))
    with pytest.raises(AssertionError):
        reg.counter("repro_modes_total", "by mode", labels=("kind",))
    with pytest.raises(AssertionError):
        fam.labels(kind="a")


def test_histogram_window_bounds_quantiles_not_totals():
    reg = Registry()
    h = reg.histogram("repro_win_seconds", "w", window=4)
    for v in (100, 100, 1, 2, 3, 4):
        h.observe(v)
    # quantiles see only the last 4 observations...
    assert h.quantile(99) == 4
    # ...while count/sum stay lifetime totals
    assert h.count == 6 and h.sum == 210


# ---------------------------------------------------------------------------
# tracer: schema/golden over a preemption storm
# ---------------------------------------------------------------------------

def test_null_tracer_is_inert():
    t = NullTracer()
    assert not t.enabled
    with t.span("x", args={"k": 1}) as sp:
        sp["late"] = 2
    t.instant("i")
    t.begin("b")
    t.end("b")
    t.thread_name(1, 1, "steps")
    assert t.export()["traceEvents"] == []


def test_trace_schema_over_preemption_storm(setup, tmp_path):
    cfg, params, prompts = setup
    obs = Recorder(tracer=Tracer())
    eng = _run(cfg, params, prompts, obs=obs)
    assert eng.preempts["recompute"] > 0            # the storm happened

    doc = obs.tracer.export()
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    real = [e for e in evs if e["ph"] != "M"]
    assert real and set(e["ph"] for e in real) <= {"B", "E", "X", "i"}

    # stable pid/tid naming
    proc = {e["pid"]: e["args"]["name"] for e in meta
            if e["name"] == "process_name"}
    assert proc[1] == "engine" and proc[2] == "requests" \
        and proc[3] == "resolver"
    threads = {(e["pid"], e["tid"]): e["args"]["name"] for e in meta
               if e["name"] == "thread_name"}
    assert threads[(1, 1)] == "steps"
    for r in eng.done:
        assert threads[(2, r.rid)] == f"req {r.rid}"

    # timestamps sorted; X complete events carry a duration
    ts = [e["ts"] for e in real]
    assert ts == sorted(ts)
    for e in real:
        if e["ph"] == "X":
            assert e["dur"] >= 0
        if e["ph"] == "i":
            assert e["s"] == "t"

    # B/E balanced and properly nested per (pid, tid)
    stacks = {}
    for e in real:
        key = (e["pid"], e["tid"])
        if e["ph"] == "B":
            stacks.setdefault(key, []).append(e["name"])
        elif e["ph"] == "E":
            assert stacks.get(key), f"E without matching B: {e}"
            assert stacks[key].pop() == e["name"]
    assert all(not s for s in stacks.values())

    # lifecycle instants: one ADMIT + one RETIRE per request; every
    # PREEMPT has its RESUME; counts match the engine's own counters
    by_name = {}
    for e in real:
        by_name.setdefault(e["name"], []).append(e)
    n = len(prompts)
    assert len(by_name["ADMIT"]) == n
    assert len(by_name["RETIRE"]) == n
    assert len(by_name["PREEMPT"]) == eng.preempts["recompute"]
    assert len(by_name["RESUME"]) == len(by_name["PREEMPT"])
    assert all(e["args"]["mode"] == "recompute"
               for e in by_name["PREEMPT"])
    assert by_name["PREFILL"] and by_name["engine.step"]

    # the written file is valid JSON and identical to export()
    path = tmp_path / "trace.json"
    obs.tracer.write(str(path))
    assert json.loads(path.read_text()) == doc


# ---------------------------------------------------------------------------
# live exporter: /metrics agrees with Engine.stats()
# ---------------------------------------------------------------------------

def test_metrics_server_agrees_with_stats(setup):
    cfg, params, prompts = setup
    obs = Recorder()
    eng = _run(cfg, params, prompts, obs=obs)
    server = MetricsServer(obs.registry, port=0,
                           refresh=eng._refresh_gauges).start()
    try:
        assert server.port > 0
        text = urllib.request.urlopen(server.url + "/metrics",
                                      timeout=10).read().decode()
        health = urllib.request.urlopen(server.url + "/healthz",
                                        timeout=10).read().decode()
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(server.url + "/nope", timeout=10)
    finally:
        server.stop()
    assert health == "ok\n"
    assert "# TYPE repro_step_seconds summary" in text

    def metric(name):
        for line in text.splitlines():
            if line.startswith(name + " "):
                return float(line.rsplit(" ", 1)[1])
        raise AssertionError(f"{name} not in exposition")

    s = eng.stats()
    assert metric("repro_requests_done_total") == len(eng.done) == \
        len(prompts)
    assert metric("repro_tokens_generated_total") == \
        sum(len(r.output) for r in eng.done)
    assert metric('repro_preempts_total{mode="recompute"}') == \
        eng.preempts["recompute"]
    # scrape-time refresh: the gauges /metrics serves are the ones
    # stats() reports
    assert metric("repro_waiting_requests") == s["queue_waiting"] == 0
    assert metric("repro_resuming_requests") == s["queue_resuming"] == 0
    assert metric("repro_running_slots") == s["running_slots"] == 0
    assert metric('repro_kv_free_pages{shard="0"}') == \
        s["free_units_by_shard"]["0"] == eng.kv.num_pages - 1


def test_metrics_server_survives_midwrite_hangup():
    """A scraper that hangs up mid-response (curl timeout, ^C) must not
    traceback the handler thread — the write path is guarded."""
    reg = Registry()
    reg.counter("repro_x_total", "x").inc()
    server = MetricsServer(reg, port=0)
    try:
        handler_cls = server._httpd.RequestHandlerClass

        class Gone:
            def write(self, *_):
                raise BrokenPipeError

            def flush(self):
                pass

        h = handler_cls.__new__(handler_cls)
        h.path = "/metrics"
        h.request_version = "HTTP/1.1"
        h.requestline = "GET /metrics HTTP/1.1"
        h.client_address = ("127.0.0.1", 0)
        h.wfile = Gone()
        h.do_GET()                         # must not raise
        h.path = "/healthz"
        h.do_GET()
    finally:
        server.stop()


def test_metrics_server_stop_is_idempotent():
    """CLI finally-blocks, tests and signal handlers may all call
    stop(); the second call must be a no-op, not a hang or error."""
    server = MetricsServer(Registry(), port=0).start()
    assert urllib.request.urlopen(server.url + "/healthz",
                                  timeout=10).read() == b"ok\n"
    server.stop()
    server.stop()                          # second shutdown: no-op
    with pytest.raises(OSError):
        urllib.request.urlopen(server.url + "/healthz", timeout=2)


def test_stats_quantiles_use_shared_util(setup):
    cfg, params, prompts = setup
    eng = _run(cfg, params, prompts)
    s = eng.stats()
    lats = sorted(r.latency_s for r in eng.done)
    assert s["p50_latency_s"] == quantile(lats, 50)
    # 5 samples: nearest-rank p50 is the 3rd, not the 4th (the old
    # int(p/100*n) bias)
    assert s["p50_latency_s"] == lats[2]


# ---------------------------------------------------------------------------
# zero-cost when disabled: tokens and jit traces identical on vs off
# ---------------------------------------------------------------------------

def test_telemetry_on_off_identical_traces_and_tokens(setup):
    cfg, params, prompts = setup
    off = _run(cfg, params, prompts)          # default no-op recorder
    on = _run(cfg, params, prompts, obs=Recorder(tracer=Tracer()))
    assert on.decode_traces == off.decode_traces
    assert on.prefill_traces == off.prefill_traces
    assert [r.output for r in sorted(on.done, key=lambda r: r.rid)] == \
           [r.output for r in sorted(off.done, key=lambda r: r.rid)]
    # default recorder still counts jit traces in its registry
    snap = off.obs.registry.snapshot()
    assert snap["repro_jit_traces_total"]['body="decode"'] == \
        off.decode_traces
