"""Optimizers, data pipeline, checkpointing, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import Checkpointer
from repro.configs import get_config
from repro.data import SyntheticTokens
from repro.optim import (AdafactorConfig, AdamWConfig, adafactor, adamw,
                         get_optimizer, lr_schedule)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quad_losses(opt_mod, ocfg, steps=60):
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    state = opt_mod.init(params, ocfg)
    losses = []
    for _ in range(steps):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt_mod.update(g, state, params, ocfg)
        losses.append(float(jnp.sum(params["w"] ** 2)))
    return losses


def test_adamw_minimizes_quadratic():
    losses = _quad_losses(adamw, AdamWConfig(lr=0.1, weight_decay=0.0))
    assert losses[-1] < 0.05 * losses[0]


def test_adafactor_minimizes_quadratic():
    losses = _quad_losses(adafactor, AdafactorConfig(lr=0.3))
    assert losses[-1] < 0.2 * losses[0]


def test_adamw8bit_tracks_adamw():
    p0 = {"w": jnp.asarray(np.random.default_rng(0)
                           .standard_normal((16, 32)), jnp.float32)}
    g = {"w": jnp.full((16, 32), 0.1, jnp.float32)}
    m1, c1 = get_optimizer("adamw", 1e-2)
    m2, c2 = get_optimizer("adamw8bit", 1e-2)
    pa, sa = dict(p0), m1.init(p0, c1)
    pb, sb = dict(p0), m2.init(p0, c2)
    for _ in range(5):
        pa, sa = m1.update(g, sa, pa, c1)
        pb, sb = m2.update(g, sb, pb, c2)
    err = float(jnp.abs(pa["w"] - pb["w"]).max())
    assert err < 5e-3


def test_adafactor_state_is_factored():
    p = {"w": jnp.zeros((64, 128))}
    st = adafactor.init(p, AdafactorConfig())
    assert st["factored"]["w"]["vr"].shape == (64,)
    assert st["factored"]["w"]["vc"].shape == (128,)


def test_lr_schedule_warmup_and_decay():
    assert float(lr_schedule(0, warmup=10, total=100)) == 0.0
    assert float(lr_schedule(10, warmup=10, total=100)) == pytest.approx(
        1.0, abs=1e-3)
    assert float(lr_schedule(100, warmup=10, total=100)) == pytest.approx(
        0.1, abs=1e-3)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_seekable():
    cfg = get_config("llama3-8b").reduced()
    ds = SyntheticTokens(cfg, batch=4, seq=16, seed=7)
    a = ds.batch_at(5)
    b = ds.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    assert a["tokens"].shape == a["labels"].shape


def test_data_host_slices_are_disjoint():
    cfg = get_config("llama3-8b").reduced()
    h0 = SyntheticTokens(cfg, batch=8, seq=16, seed=1, num_hosts=2,
                         host_index=0).batch_at(3)
    h1 = SyntheticTokens(cfg, batch=8, seq=16, seed=1, num_hosts=2,
                         host_index=1).batch_at(3)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_data_modality_stubs():
    ds = SyntheticTokens(get_config("whisper-medium").reduced(), 2, 16)
    b = ds.batch_at(0)
    assert "frames" in b
    ds = SyntheticTokens(get_config("qwen2-vl-2b").reduced(), 2, 16)
    b = ds.batch_at(0)
    assert "embeds" in b and "positions3" in b
    assert (b["labels"][:, :b["embeds"].shape[1]] == -1).all()


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _state(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v), "b": jnp.zeros((4,))},
            "step": jnp.asarray(int(v), jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    ck.save(10, _state(1.0), block=True)
    out = ck.restore_latest(like=_state())
    assert out["step"] == 10
    np.testing.assert_array_equal(out["state"]["params"]["w"],
                                  np.full((4, 4), 1.0))


def test_checkpoint_keep_k_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(float(s)), block=True)
    assert ck.list_steps() == [3, 4]
    assert ck.restore_latest(like=_state())["step"] == 4


def test_checkpoint_atomic_ignores_tmp(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(1, _state(1.0), block=True)
    os.makedirs(os.path.join(str(tmp_path), "step_0000000002.tmp"))
    assert ck.list_steps() == [1]          # half-written ckpt invisible


def test_checkpoint_async_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(7, _state(2.0))                # async
    ck.wait()
    assert ck.list_steps() == [7]
