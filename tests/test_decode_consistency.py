"""Prefill+decode must reproduce teacher-forced logits exactly — covers
every cache family (full KV, ring-buffer SWA, MLA-absorbed, mamba SSM
state, mLSTM/sLSTM recurrent state)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.api import get_model

FAMILIES = ["llama3-8b", "h2o-danube-1.8b", "gemma3-12b",
            "deepseek-v2-lite-16b", "jamba-1.5-large-398b", "xlstm-1.3b"]


@pytest.mark.parametrize("name", FAMILIES)
def test_prefill_decode_matches_forward(name):
    cfg = dataclasses.replace(get_config(name).reduced(),
                              compute_dtype="float32")
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(cfg, key)
    b, s = 2, 16
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)

    full, _, _ = model.forward(params, {"tokens": toks}, cfg, mode="train")
    _, cache = model.prefill(params, {"tokens": toks[:, :8]}, cfg,
                             max_len=32, dtype=jnp.float32)
    errs = []
    for t in range(8, s):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1], cfg)
        errs.append(float(jnp.abs(lg - full[:, t]).max()))
    assert max(errs) < 1e-4, (name, errs)
