"""Cross-request prefix cache: refcount/CoW conservation property tier.

The prefix cache aliases pages across requests (and the trie), which is
exactly the kind of code that corrupts tokens silently. This tier pins
it three ways:

* **Refcount conservation property**: a seeded interpreter drives random
  admit / prefill / decode / publish / retire / preempt(recompute and
  offload) / restore interleavings against ``PagedKVCache.check_integrity``
  — every pool page must at all times be free (on its shard's free list
  exactly once), a reserved sink, or referenced with a refcount equal to
  its referent count (binding slots + trie), with trie entries
  shard-local and consistent. 500+ deterministic examples (hypothesis is
  optional in CI; when present it fuzzes the same interpreter).
* **Copy-on-write semantics** at the allocator level: hits bind without
  recompute, a mid-page hit boundary copy-on-writes bit-exactly, the
  steal path privatises without a copy when the pool is dry, LRU
  eviction only ever drops trie-only pages, and preemption (both modes)
  never trims or drops a page another slot still references.
* **Token exactness** at the engine level: the same trace with
  ``prefix_cache=on`` vs ``off`` must be bit-identical — plain paged,
  MLA-latent, composite (jamba), and a forced preemption storm where
  victims share pages with survivors (the CoW-vs-preemption
  interaction).

Plus the pool-level accounting regression: two full-hit requests must
report ~1x the pages of one (a shared page counts once, not once per
referencing slot).
"""
import dataclasses
import random

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import kv_cache, lm
from repro.serve import Engine, EngineOptions, PagedKVCache

PS = 2          # page size used by the allocator-level tests


def _cfg(name="llama3-8b"):
    return dataclasses.replace(get_config(name).reduced(),
                               compute_dtype="float32")


def _kv(**over):
    kw = dict(num_pages=20, page_size=PS, max_slots=4,
              max_pages_per_seq=8, dtype=np.float32, shards=2,
              prefix_cache=True)
    kw.update(over)
    return PagedKVCache(_cfg(), **kw)


def _prompt(rnd, bases):
    """A prompt sharing one of a few common prefixes (so hits, partial
    hits and divergence all occur) plus a random tail."""
    base = bases[rnd.randrange(len(bases))]
    keep = rnd.randrange(len(base) + 1)
    tail = [rnd.randrange(100, 200) for _ in range(rnd.randrange(1, 6))]
    return list(base[:keep]) + tail


# ---------------------------------------------------------------------------
# refcount-conservation interpreter
# ---------------------------------------------------------------------------

def _free_victim(kv, live, slot, parked, rid_of, next_rid, mode):
    """Preempt one live slot other than ``slot`` (engine analogue: a dry
    shard frees a victim). Returns the victim or None."""
    victims = [s for s in sorted(live) if s != slot]
    if not victims:
        return None
    v = victims[0]
    if mode == "offload" and int(kv.lens[v]) > 0 \
            and rid_of[v] not in parked:
        kv.offload_slot(v, rid_of[v])
        parked[rid_of[v]] = (live[v], kv.shard_of_slot(v))
    else:
        kv.free_slot(v)
    del live[v]
    return v


def _interleave(kv: PagedKVCache, ops, seed: int) -> None:
    """Drive the allocator through one op schedule, mirroring the
    engine's use of the protocol (admit -> chunked prefill with
    ensure_private -> decode growth -> publish -> retire / preempt /
    offload / restore), auditing conservation after every op."""
    rnd = random.Random(seed)
    bases = [tuple(rnd.randrange(1, 50) for _ in range(n))
             for n in (6, 10, 14)]
    live = {}            # slot -> written token list (length == lens)
    pending = {}         # slot -> full prompt (prefill not finished)
    parked = {}          # rid -> (written tokens, shard)
    rid_of = {}          # slot -> rid of current occupant
    next_rid = [0]

    def ensure(slot, tokens):
        """Engine._ensure analogue: grow, then privatise, preempting
        victims while the shard is dry. False = gave up (self-victim)."""
        while kv.slot_capacity(slot) < tokens:
            if len(kv._slot_pages[slot]) >= kv.max_pages_per_seq:
                return False
            if kv.grow_slot(slot):
                continue
            if _free_victim(kv, live, slot, parked, rid_of,
                            next_rid, "recompute") is None:
                return False
        while not kv.ensure_private(slot, tokens):
            if _free_victim(kv, live, slot, parked, rid_of,
                            next_rid, "recompute") is None:
                return False
        return True

    for op, pick in ops:
        if op == 0:                                   # admit
            free = [s for s in range(kv.max_slots) if s not in live]
            if not free:
                continue
            slot = free[pick % len(free)]
            prompt = _prompt(rnd, bases)
            if len(prompt) > kv.max_slot_tokens or \
                    not kv.can_admit(len(prompt), kv.shard_of_slot(slot)):
                continue
            cached = kv.alloc_slot_prefix(slot, len(prompt), prompt)
            assert 0 <= cached < len(prompt)
            assert int(kv.lens[slot]) == cached
            live[slot] = list(prompt[:cached])
            pending[slot] = prompt
            rid_of[slot] = next_rid[0]
            next_rid[0] += 1
        elif op == 1:                                 # prefill chunk
            slots = [s for s in sorted(pending) if s in live]
            if not slots:
                continue
            slot = slots[pick % len(slots)]
            prompt, done = pending[slot], len(live[slot])
            c = min(3, len(prompt) - done)
            if c <= 0 or not ensure(slot, done + c):
                pending.pop(slot, None)
                continue
            if slot not in live:                      # self-preempted
                continue
            live[slot].extend(prompt[done:done + c])
            kv.lens[slot] += c
            if len(live[slot]) == len(prompt):
                del pending[slot]
                kv.cache_slot_prefix(slot, live[slot])
        elif op == 2:                                 # decode one token
            slots = [s for s in sorted(live) if s not in pending]
            if not slots:
                continue
            slot = slots[pick % len(slots)]
            if not ensure(slot, int(kv.lens[slot]) + 1):
                continue
            if slot not in live:
                continue
            live[slot].append(rnd.randrange(200, 300))
            kv.lens[slot] += 1
        elif op == 3:                                 # retire (publish)
            slots = [s for s in sorted(live) if s not in pending]
            if not slots:
                continue
            slot = slots[pick % len(slots)]
            kv.cache_slot_prefix(slot, live[slot])
            kv.free_slot(slot)
            del live[slot]
        elif op == 4:                                 # preempt recompute
            if not live:
                continue
            slot = sorted(live)[pick % len(live)]
            kv.free_slot(slot)
            del live[slot]
            pending.pop(slot, None)
        elif op == 5:                                 # preempt offload
            slots = [s for s in sorted(live) if int(kv.lens[s]) > 0]
            if not slots:
                continue
            slot = slots[pick % len(slots)]
            kv.offload_slot(slot, rid_of[slot])
            parked[rid_of[slot]] = (live[slot], kv.shard_of_slot(slot))
            del live[slot]
            pending.pop(slot, None)
        else:                                         # restore
            if not parked:
                continue
            rid = sorted(parked)[pick % len(parked)]
            tokens, shard = parked[rid]
            if not kv.can_restore(rid):
                continue
            free = [s for s in kv.slots_of(shard) if s not in live]
            if not free:
                continue
            slot = free[pick % len(free)]
            del parked[rid]
            kv.restore_slot(rid, slot, len(tokens))
            live[slot] = list(tokens)
            rid_of[slot] = rid
        # -- the property: conservation after every op ----------------
        kv.check_integrity()
        for s, written in live.items():
            assert int(kv.lens[s]) == len(written)
            assert kv.slot_capacity(s) >= len(written)
    kv.check_integrity()


def _schedule(example: int):
    rnd = random.Random(example)
    n = rnd.randrange(8, 45)
    return [(rnd.randrange(7), rnd.randrange(8)) for _ in range(n)], \
        rnd.randrange(2 ** 31)


def test_refcount_conservation_interleavings():
    """The acceptance property: 500+ deterministic random interleavings
    (admit/prefill/decode/publish/retire/preempt/offload/restore) with
    conservation audited after every op — no leaks, no double-frees, no
    page dropped while another request or the trie references it."""
    for example in range(120):
        ops, seed = _schedule(example)
        _interleave(_kv(), ops, seed)


@pytest.mark.slow
def test_refcount_conservation_interleavings_deep():
    """The long tail of the same property — through 500+ total examples
    (with the fast tier above) including single-shard and tiny-pool
    variants where eviction and CoW-steal pressure is constant."""
    for example in range(120, 400):
        ops, seed = _schedule(example)
        _interleave(_kv(), ops, seed)
    for example in range(140):
        ops, seed = _schedule(10_000 + example)
        _interleave(_kv(shards=1, num_pages=8, max_slots=3), ops, seed)


def test_refcount_conservation_hypothesis():
    """Hypothesis fuzz over the same interpreter (optional in CI — the
    deterministic tiers above are the floor)."""
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=50, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=st.lists(st.tuples(st.integers(0, 6), st.integers(0, 7)),
                        min_size=1, max_size=40),
           seed=st.integers(0, 2 ** 31 - 1))
    def run(ops, seed):
        _interleave(_kv(), ops, seed)

    run()


# ---------------------------------------------------------------------------
# CoW / trie unit tests (allocator level)
# ---------------------------------------------------------------------------

def _admit_publish(kv, slot, tokens):
    """Admit + fully prefill + publish ``tokens`` on ``slot``."""
    cached = kv.alloc_slot_prefix(slot, len(tokens), tokens)
    kv.lens[slot] = len(tokens)
    kv.cache_slot_prefix(slot, tokens)
    return cached


def test_hit_binds_published_pages_and_caps_at_len_minus_1():
    kv = _kv(shards=1)
    base = list(range(1, 9))                    # 8 tokens = 4 pages
    _admit_publish(kv, 0, base)
    first = list(kv._slot_pages[0])
    # identical prompt: full-page hits capped at len-1 (one token must
    # always prefill to produce the first-sample logits)
    cached = kv.alloc_slot_prefix(1, len(base), base)
    assert cached == len(base) - 1              # 7: mid-page boundary
    assert kv._slot_pages[1][:3] == first[:3]   # pages 0..2 shared
    assert kv._slot_pages[1][3] == first[3]     # partial page 3 shared
    assert kv.prefix_hits == 1 and kv.prefix_hit_tokens == 7
    # longer prompt sharing the prefix: hit is page-aligned full pages
    cached = kv.alloc_slot_prefix(2, 10, base + [91, 92])
    assert cached == 8 and kv._slot_pages[2][:4] == first
    kv.check_integrity()


def test_page_aligned_flag_floors_the_hit():
    kv = _kv()
    base = list(range(1, 9))
    _admit_publish(kv, 0, base)
    cached = kv.alloc_slot_prefix(1, len(base), base, page_aligned=True)
    assert cached == 6                          # floor(7 / PS) * PS
    assert int(kv.lens[1]) == 6
    kv.check_integrity()


def test_cow_copies_shared_page_bit_exactly():
    kv = _kv()
    base = list(range(1, 9))
    _admit_publish(kv, 0, base)
    # give the shared pages distinguishable content
    rng = np.random.default_rng(0)
    host = jax.tree_util.tree_map(
        lambda leaf: rng.standard_normal(
            (leaf.shape[0], 4) + leaf.shape[2:]).astype(leaf.dtype),
        kv_cache.extract_pages(kv.pools, kv._slot_pages[0]))
    kv.pools = kv_cache.insert_pages(kv.pools, kv._slot_pages[0], host)
    cached = kv.alloc_slot_prefix(1, len(base), base)
    shared = kv._slot_pages[1][3]
    assert kv._refs[shared] >= 2
    assert kv.ensure_private(1, cached + 1)
    fresh = kv._slot_pages[1][3]
    assert fresh != shared and kv.prefix_cow_copies == 1
    got = kv_cache.extract_pages(kv.pools, [fresh])
    want = kv_cache.extract_pages(kv.pools, [shared])
    jax.tree_util.tree_map(
        lambda g, w: np.testing.assert_array_equal(g, w), got, want)
    # slot 0 and the trie still hold the original — nothing trimmed
    assert kv._slot_pages[0][3] == shared
    kv.check_integrity()


def test_cow_steals_trie_entry_when_pool_dry():
    # one shard, pool sized so the second slot's CoW finds no free page
    kv = _kv(shards=1, num_pages=6, max_slots=2, max_pages_per_seq=5)
    base = [1, 2, 3, 4]
    _admit_publish(kv, 0, base)
    kv.free_slot(0)                             # trie keeps the pages
    cached = kv.alloc_slot_prefix(0, len(base), base)   # 3 tokens hit
    assert cached == 3
    # drain the free list so the CoW target take must fail
    while kv._free_by_shard[0]:
        kv._free_by_shard[0].pop()
        kv.num_pages  # keep linters quiet about the loop body
    held = len(kv._free_by_shard[0])
    assert held == 0
    before = kv.prefix_cow_copies
    assert kv.ensure_private(0, cached + 1)     # steals, does not copy
    assert kv.prefix_cow_copies == before
    assert int(kv._refs[kv._slot_pages[0][1]]) == 1


def test_eviction_is_lru_and_trie_only():
    kv = _kv(shards=1, num_pages=10, max_slots=4, max_pages_per_seq=4)
    a, b = list(range(1, 9)), list(range(11, 19))   # 4 pages each
    _admit_publish(kv, 0, a)
    kv.free_slot(0)
    _admit_publish(kv, 0, b)
    kv.free_slot(0)                             # trie: a (older), b
    kv.alloc_slot_prefix(0, len(b), b)          # rebind b: pool is full
    b_pages = list(kv._slot_pages[0])
    assert len(kv._free_by_shard[0]) == 1       # 9 usable - 4 - 4
    # demand 2 fresh pages: the second take must evict — and it must
    # pick from a's trie-only (refs==1) pages, never b's bound ones
    kv.alloc_slot(1, 4)
    assert kv.prefix_evicted_pages >= 1
    assert kv._slot_pages[0] == b_pages         # b survived, still bound
    assert all(int(kv._refs[p]) == 2 for p in b_pages)
    kv.check_integrity()


def test_preemption_never_drops_shared_pages():
    kv = _kv(shards=1, num_pages=20, max_slots=4)
    base = list(range(1, 9))
    _admit_publish(kv, 0, base)
    kv.alloc_slot_prefix(1, len(base), base)    # victim-to-be shares
    shared = [p for p in kv._slot_pages[1] if int(kv._refs[p]) >= 2]
    assert shared
    # recompute-preempt the survivor's sharer: refs drop, pages survive
    kv.free_slot(1)
    for p in shared:
        assert int(kv._refs[p]) >= 1
        assert p not in kv._free_by_shard[0]
    # offload-preempt the original owner: the trim must deref, not free
    kv.offload_slot(0, rid=7)
    for p in shared:
        assert int(kv._refs[p]) == 1            # trie still holds them
        assert p not in kv._free_by_shard[0]
    kv.check_integrity()
    # restore round-trips onto fresh pages without disturbing the trie
    kv.restore_slot(7, 0, len(base))
    kv.check_integrity()


def test_match_prefix_is_shard_local():
    kv = _kv(shards=2, num_pages=24)
    base = list(range(1, 9))
    slot0 = kv.slots_of(0)[0]
    _admit_publish(kv, slot0, base)             # published on shard 0
    shard, cached = kv.match_prefix(base + [50], 9)
    assert shard == 0 and cached == 8
    # restricted to shard 1 there is no hit
    shard, cached = kv.match_prefix(base + [50], 9, candidates=[1])
    assert shard is None and cached == 0
    # and a shard-1 slot's admission cannot use shard 0's pages
    slot1 = kv.slots_of(1)[0]
    assert kv.alloc_slot_prefix(slot1, 9, base + [50]) == 0
    kv.check_integrity()


def test_prefix_off_is_refcount_free():
    """With prefix_cache off every counter stays 0, refcounts stay <= 1
    and free accounting equals the raw free lists (the off path must be
    bit-identical to the pre-prefix allocator)."""
    kv = _kv(prefix_cache=False)
    base = list(range(1, 9))
    assert kv.alloc_slot_prefix(0, len(base), base) == 0
    kv.lens[0] = len(base)
    kv.cache_slot_prefix(0, base)               # no-op
    assert kv.match_prefix(base, 8) == (None, 0)
    assert kv.ensure_private(0, 9)
    assert not kv._node_of_page and kv.prefix_hits == 0
    assert all(int(r) <= 1 for r in kv._refs)
    for s in range(kv.n_shards):
        assert kv.free_pages_of(s) == len(kv._free_by_shard[s])
    kv.free_slot(0)
    kv.check_integrity()


# ---------------------------------------------------------------------------
# pool-level accounting (the shared-page double-count bugfix)
# ---------------------------------------------------------------------------

def test_shared_pages_count_once_in_pool_accounting():
    """Two full-hit requests must report ~1x the pages of one: shared
    pages count once in used/held/peak accounting (pool-level), and a
    slot's exclusive held_bytes excludes pages another slot shares."""
    kv = _kv(shards=1, num_pages=20)
    base = list(range(1, 9))                    # 4 pages
    _admit_publish(kv, 0, base)
    kv.free_slot(0)
    solo = _kv(shards=1, num_pages=20)
    _admit_publish(solo, 0, base)
    one = solo.used_pages_of(0)
    # two full-hit requests over the published prefix
    kv.alloc_slot_prefix(0, len(base), base)
    kv.alloc_slot_prefix(1, len(base), base)
    both = kv.used_pages_of(0)
    # 4 shared + one private CoW-boundary page each at most
    assert both <= one + 2
    assert kv.used_pages == both
    # held_bytes: the shared pages are attributed to no slot
    assert kv.held_bytes(0) == 0 and kv.held_bytes(1) == 0
    # peak tracking follows physical pages, not per-slot sums
    assert kv.peak_used_pages <= 20 - 1
    assert kv.per_device_peak_used_bytes == \
        kv.peak_used_pages * kv.page_bytes
    kv.check_integrity()


# ---------------------------------------------------------------------------
# engine-level token exactness: prefix on == off, bit for bit
# ---------------------------------------------------------------------------

def _engine_outputs(cfg, params, prefix, waves, *, storm=0, preempt="auto",
                    num_pages=0, max_new=6):
    eng = Engine(cfg, params, options=EngineOptions(
        page_size=4, max_slots=4, max_seq_len=64, chunk=16, min_bucket=8,
        adaptive=True, prefix_cache=prefix, storm_every=storm,
        preempt=preempt, num_pages=num_pages))
    outs = []
    for wave in waves:
        reqs = [eng.submit(np.asarray(p, np.int32), max_new_tokens=max_new,
                           arrival_s=0.0) for p in wave]
        eng.run_until_idle()
        outs.extend(list(r.output) for r in reqs)
    if eng.kv.prefix_enabled:
        eng.kv.check_integrity()
    return outs, eng


def _waves(vocab, seed=3):
    rnd = np.random.default_rng(seed)
    shared = rnd.integers(1, vocab, size=16).astype(np.int32)
    w1 = [np.concatenate([shared, rnd.integers(1, vocab, size=k)
                          .astype(np.int32)]) for k in (3, 5)]
    # warm wave: full-prefix resubmits (mid-page hit -> CoW) and longer
    # continuations of the published prefix
    w2 = [shared.copy(), shared.copy(),
          np.concatenate([shared, rnd.integers(1, vocab, size=2)
                          .astype(np.int32)])]
    return [w1, w2]


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["moe-gpt3-s", "deepseek-v2-lite-16b",
                                  "jamba-1.5-large-398b"])
def test_prefix_on_off_token_exact(arch):
    """Same trace, prefix on vs off: bit-identical tokens. Covers plain
    paged KV, the MLA latent cache, and the composite (jamba) cache —
    which degrades prefix to off and must still match exactly."""
    cfg = dataclasses.replace(
        get_config(arch).reduced(), compute_dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    waves = _waves(cfg.vocab_size)
    off, _ = _engine_outputs(cfg, params, "off", waves)
    on, eng = _engine_outputs(cfg, params, "on", waves)
    assert on == off
    s = eng.stats()
    if eng.cache_kind == "paged":
        assert s["prefix_hits"] >= 3 and s["prefix_hit_tokens"] >= 30
        assert s["prefix_cow_copies"] >= 1     # full-prefix resubmits
    else:
        assert not eng.kv.prefix_enabled and s["prefix_hits"] == 0


@pytest.mark.slow
def test_prefix_storm_token_exact():
    """Forced preemption storm with shared pages in flight: victims
    share pages with survivors, so recompute/offload preemption runs
    straight through the CoW/refcount machinery — tokens must still be
    bit-identical to the storm with the prefix cache off."""
    cfg = dataclasses.replace(
        get_config("moe-gpt3-s").reduced(), compute_dtype="float32")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    waves = _waves(cfg.vocab_size, seed=11)
    for preempt in ("auto", "offload"):
        off, eoff = _engine_outputs(cfg, params, "off", waves,
                                    storm=3, preempt=preempt)
        on, eon = _engine_outputs(cfg, params, "on", waves,
                                  storm=3, preempt=preempt)
        assert on == off
        total = (eon.preempts["recompute"] + eon.preempts["offload"])
        assert total >= 2, "storm did not preempt"
