"""Eq. 10 performance model + Eqs. 1-6 memory model (paper §III-D/E)."""
import dataclasses

import pytest

from repro.core.memory_model import MoEMemory
from repro.core.perf_model import (MoEWorkload, all_costs, cost,
                                   select_strategy, stream_times)
from repro.core.types import Q_TABLE, TPU_V5E, HardwareSpec, Strategy


def test_q_table_matches_paper_table_ii():
    assert Q_TABLE[Strategy.NONE] == ((2, 2, 0), (4, 2, 0))
    assert Q_TABLE[Strategy.S1] == ((2, 2, 5), (4, 2, 5))
    assert Q_TABLE[Strategy.S2] == ((2, 2, 4), (4, 3, 4))
    assert Q_TABLE[Strategy.S3] == ((2, 2, 1), (5, 2, 1))
    assert Q_TABLE[Strategy.S4] == ((2, 2, 0), (5, 3, 0))


def test_strategy_restore_semantics():
    assert Strategy.S1.offloads == ("t_di", "t_m")
    assert Strategy.S2.offloads == ("t_m",)      # t_di re-communicated
    assert Strategy.S3.offloads == ("t_di",)     # t_m recomputed
    assert Strategy.S4.offloads == ()
    assert Strategy.NONE.saves == ("t_di", "t_m")


def test_cost_is_max_of_streams():
    w = MoEWorkload(b=4096, m=1024, h=4096, k=1, ep=16)
    t = stream_times(Strategy.S2, w, TPU_V5E)
    c = cost(Strategy.S2, w, TPU_V5E)
    assert c == pytest.approx(max(t["comp"], t["comm"], t["mem"])
                              + t["overhead"])


def test_no_host_masks_offload_strategies():
    w = MoEWorkload(b=4096, m=1024, h=4096, k=1, ep=16)
    hw = dataclasses.replace(TPU_V5E, has_host_offload=False)
    assert select_strategy(w, hw) == Strategy.S4


def test_compute_bound_prefers_offload_io_bound_prefers_recompute():
    # compute-bound (huge experts, few devices) -> S1/S2 (extra GEMMs of
    # S3/S4 hurt); comm-bound (many devices) -> recompute side wins
    w_comp = MoEWorkload(b=8192, m=4096, h=16384, k=1, ep=4)
    w_comm = MoEWorkload(b=8192, m=4096, h=4096, k=1, ep=64,
                         dtype_bytes=4)
    s_comp = select_strategy(w_comp, TPU_V5E)
    assert s_comp in (Strategy.S1, Strategy.S2)
    costs = all_costs(w_comm, TPU_V5E)
    # S2 adds a backward All-to-All: never cheaper than S4 when comm-bound
    assert costs["s4"] <= costs["s2"] + 1e-12


# ---------------------------------------------------------------------------
# memory model (Eqs. 1-6)
# ---------------------------------------------------------------------------

def test_memory_formulas():
    mm = MoEMemory(b=8192, m=768, h=3072, e=64, n=4, bytes_per=1)
    assert mm.m_ms == 4 * (64 * 768 + 2 * 3072 * 768)          # Eq. 1
    assert mm.m_act == 4 * 8192 * 768 + 8192 * 3072            # Eq. 2
    assert mm.m_buf == 8192 * 768 + 8192 * 3072                # Eq. 3
    assert mm.m_buf_pipe == mm.m_act_pipe                      # Eq. 4
    expected_delta = 8192 * (2 * 768 * (4 - 2) / 4
                             + 3072 * (4 - 1) / 4)             # Eq. 5
    assert mm.delta_act == pytest.approx(expected_delta)
    phi = ((mm.delta_act + mm.delta_buf)
           / (mm.m_ms + mm.m_act_pipe + mm.m_buf_pipe))        # Eq. 6
    assert mm.phi == pytest.approx(phi)
    assert 0 < mm.phi < 1


def test_phi_grows_with_partitions_and_saturates():
    phis = [MoEMemory(b=16384, m=1024, h=4096, e=64, n=n).phi
            for n in (2, 4, 8, 16)]
    assert phis == sorted(phis)
    assert phis[-1] - phis[-2] < phis[1] - phis[0]   # diminishing returns


def test_phi_larger_for_larger_batches():
    """Fig. 2: activations dominate at large B, so reuse saves more."""
    small = MoEMemory(b=256, m=768, h=3072, e=64, n=8).phi
    large = MoEMemory(b=16384, m=768, h=3072, e=64, n=8).phi
    assert large > small
