"""Per-shard paged-KV allocator: the host-side half of the DP-sharded
KV layout, testable without a mesh (``PagedKVCache(shards=k)`` shards
the free lists / page table / accounting while the pools stay on one
device — device placement is exercised on 8 virtual devices in
``tests/test_serving_conformance.py``).

Invariants restated per shard (the tentpole contract):
* each shard's local page 0 (globally ``s * pages_per_shard``) is
  reserved as that shard's masked-write sink — never allocated;
* a slot binds pages of its own shard only; no page is ever bound twice
  or freed twice (the hypothesis schedule test);
* global free-page count is conserved: free + bound ==
  ``num_pages - n_shards`` at every step (an offloaded request holds
  zero device pages);
* pool-dry is per shard: one shard running dry does not consume — or
  unblock on — another shard's pages;
* placement is sticky: an offloaded request can only restore onto its
  owning shard.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.serve import PagedKVCache


def _cfg():
    return dataclasses.replace(get_config("llama3-8b").reduced(),
                               compute_dtype="float32")


def _kv(**over):
    kw = dict(num_pages=16, page_size=2, max_slots=4,
              max_pages_per_seq=4, dtype=np.float32, shards=2)
    kw.update(over)
    return PagedKVCache(_cfg(), **kw)


def _check_shards(kv: PagedKVCache) -> None:
    """Full allocator audit: per-shard integrity + global conservation."""
    bound_total = 0
    for sh in range(kv.n_shards):
        lo, hi = sh * kv.pages_per_shard, (sh + 1) * kv.pages_per_shard
        free = set(kv._free_by_shard[sh])
        bound = [p for s in kv.slots_of(sh) for p in kv._slot_pages[s]]
        bound_total += len(bound)
        sink = kv.sink_page(sh)
        assert sink == lo                       # local page 0
        assert sink not in free and sink not in bound
        assert len(bound) == len(set(bound))    # never bound twice
        assert free.isdisjoint(bound)           # never free AND bound
        assert all(lo <= p < hi for p in free | set(bound))
        # per-shard conservation: nothing leaked, nothing conjured
        assert len(free) + len(bound) == kv.pages_per_shard - 1
    assert kv.free_pages + bound_total == kv.num_pages - kv.n_shards
    for slot in range(kv.max_slots):
        n = len(kv._slot_pages[slot])
        sink = kv.sink_page(kv.shard_of_slot(slot))
        assert list(kv.page_table[slot, :n]) == kv._slot_pages[slot]
        assert (kv.page_table[slot, n:] == sink).all()


# ---------------------------------------------------------------------------
# Deterministic shard semantics
# ---------------------------------------------------------------------------

def test_shard_topology_and_rounding():
    kv = _kv()
    assert kv.n_shards == 2 and kv.pages_per_shard == 8
    assert kv.slots_per_shard == 2
    assert [kv.sink_page(s) for s in range(2)] == [0, 8]
    assert [kv.shard_of_slot(s) for s in range(4)] == [0, 0, 1, 1]
    assert list(kv.slots_of(1)) == [2, 3]
    assert kv.shard_capacity_pages == 7
    _check_shards(kv)
    # odd sizes round up to the shard count (device arrays must split)
    kv2 = _kv(num_pages=15, max_slots=3)
    assert kv2.num_pages == 16 and kv2.max_slots == 4
    # floor: every shard needs its sink + one real page
    kv3 = _kv(num_pages=2, shards=4)
    assert kv3.num_pages == 8 and kv3.pages_per_shard == 2


def test_alloc_stays_shard_local_and_reserves_no_sink():
    kv = _kv()
    kv.alloc_slot(0, 8)           # 4 pages on shard 0
    kv.alloc_slot(2, 8)           # 4 pages on shard 1
    assert all(0 < p < 8 for p in kv._slot_pages[0])
    assert all(8 < p < 16 for p in kv._slot_pages[2])
    _check_shards(kv)
    kv.free_slot(0)
    kv.free_slot(2)
    _check_shards(kv)
    assert kv.free_pages == kv.num_pages - kv.n_shards


def test_pool_dry_is_per_shard():
    """Shard 0 running dry neither consumes nor unblocks on shard 1's
    pages — growth on a shard-0 slot fails while shard 1 is empty."""
    kv = _kv()
    kv.alloc_slot(0, 6)                        # 3 of shard 0's 7 pages
    kv.alloc_slot(1, 8)                        # 4 more: shard 0 dry
    assert kv.free_pages_of(0) == 0 and kv.free_pages_of(1) == 7
    assert not kv.grow_slot(0)                 # dry despite 7 free pages
    assert not kv.can_admit(2, shard=0)        # ...on the other shard
    assert kv.can_admit(2, shard=1)
    assert kv.can_admit(2)                     # shard=None: any shard
    _check_shards(kv)


def test_best_shard_is_least_loaded_with_low_tie_break():
    kv = _kv()
    assert kv.best_shard(2) == 0               # tie -> lowest id
    kv.alloc_slot(0, 4)                        # load shard 0
    assert kv.best_shard(2) == 1               # least-loaded wins
    assert kv.best_shard(2, candidates=[0]) == 0
    assert kv.best_shard(100) is None          # nobody fits
    kv.alloc_slot(2, 8)
    kv.alloc_slot(3, 4)                        # shard 1 now fuller
    assert kv.best_shard(2) == 0


def test_restore_is_sticky_to_owning_shard():
    kv = _kv()
    kv.alloc_slot(0, 4)                        # shard 0
    kv.lens[0] = 4
    kv.offload_slot(0, rid=7)
    assert kv.offloaded_shard(7) == 0
    _check_shards(kv)
    with pytest.raises(AssertionError, match="sticky"):
        kv.restore_slot(7, slot=2, tokens=4)   # slot 2 is shard 1's
    kv.restore_slot(7, slot=1, tokens=4)       # same shard: fine
    assert all(0 < p < 8 for p in kv._slot_pages[1])
    _check_shards(kv)


def test_offload_trim_returns_tail_to_owning_shard():
    """The PR 3 grown-ahead gotcha, per shard: the trimmed tail goes
    back to the *owning* shard's free list."""
    kv = _kv()
    kv.alloc_slot(2, 2)                        # shard 1, 1 page
    kv.grow_slot(2)
    kv.grow_slot(2)                            # 3 pages held
    kv.lens[2] = 2                             # ...1 page of real KV
    kv.offload_slot(2, rid=1)
    assert kv.offloaded_pages(1) == 1
    assert kv.free_pages_of(1) == 7            # tail came home
    assert kv.free_pages_of(0) == 7
    _check_shards(kv)


def test_single_shard_degenerates_to_pr2_layout():
    """shards=1 must reproduce the unsharded allocator exactly (the
    replicated engines run through this path untouched)."""
    kv = _kv(shards=1, num_pages=9, max_slots=3)
    assert kv.n_shards == 1 and kv.pages_per_shard == 9
    assert kv.sink_page(0) == 0
    assert sorted(kv._free) == list(range(1, 9))
    assert (kv.page_table == 0).all()
    kv.alloc_slot(1, 6)
    _check_shards(kv)


# ---------------------------------------------------------------------------
# Hypothesis schedule property
# ---------------------------------------------------------------------------

def test_per_shard_free_lists_random_schedules():
    """Random admission / growth / preempt(recompute) / offload /
    restore / complete schedules keep every shard's allocator exact: no
    leak, no double-free, sinks reserved, conservation holds — audited
    after every single op."""
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    NP, PS, SLOTS, MPS, SHARDS = 16, 2, 4, 4, 2

    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=st.lists(st.tuples(st.integers(0, 5),
                                  st.integers(0, SLOTS - 1),
                                  st.integers(1, MPS * PS)),
                        min_size=1, max_size=80))
    def run(ops):
        kv = _kv(num_pages=NP, page_size=PS, max_slots=SLOTS,
                 max_pages_per_seq=MPS, shards=SHARDS)
        held, offl, rid = {}, {}, 0
        for op, slot, tokens in ops:
            if op == 0:                        # admission: least-loaded
                free_slots = [s for s in range(SLOTS) if s not in held]
                shard = kv.best_shard(tokens, candidates=sorted(
                    {kv.shard_of_slot(s) for s in free_slots}))
                if shard is not None:
                    s = next(s for s in free_slots
                             if kv.shard_of_slot(s) == shard)
                    kv.alloc_slot(s, tokens)
                    held[s] = tokens
            elif op == 1 and slot in held:     # decode growth
                if len(kv._slot_pages[slot]) < MPS:
                    kv.grow_slot(slot)         # False when shard dry
            elif op == 2 and slot in held:     # preempt by recompute
                kv.free_slot(slot)
                del held[slot]
            elif op == 3 and slot in held and kv.slot_page_count(slot):
                cached = kv.slot_capacity(slot)    # page-aligned
                kv.lens[slot] = cached
                kv.offload_slot(slot, rid)     # preempt by offload
                offl[rid] = cached
                del held[slot]
                rid += 1
            elif op == 4 and offl:             # resume (sticky shard)
                r, cached = next(iter(offl.items()))
                shard = kv.offloaded_shard(r)
                free_slots = [s for s in kv.slots_of(shard)
                              if s not in held]
                if free_slots and kv.can_restore(r):
                    kv.restore_slot(r, free_slots[0], cached)
                    held[free_slots[0]] = cached
                    del offl[r]
            elif op == 5 and slot in held:     # complete
                kv.free_slot(slot)
                del held[slot]
            assert kv.offloaded_count == len(offl)
            _check_shards(kv)

    run()
