"""Pins tools/check_docs.py command extraction.

Two behaviors are load-bearing for the docs gate:

1. **Fence pairing.** The fence regex must consume EVERY opener,
   whatever its info string. The old pattern only matched
   ``bash``/``sh``/``console``/anonymous openers, so a ```` ```python ````
   block's opener went unmatched and its CLOSER re-opened as an
   anonymous fence — swallowing the prose after the block (phantom
   commands from example text, real commands in the next fence shifted
   out of scanning). Non-shell blocks are matched, then skipped.

2. **Line-1-only flags.** Flags are extracted from the first physical
   line of a command; a trailing ``\\`` is stripped but continuation
   lines are NOT joined. Docs must keep load-bearing flags on line 1
   (that is what REQUIRED_FLAGS cross-checks), and the gate must not
   invent flags from unrelated following lines.
"""
import importlib.util
import os
import textwrap

_SPEC = importlib.util.spec_from_file_location(
    "check_docs", os.path.join(os.path.dirname(__file__), os.pardir,
                               "tools", "check_docs.py"))
check_docs = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(check_docs)
extract_commands = check_docs.extract_commands


def test_python_fence_does_not_swallow_following_prose():
    """A ```python block must pair with its own closer: the prose after
    it is NOT inside a fence (no phantom commands extracted from it)
    and the next real shell fence IS scanned."""
    text = textwrap.dedent('''\
        ```python
        # example snippet, not a command
        train(cfg)
        ```

        Prose mentioning python tools/not_a_command.py --bogus inline.

        ```bash
        python benchmarks/serving.py --smoke
        ```
        ''')
    cmds = extract_commands(text)
    assert cmds == [("benchmarks/serving.py", ["--smoke"])]


def test_non_shell_blocks_are_skipped_entirely():
    """Command-looking lines inside a ```python (or any non-shell) block
    are examples, not documented commands."""
    text = textwrap.dedent('''\
        ```python
        subprocess.run(["python", "benchmarks/serving.py", "--overload"])
        ```
        ```text
        python tools/check_docs.py --root .
        ```
        ''')
    assert extract_commands(text) == []


def test_shell_info_strings_are_scanned():
    text = "".join(
        f"```{info}\npython -m repro.launch.serve --devices 8\n```\n"
        for info in ("", "bash", "sh", "console"))
    cmds = extract_commands(text)
    assert cmds == [("-m repro.launch.serve", ["--devices"])] * 4


def test_flags_extracted_from_first_line_only():
    """Continuation lines are not joined: line 1's flags are extracted
    (trailing backslash stripped), later lines contribute nothing."""
    text = textwrap.dedent('''\
        ```bash
        python benchmarks/serving.py --smoke --devices 8 \\
            --kv-sharding dp --overload
        ```
        ''')
    cmds = extract_commands(text)
    assert cmds == [("benchmarks/serving.py", ["--smoke", "--devices"])]


def test_required_flags_cover_the_new_kernel_surface():
    """The PR 8 flags are pinned: dropping either from its CLI or from
    the docs fails the gate."""
    assert "--attn-kernel-compare" in \
        check_docs.REQUIRED_FLAGS["benchmarks/serving.py"]
    assert "--attn-kernel" in \
        check_docs.REQUIRED_FLAGS["-m repro.launch.serve"]


def test_docs_tree_extracts_cleanly():
    """Smoke the real docs tree through the fixed extractor: every file
    parses and the pinned targets are present in the documented set."""
    root = os.path.join(os.path.dirname(__file__), os.pardir)
    targets = set()
    for f in check_docs.md_files(root):
        targets |= {t for t, _ in extract_commands(open(f).read())}
    for required in check_docs.REQUIRED_FLAGS:
        assert required in targets, required
