"""Cancellation conformance (Engine.cancel / Scheduler.cancel): a
request cancelled from every lifecycle stage — QUEUED, PREFILL
mid-chunk, DECODE, PREEMPTED (recompute and offload) — must release
everything it holds (queue entry, slot, pages, host snapshot), keep the
page-refcount audit clean, and leave every surviving request
token-exact vs the uncancelled golden run. Parametrized over
prefix_cache on|off, since cancellation publishes completed prefix
pages on the way out."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve import (Engine, EngineOptions, RequestState,
                         dense_greedy_reference as ref_decode)

PROMPT_LENS = (13, 29, 7, 21, 5)
MAX_NEW = (6, 4, 8, 5, 7)

pytestmark = pytest.mark.parametrize("prefix", ["off", "on"])


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                              compute_dtype="float32")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.Generator(np.random.Philox(key=7))
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in PROMPT_LENS]
    refs = [ref_decode(params, cfg, p, m)
            for p, m in zip(prompts, MAX_NEW)]
    return cfg, params, prompts, refs


def _engine(cfg, params, prefix, **over):
    kw = dict(page_size=4, max_slots=3, max_seq_len=64, chunk=16,
              min_bucket=8, prefix_cache=prefix)
    kw.update(over)
    return Engine(cfg, params, options=EngineOptions(**kw))


def _submit_all(eng, prompts):
    return [eng.submit(p, max_new_tokens=m, arrival_s=0.0)
            for p, m in zip(prompts, MAX_NEW)]


def _step_until(eng, pred, limit=500):
    """Step until ``pred()`` returns a truthy value (the victim)."""
    for _ in range(limit):
        eng.step()
        got = pred()
        if got:
            return got
    raise AssertionError("target lifecycle stage never reached")


def _check_end_state(eng, reqs, refs, victims):
    """Drain, audit the allocator, check the victims terminal and the
    survivors bit-exact vs the uncancelled dense reference."""
    eng.run_until_idle()
    eng.kv.check_integrity()
    assert not any(getattr(eng.kv, "_slot_pages", [])), "pages leaked"
    for v in victims:
        assert v.state == RequestState.CANCELLED
        assert v.finish_reason == "cancelled"
        assert v.slot == -1
        # whatever it produced before dying is a prefix of its golden run
        ref = refs[reqs.index(v)]
        assert v.output == ref[:len(v.output)]
    for r, ref in zip(reqs, refs):
        if r in victims:
            continue
        assert r.state == RequestState.DONE
        assert r.output == ref
    assert eng.stats()["requests_cancelled"] == len(victims)


def test_cancel_queued(setup, prefix):
    cfg, params, prompts, refs = setup
    eng = _engine(cfg, params, prefix)
    reqs = _submit_all(eng, prompts)
    victim = reqs[3]
    assert victim.state == RequestState.QUEUED
    assert eng.cancel(victim)
    assert victim not in eng.scheduler.waiting
    assert not victim.output                 # never produced a token
    _check_end_state(eng, reqs, refs, [victim])
    assert eng.stats()["cancelled_by_stage"] == {"queued": 1}


def test_cancel_prefill_mid_chunk(setup, prefix):
    cfg, params, prompts, refs = setup
    eng = _engine(cfg, params, prefix)
    reqs = _submit_all(eng, prompts)

    def mid_prefill():
        return next(
            (r for r in reqs if r.state == RequestState.PREFILL
             and 0 < int(eng.kv.lens[r.slot]) < len(r.prompt)), None)

    victim = _step_until(eng, mid_prefill)
    slot = victim.slot
    assert eng.cancel(victim)
    # the slot is back immediately, not at some later retirement
    assert victim.slot == -1
    assert slot not in eng.scheduler.running
    assert slot not in eng.scheduler._prefilling
    _check_end_state(eng, reqs, refs, [victim])
    assert eng.stats()["cancelled_by_stage"] == {"prefill": 1}


def test_cancel_prefill_publishes_prefix(setup, prefix):
    """With the prefix cache on, a cancelled request's completed full
    pages are published on the way out — a later identical prompt
    skips that prefill work and still decodes bit-exact."""
    if prefix == "off":
        pytest.skip("prefix-cache path only")
    cfg, params, prompts, refs = setup
    eng = _engine(cfg, params, prefix)
    long_i = PROMPT_LENS.index(29)           # 2 chunks of 16
    r1 = eng.submit(prompts[long_i], max_new_tokens=MAX_NEW[long_i])

    def mid_prefill():
        return (r1 if r1.state == RequestState.PREFILL
                and int(eng.kv.lens[r1.slot]) >= eng.kv.page_size
                else None)

    _step_until(eng, mid_prefill)
    assert eng.cancel(r1)
    eng.kv.check_integrity()
    r2 = eng.submit(prompts[long_i], max_new_tokens=MAX_NEW[long_i])
    eng.run_until_idle()
    assert r2.output == refs[long_i]
    assert eng.stats()["prefix_hits"] >= 1
    eng.kv.check_integrity()


def test_cancel_decode(setup, prefix):
    cfg, params, prompts, refs = setup
    eng = _engine(cfg, params, prefix)
    reqs = _submit_all(eng, prompts)
    victim = _step_until(eng, lambda: next(
        (r for r in reqs
         if r.state == RequestState.DECODE and r.output), None))
    assert eng.cancel(victim)
    assert victim.slot == -1
    _check_end_state(eng, reqs, refs, [victim])
    assert eng.stats()["cancelled_by_stage"] == {"decode": 1}


@pytest.mark.parametrize("mode", ["recompute", "offload"])
def test_cancel_preempted(setup, prefix, mode):
    cfg, params, prompts, refs = setup
    # pool pressure (test_preemption's storm sizing) so requests are
    # parked in PREEMPTED for the cancel to land on
    eng = _engine(cfg, params, prefix, num_pages=12, preempt=mode)
    reqs = _submit_all(eng, prompts)
    victim = _step_until(eng, lambda: next(
        (r for r in reqs if r.state == RequestState.PREEMPTED), None))
    assert victim.preempt_mode == mode
    if mode == "offload":
        assert eng.kv.offloaded_count >= 1
        before = eng.kv.host_bytes
        assert before > 0
    assert eng.cancel(victim)
    assert victim not in eng.scheduler.resuming
    if mode == "offload":
        # the host snapshot died with the request
        assert eng.kv.host_bytes < before or eng.kv.offloaded_count == 0
    eng.kv.check_integrity()
    _check_end_state(eng, reqs, refs, [victim])
    assert eng.stats()["cancelled_by_stage"] == {"preempted": 1}
    assert eng.kv.offloaded_count == 0 and eng.kv.host_bytes == 0


def test_cancel_done_is_noop(setup, prefix):
    cfg, params, prompts, refs = setup
    eng = _engine(cfg, params, prefix)
    r = eng.submit(prompts[0], max_new_tokens=MAX_NEW[0])
    eng.run_until_idle()
    assert r.state == RequestState.DONE
    # the disconnect-vs-finished race: cancel after completion is a no-op
    assert not eng.cancel(r)
    assert r.state == RequestState.DONE and r.output == refs[0]
    assert eng.stats()["requests_cancelled"] == 0
