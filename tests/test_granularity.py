"""Algorithm 1 (adaptive pipeline granularity search) behaviour."""
from repro.core.granularity import GranularitySearcher
from repro.core.perf_model import MoEWorkload
from repro.core.pipeline_sim import simulate
from repro.core.types import TPU_V5E, Strategy


def _measure_step(b, n):
    """Synthetic measure with a clear optimum that grows with B."""
    # ideal n ~ b / 1024; cost = |n - ideal| + overhead*n
    ideal = max(1, b // 1024)
    return abs(n - ideal) + 0.01 * n


def test_cache_avoids_research():
    s = GranularitySearcher(_measure_step, candidates=(1, 2, 4, 8, 16))
    n1 = s.best_n(4096)
    calls = s.search_calls
    n2 = s.best_n(4096)
    assert n1 == n2
    assert s.search_calls == calls          # hash-table hit (lines 3-5)


def test_range_reuse_without_research():
    s = GranularitySearcher(_measure_step, candidates=(1, 2, 4, 8, 16))
    # 4200 and 4800 share the same optimal n -> one merged range
    s.best_n(4200)
    s.best_n(4800)
    calls = s.search_calls
    n = s.best_n(4500)        # inside [4200, 4800] -> range lookup only
    assert s.search_calls == calls
    assert n == s.best_n(4200)


def test_monotone_ranges_stay_disjoint():
    s = GranularitySearcher(_measure_step, candidates=(1, 2, 4, 8, 16))
    for b in (512, 2048, 9000, 1024, 17000, 3000, 700):
        s.best_n(b)
    rs = s.ranges
    for (lo1, hi1, _), (lo2, hi2, _) in zip(rs, rs[1:]):
        assert hi1 < lo2                     # disjoint, sorted
    # monotonicity hypothesis: n non-decreasing in B
    ns = [n for (_, _, n) in rs]
    assert ns == sorted(ns)


def test_sim_measure_picks_larger_n_for_larger_b():
    """With the analytic simulator, bigger batches pipeline deeper —
    the hypothesis Algorithm 1 rests on (paper Fig. 12)."""
    hw = TPU_V5E

    def measure(b, n):
        w = MoEWorkload(b=b, m=768, h=3072, k=1, ep=16)
        return simulate(w, hw, n, Strategy.S4)

    s = GranularitySearcher(measure, candidates=(1, 2, 4, 8, 16, 32))
    small = s.best_n(256)
    large = s.best_n(65536)
    assert large >= small


def test_pipelining_beats_serial_when_comm_bound():
    w = MoEWorkload(b=8192, m=768, h=3072, k=1, ep=16)
    serial = simulate(w, TPU_V5E, 1, Strategy.S4)
    piped = simulate(w, TPU_V5E, 8, Strategy.S4)
    assert piped < serial
