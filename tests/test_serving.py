"""Serving engine (repro.serve): golden-token equivalence vs the dense
sequential loop, paged gather/scatter correctness, KV page accounting,
admission control, stop conditions and hw-spec resolution."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import CPU_HOST, TPU_V5E, resolve_hw
from repro.models import kv_cache, lm
from repro.models.api import serving_support
from repro.serve import (Engine, EngineOptions, RequestState,
                         dense_greedy_reference as ref_decode)

PROMPT_LENS = (13, 29, 7, 21, 5)
MAX_NEW = (6, 4, 8, 5, 7)


def _cfg(name):
    cfg = get_config(name).reduced()
    moe = cfg.moe
    if moe is not None:
        # generous capacity => no dropped tokens => the MoE layer is a
        # per-token function and chunked prefill is exact (the invariant
        # the golden test relies on)
        moe = dataclasses.replace(moe, capacity_factor=8.0)
    return dataclasses.replace(cfg, compute_dtype="float32", moe=moe)


@pytest.fixture(scope="module", params=["llama3-8b", "moe-gpt3-s"])
def setup(request):
    cfg = _cfg(request.param)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.Generator(np.random.Philox(key=7))
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in PROMPT_LENS]
    refs = [ref_decode(params, cfg, p, m)
            for p, m in zip(prompts, MAX_NEW)]
    return cfg, params, prompts, refs


def _engine(cfg, params, **over):
    kw = dict(page_size=4, max_slots=3, max_seq_len=64, chunk=16,
              min_bucket=8)
    kw.update(over)
    return Engine(cfg, params, options=EngineOptions(**kw))


# ---------------------------------------------------------------------------
# Golden-token equivalence (the tentpole invariant)
# ---------------------------------------------------------------------------

def test_golden_token_equivalence(setup):
    """Continuous batching + paged KV + chunked prefill emits exactly the
    greedy tokens of the dense sequential loop — under slot pressure, so
    slots (and their pages) are reused across requests."""
    cfg, params, prompts, refs = setup
    eng = _engine(cfg, params)
    assert eng.kv.max_slots < len(prompts)      # force queueing + reuse
    for p, m in zip(prompts, MAX_NEW):
        eng.submit(p, max_new_tokens=m, arrival_s=0.0)
    eng.run_until_idle()
    outs = [r.output for r in sorted(eng.done, key=lambda r: r.rid)]
    assert outs == refs
    # every request covered >1 prefill bucket across the mixed lengths
    assert len(eng.adaptive.resolutions) >= 2
    if cfg.moe is not None:
        for bucket, (n, strat) in eng.adaptive.resolutions.items():
            assert n >= 1 and strat in ("none", "s1", "s2", "s3", "s4")


def test_golden_token_equivalence_windowed():
    """Sliding-window layers (gemma3 5:1 local:global) through the paged
    path: the position-contiguous gathered view + window masking must
    match the dense ring-buffer reference, including after the sequence
    length passes the window."""
    cfg = _cfg("gemma3-12b")
    assert cfg.attn.window > 0
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.Generator(np.random.Philox(key=11))
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in (29, 9)]
    max_new = (6, 5)
    assert max(len(p) + m for p, m in zip(prompts, max_new)) \
        > cfg.attn.window                        # ring wraps in the ref
    refs = [ref_decode(params, cfg, p, m)
            for p, m in zip(prompts, max_new)]
    eng = _engine(cfg, params, max_slots=2)
    for p, m in zip(prompts, max_new):
        eng.submit(p, max_new_tokens=m, arrival_s=0.0)
    eng.run_until_idle()
    outs = [r.output for r in sorted(eng.done, key=lambda r: r.rid)]
    assert outs == refs


def test_eos_early_exit_and_length_stop(setup):
    cfg, params, prompts, refs = setup
    eng = _engine(cfg, params)
    # eos = the reference's second token => engine must stop right there
    r_eos = eng.submit(prompts[0], max_new_tokens=MAX_NEW[0],
                       eos_id=refs[0][1])
    r_len = eng.submit(prompts[2], max_new_tokens=3)
    eng.run_until_idle()
    assert r_eos.output == refs[0][:2] and r_eos.finish_reason == "eos"
    assert r_len.output == refs[2][:3] and r_len.finish_reason == "length"
    assert r_eos.state == RequestState.DONE


def test_streaming_callbacks(setup):
    cfg, params, prompts, refs = setup
    eng = _engine(cfg, params)
    streamed, done = [], []
    eng.submit(prompts[2], max_new_tokens=4,
               on_token=lambda t, r: streamed.append(t),
               on_done=lambda r: done.append(r.rid))
    eng.run_until_idle()
    assert streamed == refs[2][:4]
    assert done == [0]


# ---------------------------------------------------------------------------
# Paged primitives
# ---------------------------------------------------------------------------

def test_scatter_gather_roundtrip():
    rng = np.random.Generator(np.random.Philox(key=3))
    pool = jnp.zeros((8, 4, 2, 5), jnp.float32)     # 8 pages of 4 slots
    pt = jnp.asarray([[3, 1, 6, 0], [2, 5, 7, 0]], jnp.int32)
    pos = jnp.asarray([[0, 1, 5], [4, 6, 7]], jnp.int32)
    vals = jnp.asarray(rng.standard_normal((2, 3, 2, 5)), jnp.float32)
    pool = kv_cache.scatter_pages(pool, pt, pos, vals)
    out = kv_cache.gather_pages(pool, pt)            # [2, 16, 2, 5]
    for b in range(2):
        for i in range(3):
            np.testing.assert_array_equal(out[b, int(pos[b, i])],
                                          vals[b, i])


def test_scatter_masked_writes_hit_sink_page_only():
    pool = jnp.zeros((4, 2, 1, 1), jnp.float32)
    pt = jnp.asarray([[2, 3]], jnp.int32)
    pos = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
    vals = jnp.ones((1, 4, 1, 1), jnp.float32)
    valid = jnp.asarray([[True, True, False, False]])
    new = kv_cache.scatter_pages(pool, pt, pos, vals, valid)
    assert float(new[2].sum()) == 2.0               # real writes
    assert float(new[3].sum()) == 0.0               # masked out
    assert float(new[1].sum()) == 0.0
    # positions past the table also land in the sink, never clamp into
    # the last real page
    far = kv_cache.scatter_pages(pool, pt, jnp.asarray([[99]]),
                                 jnp.ones((1, 1, 1, 1)))
    assert float(far[3].sum()) == 0.0


def test_serving_support_assigns_cache_kinds():
    """One central capability query: every mixer mix maps to a cache
    kind, refusals come with a stable reason."""
    assert serving_support(_cfg("llama3-8b")) == ("paged", "")
    assert serving_support(
        get_config("deepseek-v2-lite-16b").reduced()) == ("paged", "")
    assert serving_support(
        get_config("xlstm-1.3b").reduced()) == ("constant", "")
    assert serving_support(
        get_config("jamba-1.5-large-398b").reduced()) == ("composite", "")
    for name in ("whisper-medium", "qwen2-vl-2b"):
        kind, why = serving_support(get_config(name).reduced())
        assert kind is None and why


# ---------------------------------------------------------------------------
# KV accounting (cache_bytes exercised against real buffers)
# ---------------------------------------------------------------------------

def test_cache_bytes_matches_buffer_sizes():
    cfg = _cfg("llama3-8b")
    dense = lm.init_cache(cfg, batch=2, max_len=32, dtype=jnp.float32)
    leaves = jax.tree_util.tree_leaves(dense["layers"])
    assert kv_cache.cache_bytes(dense["layers"]) == \
        sum(x.size * x.dtype.itemsize for x in leaves)
    pools = lm.init_paged_cache(cfg, num_pages=10, page_size=4,
                                dtype=jnp.float32)
    leaves = jax.tree_util.tree_leaves(pools)
    assert kv_cache.cache_bytes(pools) == \
        sum(x.size * x.dtype.itemsize for x in leaves) > 0


def test_engine_surfaces_kv_metrics(setup):
    cfg, params, prompts, _ = setup
    eng = _engine(cfg, params)
    eng.submit(prompts[2], max_new_tokens=3)
    info = eng.step()
    leaves = jax.tree_util.tree_leaves(eng.kv.pools)
    assert info["cache_bytes"] == \
        sum(x.size * x.dtype.itemsize for x in leaves)
    assert info["kv_used_bytes"] > 0                # pages reserved
    eng.run_until_idle()
    assert eng.metrics["kv_used_bytes"] == 0        # all pages returned
    assert eng.stats()["peak_kv_used_bytes"] > 0


# ---------------------------------------------------------------------------
# Scheduler / admission
# ---------------------------------------------------------------------------

def test_admission_by_page_budget(setup):
    """The conservative admission-blocking baseline (preempt="never"):
    a request's whole budget is reserved up front, so a too-small pool
    queues instead of preempting."""
    cfg, params, prompts, refs = setup
    # pool so small only one request fits at a time: budget 13+6=19 tokens
    # -> 5 pages; pool has 6 real pages
    eng = _engine(cfg, params, num_pages=7, max_slots=3, preempt="never")
    r0 = eng.submit(prompts[0], max_new_tokens=MAX_NEW[0], arrival_s=0.0)
    r2 = eng.submit(prompts[2], max_new_tokens=MAX_NEW[2], arrival_s=0.0)
    eng.step()
    # second request must still be queued — not enough free pages
    assert r0.state != RequestState.QUEUED
    assert r2.state == RequestState.QUEUED
    eng.run_until_idle()
    assert [r0.output, r2.output] == [refs[0], refs[2]]
    assert eng.kv.free_pages == eng.kv.num_pages - 1
    assert eng.kv.peak_used_pages <= 6


def test_oversized_request_rejected(setup):
    cfg, params, prompts, _ = setup
    eng = _engine(cfg, params, max_seq_len=16)
    with pytest.raises(ValueError, match="exceeds engine capacity"):
        eng.submit(np.arange(20, dtype=np.int32) % cfg.vocab_size,
                   max_new_tokens=8)


# ---------------------------------------------------------------------------
# Trace replay pacing
# ---------------------------------------------------------------------------

def test_replay_idles_in_few_sleeps(setup, monkeypatch):
    """An idle gap before the next scheduled arrival is covered by a
    handful of capped sleeps, not a 1 kHz busy-poll (regression: the
    old 1 ms fixed sleep burned a core for the whole gap)."""
    import time as _time

    from repro.serve import TraceEntry, replay

    cfg, params, prompts, refs = setup
    eng = _engine(cfg, params)
    trace = [TraceEntry(0.0, prompts[0], MAX_NEW[0]),
             TraceEntry(0.4, prompts[2], MAX_NEW[2])]
    calls = []
    real_sleep = _time.sleep

    def counting_sleep(s):
        calls.append(s)
        real_sleep(s)

    monkeypatch.setattr(_time, "sleep", counting_sleep)
    replay(eng, trace)
    monkeypatch.undo()
    outs = [r.output for r in sorted(eng.done, key=lambda r: r.rid)]
    assert outs == [refs[0], refs[2]]
    # the ~0.4s gap needs ~8 sleeps at the 0.05s cap; the old busy-poll
    # took ~400. Generous headroom for engine-work jitter:
    assert len(calls) <= 40, f"{len(calls)} sleeps — busy-polling again?"
    assert all(s <= 0.05 + 1e-9 for s in calls)


# ---------------------------------------------------------------------------
# HW spec resolution (--hw flag / auto-detect)
# ---------------------------------------------------------------------------

def test_resolve_hw():
    assert resolve_hw("tpu-v5e") is TPU_V5E
    assert resolve_hw("cpu-host") is CPU_HOST
    # tests force the CPU backend (conftest), so auto must detect it
    assert resolve_hw("auto") is CPU_HOST
    with pytest.raises(KeyError):
        resolve_hw("abacus-9000")
