"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")           # optional dep: skip, don't abort
from hypothesis import given, settings, strategies as st

from repro.core.granularity import GranularitySearcher
from repro.core.memory_model import MoEMemory
from repro.core.perf_model import MoEWorkload, cost
from repro.core.types import TPU_V5E, Strategy
from repro.distributed.compression import compress_with_feedback
from repro.moe import dispatch as D

SETTINGS = dict(max_examples=25, deadline=None)


@given(t=st.integers(4, 64), e=st.integers(2, 16), k=st.integers(1, 3),
       cf=st.floats(0.5, 2.0), seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_dispatch_combine_roundtrip(t, e, k, cf, seed):
    """Tokens under capacity are preserved; combine output is a convex
    combination of expert outputs weighted by gate probs."""
    k = min(k, e)
    rng = np.random.default_rng(seed)
    cap = max(1, int(t * k * cf / e))
    tokens = jnp.asarray(rng.standard_normal((t, 8)), jnp.float32)
    eidx = jnp.asarray(rng.integers(0, e, (t, k)), jnp.int32)
    probs = jnp.asarray(rng.random((t, k)), jnp.float32)
    probs = probs / probs.sum(-1, keepdims=True)

    dest, valid = D.dispatch_plan(eidx, e, cap)
    buf = D.dispatch(tokens, dest, e, cap)
    # identity experts -> combine returns sum of surviving-route weights
    out = D.combine(buf, dest, probs, t)
    w = (probs.reshape(-1) * valid).reshape(t, k).sum(-1)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(tokens * w[:, None]),
                               rtol=1e-5, atol=1e-5)
    # every expert holds at most `cap` tokens
    counts = np.bincount(np.asarray(dest)[np.asarray(valid)] // cap,
                         minlength=e)
    assert (counts <= cap).all()


@given(seed=st.integers(0, 50), steps=st.integers(1, 5))
@settings(**SETTINGS)
def test_compression_error_feedback_is_lossless_in_the_limit(seed, steps):
    """int8+error-feedback: accumulated applied updates converge to the
    true gradient sum (error never grows unboundedly)."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)}
    err = None
    applied = jnp.zeros_like(g["w"])
    for _ in range(steps):
        out, err = compress_with_feedback(g, err)
        applied = applied + out["w"]
    true = g["w"] * steps
    resid = np.abs(np.asarray(applied + err["w"] - true)).max()
    assert resid < 1e-4          # applied + carried error == exact sum


@given(b=st.integers(64, 1 << 16), m=st.sampled_from([256, 768, 4096]),
       h=st.sampled_from([1024, 3072, 16384]), n=st.sampled_from([2, 4, 8]))
@settings(**SETTINGS)
def test_memory_saving_ratio_bounds(b, m, h, n):
    mm = MoEMemory(b=b, m=m, h=h, e=64, n=n)
    assert 0.0 <= mm.phi < 1.0
    assert mm.delta_act <= mm.m_act
    # reused activation footprint ~ m/n scaling: 2m/n for T_DI/T_DO + m/n
    reused = mm.m_act - mm.delta_act
    expected = (2 * b * m                      # T_I, T_O untouched
                + 2 * b * m * 2 / n            # T_DI, T_DO double buffer
                + b * h / n)                   # T_M single buffer
    assert reused == expected


@given(b=st.integers(256, 1 << 15))
@settings(**SETTINGS)
def test_eq10_cost_monotone_in_batch(b):
    w1 = MoEWorkload(b=b, m=768, h=3072, k=1, ep=16)
    w2 = MoEWorkload(b=2 * b, m=768, h=3072, k=1, ep=16)
    for s in Strategy:
        assert cost(s, w1, TPU_V5E) <= cost(s, w2, TPU_V5E)


@given(data=st.lists(st.integers(64, 1 << 15), min_size=1, max_size=12))
@settings(**SETTINGS)
def test_granularity_ranges_always_disjoint_sorted(data):
    s = GranularitySearcher(lambda b, n: abs(n - max(1, b // 2048)),
                            candidates=(1, 2, 4, 8, 16))
    for b in data:
        s.best_n(b)
    rs = s.ranges
    for (l1, h1, _), (l2, h2, _) in zip(rs, rs[1:]):
        assert h1 < l2
    for lo, hi, _ in rs:
        assert lo <= hi


@given(seed=st.integers(0, 30), b=st.integers(1, 8), s=st.integers(4, 32))
@settings(max_examples=10, deadline=None)
def test_cross_entropy_matches_naive(seed, b, s):
    from repro.models.lm import cross_entropy
    rng = np.random.default_rng(seed)
    v = 17
    logits = jnp.asarray(rng.standard_normal((b, s, v)), jnp.float32)
    labels = jnp.asarray(rng.integers(-1, v, (b, s)), jnp.int32)
    got = cross_entropy(logits, labels)
    lp = jax.nn.log_softmax(logits, -1)
    valid = labels >= 0
    lab = jnp.where(valid, labels, 0)
    nll = -jnp.take_along_axis(lp, lab[..., None], -1)[..., 0]
    want = jnp.where(valid, nll, 0).sum() / max(1, int(valid.sum()))
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5,
                               atol=1e-6)
