"""Online adaptive runtime controller (§III-C + §III-E wired into train):
re-jit economy, persistent-searcher reuse, capacity-masked degradation."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import TPU_V5E, Resolver
from repro.data import VaryingSyntheticTokens
from repro.runtime import (AdaptiveController, AdaptiveOptions,
                           TrainOptions, init_state, train)


@pytest.fixture(scope="module")
def adaptive_cfg():
    base = get_config("moe-gpt3-s").reduced()
    return dataclasses.replace(
        base, num_layers=2, compute_dtype="float32",
        moe=dataclasses.replace(base.moe, num_partitions=0,
                                memory_reuse_strategy="adaptive"))


def _fake_clock(b, n, strategy):
    """Deterministic measure with optimum n growing in b (fake clock)."""
    ideal = max(1, b // 256)
    return abs(n - ideal) + 0.01 * n


def test_rejit_only_on_new_config(adaptive_cfg):
    """Across a repeating trace, the step cache compiles at most once per
    distinct (n, strategy, batch_shape) and the searcher's measure calls
    stay sublinear in steps (cache hits on revisited batch sizes)."""
    opts = TrainOptions()
    aopts = AdaptiveOptions(measure_fn=_fake_clock, candidates=(1, 2, 4, 8))
    ctl = AdaptiveController(adaptive_cfg, opts, aopts=aopts, jit=False)
    state = init_state(adaptive_cfg, jax.random.PRNGKey(0), opts)
    trace = [4, 8, 4, 16, 8, 4, 16, 8, 4, 4, 8, 16]
    ds = VaryingSyntheticTokens(adaptive_cfg, trace, seq=32, seed=0)
    keys = set()
    for step in range(len(trace)):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
        _, info = ctl.step_fn(state, batch, step)
        assert info["n"] >= 1 and info["strategy"] != "adaptive"
        keys.add((info["n"], info["strategy"], ctl._shape_key(batch)))
    assert ctl.rejit_count == len(keys)
    # sublinear search: one real search per distinct token count, despite
    # a retune at every shape change
    assert ctl.resolver.search_calls <= len(set(trace))
    assert ctl.retune_count > len(set(trace))


def test_retune_every_remeasures_without_rejit(adaptive_cfg):
    """Timer-triggered retunes re-MEASURE (stale-timing refresh, not an
    inert cache hit) but never re-jit while the resolved
    (n, strategy, shape) is unchanged."""
    opts = TrainOptions()
    aopts = AdaptiveOptions(measure_fn=_fake_clock, candidates=(1, 2, 4),
                            retune_every=2)
    ctl = AdaptiveController(adaptive_cfg, opts, aopts=aopts, jit=False)
    state = init_state(adaptive_cfg, jax.random.PRNGKey(0), opts)
    ds = VaryingSyntheticTokens(adaptive_cfg, [8], seq=32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
    for step in range(8):
        _, info = ctl.step_fn(state, batch, step)
    assert ctl.retune_count == 4                 # steps 0, 2, 4, 6
    assert ctl.resolver.search_calls == 4        # each one re-measured
    assert ctl.rejit_count == 1                  # same config -> cached


def test_retune_timer_fires_under_shape_churn(adaptive_cfg):
    """The drift timer runs on its own clock: a cyclic-shape trace
    (every step retunes for shape) must not starve re-measurement."""
    opts = TrainOptions()
    aopts = AdaptiveOptions(measure_fn=_fake_clock, candidates=(1, 2, 4),
                            retune_every=2)
    ctl = AdaptiveController(adaptive_cfg, opts, aopts=aopts, jit=False)
    state = init_state(adaptive_cfg, jax.random.PRNGKey(0), opts)
    trace = [4, 8] * 4
    ds = VaryingSyntheticTokens(adaptive_cfg, trace, seq=32, seed=0)
    for step in range(len(trace)):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
        ctl.step_fn(state, batch, step)
    # refresh at steps 2/4/6 resets the searcher, so both sizes
    # re-measure each cycle: 2 initial + 3 resets * 2 sizes = 8.
    # Without the independent timer this would stay at 2 (cache hits).
    assert ctl.resolver.search_calls == 8
    assert ctl.rejit_count == 2                  # configs never changed


def test_controller_rejects_unpipelined_config(adaptive_cfg):
    import dataclasses as dc
    cfg = dc.replace(adaptive_cfg, moe=dc.replace(adaptive_cfg.moe,
                                                  pipeline=False))
    with pytest.raises(ValueError):
        AdaptiveController(cfg, TrainOptions(), jit=False)


def test_resolver_is_incremental(adaptive_cfg):
    r = Resolver(adaptive_cfg, ep_size=8, hw=TPU_V5E,
                 measure_fn=_fake_clock)
    cfg1 = r.resolve(4096)
    calls = r.search_calls
    cfg2 = r.resolve(4096)                       # hash-table hit
    assert (cfg1.moe.num_partitions, cfg1.moe.memory_reuse_strategy) == \
        (cfg2.moe.num_partitions, cfg2.moe.memory_reuse_strategy)
    assert r.search_calls == calls


def test_resolve_masks_offload_strategies(adaptive_cfg):
    """allow_offload=False degrades the §III-E candidate set to the
    device-only strategies (S1-S3 need a host link; S4 survives)."""
    r = Resolver(adaptive_cfg, ep_size=8, hw=TPU_V5E,
                 measure_fn=_fake_clock, allow_offload=False)
    for tokens in (512, 4096, 65536):
        cfg = r.resolve(tokens)
        assert cfg.moe.memory_reuse_strategy == "s4"
        assert cfg.moe.num_partitions >= 1


def test_train_adaptive_end_to_end(adaptive_cfg):
    """Acceptance: num_partitions=0 + strategy='adaptive' -> train()
    resolves online through one persistent searcher, re-jits at most once
    per distinct (n, strategy, batch_shape), and emits the controller
    metrics."""
    opts = TrainOptions(lr=1e-3, warmup=2, total_steps=6)
    aopts = AdaptiveOptions(measure_fn=_fake_clock, candidates=(1, 2, 4))
    ctl = AdaptiveController(adaptive_cfg, opts, aopts=aopts)
    trace = (4, 8, 4, 8, 4, 8)
    ds = VaryingSyntheticTokens(adaptive_cfg, trace, seq=16, seed=0)
    state, hist = train(adaptive_cfg, steps=6, batch_source=ds, opts=opts,
                        adaptive=ctl)
    assert int(state["step"]) == 6
    assert ctl.rejit_count == 2                  # two shapes, one (n, strat)
    assert ctl.resolver.search_calls == 2        # one per distinct size
    for h in hist:
        assert h["n"] >= 1 and h["strategy"] != "adaptive"
        assert jnp.isfinite(h["loss"])
    assert "retune_time_s" in hist[0]
