"""Per-arch smoke tests: one forward/train step on a REDUCED config,
asserting output shapes and finite values (deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models.api import get_model


def _batch(cfg, key, b=2, s=32):
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(k2, (b, s), 0, cfg.vocab_size)}
    if cfg.frontend == "audio_stub":
        e = cfg.encoder
        batch["frames"] = 0.02 * jax.random.normal(
            k3, (b, e.context_len, e.d_model))
    elif cfg.frontend == "vision_stub":
        batch["embeds"] = 0.02 * jax.random.normal(k3, (b, 8, cfg.d_model))
        if cfg.attn.mrope:
            pos = jnp.broadcast_to(jnp.arange(s + 8)[None], (b, s + 8))
            batch["positions3"] = jnp.stack([pos, pos, pos])
        batch["labels"] = jnp.concatenate(
            [jnp.full((b, 8), -1, jnp.int32), batch["labels"]], axis=1)
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_smoke(name):
    cfg = get_config(name).reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(cfg, key)
    batch = _batch(cfg, key)

    logits, aux, _ = model.forward(params, batch, cfg, mode="train")
    assert logits.shape[-1] == cfg.vocab_size
    assert logits.shape[0] == 2
    assert jnp.isfinite(logits).all()

    loss, metrics = model.loss_fn(params, batch, cfg)
    assert jnp.isfinite(loss)
    grads = jax.grad(lambda p: model.loss_fn(p, batch, cfg)[0])(params)
    gsq = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(g.astype(jnp.float32) ** 2), grads, 0.0)
    assert jnp.isfinite(gsq) and gsq > 0


@pytest.mark.parametrize("name", ["llama3-8b", "gemma3-12b",
                                  "deepseek-v2-lite-16b",
                                  "jamba-1.5-large-398b", "xlstm-1.3b",
                                  "h2o-danube-1.8b", "whisper-medium"])
def test_decode_smoke(name):
    cfg = get_config(name).reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(cfg, key)
    b = 2
    if name == "whisper-medium":
        batch = _batch(cfg, key, b=b, s=8)
        batch.pop("labels")
        _, cache = model.prefill(params, batch, cfg, max_len=64,
                                 dtype=jnp.float32)
    else:
        cache = model.init_cache(cfg, b, max_len=64, dtype=jnp.float32)
    for step in range(3):
        tok = jax.random.randint(jax.random.PRNGKey(step), (b, 1), 0,
                                 cfg.vocab_size)
        logits, cache = model.decode_step(params, cache, tok, cfg)
        assert logits.shape == (b, cfg.vocab_size)
        assert jnp.isfinite(logits).all()
