"""Training runtime: loss goes down, fault tolerance works."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.ckpt import Checkpointer
from repro.configs import get_config
from repro.data import SyntheticTokens
from repro.runtime import TrainOptions, init_state, make_train_step, train


@pytest.fixture(scope="module")
def tiny_cfg():
    base = get_config("moe-gpt3-s").reduced()
    return dataclasses.replace(
        base, num_layers=2, compute_dtype="float32",
        moe=dataclasses.replace(base.moe, num_partitions=2,
                                memory_reuse_strategy="s4"))


def test_loss_decreases(tiny_cfg):
    ds = SyntheticTokens(tiny_cfg, batch=8, seq=32, seed=0)
    opts = TrainOptions(lr=3e-3, warmup=5, total_steps=60)
    state, hist = train(tiny_cfg, steps=60, batch_source=ds, opts=opts)
    first = sum(h["loss"] for h in hist[:5]) / 5
    last = sum(h["loss"] for h in hist[-5:]) / 5
    assert last < first - 0.2, (first, last)
    assert int(state["step"]) == 60


def test_checkpoint_restart_resumes_exactly(tiny_cfg, tmp_path):
    ds = SyntheticTokens(tiny_cfg, batch=8, seq=32, seed=0)
    opts = TrainOptions(lr=1e-3, warmup=5, total_steps=30)
    ck = Checkpointer(str(tmp_path), keep=3)
    # run 20 steps, checkpoint every 10
    state, _ = train(tiny_cfg, steps=20, batch_source=ds, opts=opts,
                     checkpointer=ck, ckpt_every=10)
    ck.wait()
    assert 20 in ck.list_steps()
    # "crash": new loop restores from latest and continues to 25
    class _Ck(Checkpointer):
        def restore_latest(self, abstract=None, like=None, shardings=None):
            out = super().restore_latest(like=_like(), shardings=None)
            return out
    def _like():
        from repro.runtime.train_loop import init_state
        return init_state(tiny_cfg, jax.random.PRNGKey(0), opts)
    ck2 = _Ck(str(tmp_path), keep=3)
    state2, hist2 = train(tiny_cfg, steps=25, batch_source=ds, opts=opts,
                          checkpointer=ck2, ckpt_every=100)
    assert hist2[0]["step"] == 20           # resumed, not restarted
    assert int(state2["step"]) == 25


def test_grad_compression_trains(tiny_cfg):
    ds = SyntheticTokens(tiny_cfg, batch=8, seq=32, seed=0)
    opts = TrainOptions(lr=3e-3, warmup=5, total_steps=40,
                        compress_grads=True)
    state, hist = train(tiny_cfg, steps=40, batch_source=ds, opts=opts)
    assert "grad_err" in state
    first = sum(h["loss"] for h in hist[:5]) / 5
    last = sum(h["loss"] for h in hist[-5:]) / 5
    assert last < first


def test_grad_accum_matches_full_batch(tiny_cfg):
    """2 microbatches of 4 == 1 batch of 8 (same grads, fp32)."""
    ds = SyntheticTokens(tiny_cfg, batch=8, seq=32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
    s1 = init_state(tiny_cfg, jax.random.PRNGKey(0), TrainOptions())
    s2 = init_state(tiny_cfg, jax.random.PRNGKey(0), TrainOptions())
    step1 = make_train_step(tiny_cfg, TrainOptions(lr=1e-3))
    step2 = make_train_step(tiny_cfg, TrainOptions(lr=1e-3, grad_accum=2))
    o1, m1 = step1(s1, batch)
    o2, m2 = step2(s2, batch)
    assert m1["loss"] == pytest.approx(float(m2["loss"]), rel=2e-2)
    w1 = jax.tree_util.tree_leaves(o1["params"])[0]
    w2 = jax.tree_util.tree_leaves(o2["params"])[0]
    assert jnp.allclose(w1, w2, atol=1e-4)
