"""Paged-attention decode kernel exactness tier.

The fused Pallas page-walking kernel (``repro.kernels.paged_attention``,
run in interpret mode on CPU), the pure-lax ``ref.py`` oracle and the
legacy ``gather_pages`` + ``decode_attention`` path must agree to
``atol=0`` — bit-identical outputs — on random page tables, ragged
lens, garbage-filled sink pages and grown-ahead slots (the PR 3 gotcha:
a slot holding more pages than ``pages_for(lens)`` after on-demand
decode growth). Token-exact serving A/B (``EngineOptions.attn_kernel``)
reduces to exactly this invariant.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import kv_cache as KV
from repro.models.layers.attention import decode_attention
from repro.kernels.paged_attention import (
    paged_decode_attention, paged_decode_attention_ref,
    paged_mla_decode, paged_mla_decode_ref)
from repro.kernels.paged_attention.ref import NEG_INF as REF_NEG_INF
from repro.models.layers.attention import NEG_INF as ATTN_NEG_INF

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                  # optional dep: deterministic tests
    HAVE_HYPOTHESIS = False          # still run without it

PS = 4          # page size
NP = 5          # page-table width (pages per slot)
GARBAGE = 3.0e4  # sink-page fill; finite but loud if it ever leaks


def _bits_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return (a.shape == b.shape and a.dtype == b.dtype
            and a.tobytes() == b.tobytes())


def _paged_setup(rng, lens, extra_pages, *, payload, dtype):
    """Build pools + page tables the way the serving allocator does:
    page 0 is the sink (filled with garbage — masked writes land there),
    each slot owns ``pages_for(len) + extra`` distinct pages, and
    unallocated page-table entries point at the sink."""
    b = len(lens)
    pools = []
    num_pages = 1 + sum(-(-l // PS) + e for l, e in zip(lens, extra_pages))
    for shape in payload:
        pool = rng.standard_normal((num_pages, PS) + shape)
        pool[0] = GARBAGE                       # sink page
        pools.append(jnp.asarray(pool, dtype))
    pt = np.zeros((b, NP), np.int32)            # sink-filled rows
    nxt = 1
    for i, (l, e) in enumerate(zip(lens, extra_pages)):
        n = -(-l // PS) + e
        assert n <= NP
        pt[i, :n] = np.arange(nxt, nxt + n)
        nxt += n
    return pools, jnp.asarray(pt), jnp.asarray(lens, jnp.int32)


def _three_way_plain(rng, lens, extra_pages, *, kv_heads=2, group=3,
                     d=8, window=0, dtype=jnp.float32):
    (k_pool, v_pool), pt, ln = _paged_setup(
        rng, lens, extra_pages, payload=[(kv_heads, d)] * 2, dtype=dtype)
    b = len(lens)
    q = jnp.asarray(rng.standard_normal((b, 1, kv_heads * group, d)),
                    dtype)
    legacy = decode_attention(q, KV.gather_pages(k_pool, pt),
                              KV.gather_pages(v_pool, pt), ln,
                              window=window, ring=False)
    ref = paged_decode_attention_ref(
        q.reshape(b, kv_heads, group, d), k_pool, v_pool, pt, ln,
        window=window).reshape(b, 1, kv_heads * group, d)
    kernel = paged_decode_attention(q, k_pool, v_pool, pt, ln,
                                    window=window)
    assert _bits_equal(legacy, ref), "ref diverged from gather path"
    assert _bits_equal(legacy, kernel), "kernel diverged from gather path"
    assert np.isfinite(np.asarray(kernel, np.float32)).all()


def _three_way_mla(rng, lens, extra_pages, *, h=3, r=8, e=4,
                   dtype=jnp.float32):
    (ckv_pool, kr_pool), pt, ln = _paged_setup(
        rng, lens, extra_pages, payload=[(), ()], dtype=dtype)
    # latent pools are [P, ps, R] / [P, ps, E]
    ckv_pool = ckv_pool[..., None] * jnp.asarray(
        rng.standard_normal((r,)), dtype)
    kr_pool = kr_pool[..., None] * jnp.asarray(
        rng.standard_normal((e,)), dtype)
    b = len(lens)
    q_abs = jnp.asarray(rng.standard_normal((b, 1, h, r)), dtype)
    q_rope = jnp.asarray(rng.standard_normal((b, 1, h, e)), dtype)
    scale = (r + e) ** -0.5
    # legacy gather math, verbatim from attention._apply_mla_paged
    dt = q_abs.dtype
    ckv_all = KV.gather_pages(ckv_pool, pt)
    kr_all = KV.gather_pages(kr_pool, pt)
    s_ = (jnp.einsum("bshr,btr->bhst", q_abs, ckv_all.astype(dt),
                     preferred_element_type=jnp.float32)
          + jnp.einsum("bshe,bte->bhst", q_rope, kr_all.astype(dt),
                       preferred_element_type=jnp.float32)) * scale
    t = ckv_all.shape[1]
    mask = jnp.arange(t)[None, None, :] <= ln[:, None, None, None][:, 0]
    legacy = jnp.einsum("bhst,btr->bshr",
                        jax.nn.softmax(jnp.where(mask[:, None], s_,
                                                 ATTN_NEG_INF), axis=-1),
                        ckv_all.astype(jnp.float32))
    ref = paged_mla_decode_ref(q_abs[:, 0], q_rope[:, 0], ckv_pool,
                               kr_pool, pt, ln, scale=scale)[:, None]
    kernel = paged_mla_decode(q_abs, q_rope, ckv_pool, kr_pool, pt, ln,
                              scale=scale)
    assert _bits_equal(legacy, ref), "MLA ref diverged from gather path"
    assert _bits_equal(legacy, kernel), \
        "MLA kernel diverged from gather path"


# ---------------------------------------------------------------------------
# Deterministic exactness sweeps (always run; no hypothesis needed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 6])
def test_three_way_exactness_plain(dtype, window):
    """kernel == ref == gather bitwise: ragged lens (1 token up to the
    full table), sink-filled unallocated entries, garbage sink page."""
    rng = np.random.default_rng(0)
    _three_way_plain(rng, lens=[1, NP * PS, 7, 13],
                     extra_pages=[0, 0, 0, 0], window=window, dtype=dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_three_way_exactness_mla(dtype):
    """Latent (deepseek MLA) kernel == ref == absorbed gather einsums,
    bitwise — including lens=0 (sole visible key is this step's)."""
    rng = np.random.default_rng(1)
    _three_way_mla(rng, lens=[0, NP * PS - 1, 6, 12],
                   extra_pages=[0, 0, 0, 0], dtype=dtype)


def test_grown_ahead_slots_pr3_gotcha():
    """The PR 3 gotcha shape: a slot holding MORE pages than
    ``pages_for(lens)`` (decode growth allocates the page before the
    length catches up). The extra pages hold stale pool garbage that
    must never reach the output."""
    rng = np.random.default_rng(2)
    _three_way_plain(rng, lens=[3, 6, 9], extra_pages=[2, 1, 2])
    _three_way_mla(rng, lens=[3, 6, 9], extra_pages=[2, 1, 2])


def test_ref_neg_inf_matches_attention():
    """The triad's mask constant must track the layer's NEG_INF — a
    drift would silently break bit-exactness for fully-masked rows."""
    assert REF_NEG_INF == ATTN_NEG_INF


def test_kernel_rejects_multi_query():
    rng = np.random.default_rng(3)
    (k_pool, v_pool), pt, ln = _paged_setup(
        rng, [4], [0], payload=[(2, 8)] * 2, dtype=jnp.float32)
    q = jnp.zeros((1, 2, 4, 8), jnp.float32)    # S=2: prefill shape
    with pytest.raises(AssertionError):
        paged_decode_attention(q, k_pool, v_pool, pt, ln)


# ---------------------------------------------------------------------------
# Engine-level A/B: attn_kernel="pallas" tokens == attn_kernel="gather"
# ---------------------------------------------------------------------------

def test_engine_attn_kernel_token_exact():
    from repro.configs import get_config
    from repro.models import lm
    from repro.serve import Engine, EngineOptions

    cfg = get_config("moe-gpt3-s").reduced()
    cfg = dataclasses.replace(
        cfg, compute_dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.Generator(np.random.Philox(key=7))
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in (13, 7)]
    outs, stats = {}, {}
    for kern in ("gather", "pallas"):
        eng = Engine(cfg, params, options=EngineOptions(
            page_size=4, max_slots=2, max_seq_len=64, chunk=16,
            min_bucket=8, attn_kernel=kern))
        for p in prompts:
            eng.submit(p, max_new_tokens=5, arrival_s=0.0)
        eng.run_until_idle()
        outs[kern] = [r.output
                      for r in sorted(eng.done, key=lambda r: r.rid)]
        stats[kern] = eng.stats()
    assert outs["pallas"] == outs["gather"]
    assert stats["pallas"]["attn_kernel"] == "pallas"
    assert stats["gather"]["attn_kernel"] == "gather"
    # the kernel is trace-static: one compiled decode program per engine
    assert stats["pallas"]["decode_traces"] \
        == stats["gather"]["decode_traces"] == 1


def test_engine_attn_kernel_auto_resolution():
    from repro.serve.engine import ATTN_KERNELS
    assert ATTN_KERNELS == ("auto", "pallas", "gather")
    # on CPU, auto must resolve to the gather baseline (interpret-mode
    # pallas is an exactness oracle, not a fast path)
    assert jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------
# Hypothesis property: exactness over random tables / lens / dtypes
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 10_000),
           lens=st.lists(st.integers(1, NP * PS), min_size=2, max_size=4),
           window=st.sampled_from([0, 3, 7]),
           bf16=st.booleans())
    @settings(max_examples=25, deadline=None)
    def test_paged_attention_property(seed, lens, window, bf16):
        """Any ragged batch, any window, any dtype: the three paths are
        bit-identical (grown-ahead pages included when they fit)."""
        rng = np.random.default_rng(seed)
        extra = [min(int(rng.integers(0, 3)), NP - (-(-l // PS)))
                 for l in lens]
        _three_way_plain(rng, lens, extra, window=window,
                         dtype=jnp.bfloat16 if bf16 else jnp.float32)

    @given(seed=st.integers(0, 10_000),
           lens=st.lists(st.integers(0, NP * PS - 1),
                         min_size=2, max_size=4))
    @settings(max_examples=15, deadline=None)
    def test_paged_mla_property(seed, lens):
        rng = np.random.default_rng(seed)
        extra = [min(int(rng.integers(0, 3)), NP - (-(-(l + 1) // PS)))
                 for l in lens]
        _three_way_mla(rng, lens, extra)
