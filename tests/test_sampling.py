"""Sampling suite (repro.serve.sampling): masked top-k/top-p kernel
semantics, per-seed determinism across batch compositions (and through
preemption), stop sequences, and the one-compile invariant."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve import Engine, EngineOptions, SamplingParams, sample_tokens
from repro.serve.sampling import stop_hit


def _sample(logits, *, temp=1.0, top_k=0, top_p=1.0, seed=0, pos=0):
    n = logits.shape[0]
    arr = lambda v, dt: jnp.full((n,), v, dt)
    return np.asarray(sample_tokens(
        jnp.asarray(logits), arr(temp, jnp.float32), arr(top_k, jnp.int32),
        arr(top_p, jnp.float32), arr(seed, jnp.int32),
        arr(pos, jnp.int32)))


# ---------------------------------------------------------------------------
# Kernel semantics
# ---------------------------------------------------------------------------

def test_greedy_is_exact_argmax():
    rng = np.random.Generator(np.random.Philox(key=1))
    lg = rng.standard_normal((5, 37)).astype(np.float32)
    assert (_sample(lg, temp=0.0) == lg.argmax(-1)).all()


def test_top_k_one_is_argmax_at_any_temperature():
    rng = np.random.Generator(np.random.Philox(key=2))
    lg = rng.standard_normal((4, 50)).astype(np.float32)
    assert (_sample(lg, temp=5.0, top_k=1) == lg.argmax(-1)).all()


def test_top_k_strict_under_ties():
    """Tied logits at the k-th rank: the keep set is decided by sort
    rank (stable argsort -> lowest vocab index wins), never by a value
    threshold that would admit every tied entry. Regression: top_k=1
    over exact ties used to sample among all of them."""
    lg = np.array([[1.0, 2.0, 2.0, 0.0]], np.float32)
    for p in range(32):
        assert _sample(lg, temp=1.0, top_k=1, pos=p)[0] == 1
    # k=2 over a 3-way tie keeps exactly the two lowest tied indices
    lg3 = np.array([[0.0, 5.0, 5.0, 5.0, -1.0]], np.float32)
    seen = {int(_sample(lg3, temp=2.0, top_k=2, pos=p)[0])
            for p in range(64)}
    assert seen == {1, 2}


def test_tiny_top_p_is_argmax():
    rng = np.random.Generator(np.random.Philox(key=3))
    lg = rng.standard_normal((4, 50)).astype(np.float32)
    assert (_sample(lg, temp=2.0, top_p=1e-6) == lg.argmax(-1)).all()


def test_top_k_restricts_support():
    rng = np.random.Generator(np.random.Philox(key=4))
    lg = rng.standard_normal((1, 64)).astype(np.float32)
    top5 = set(np.argsort(lg[0])[::-1][:5].tolist())
    seen = {int(_sample(lg, temp=3.0, top_k=5, pos=p)[0])
            for p in range(50)}
    assert seen <= top5 and len(seen) > 1


def test_top_p_restricts_support():
    # 3 dominant logits carry ~all the mass; nucleus 0.9 keeps only them
    lg = np.full((1, 16), -10.0, np.float32)
    lg[0, [3, 7, 11]] = [5.0, 5.2, 4.8]
    seen = {int(_sample(lg, temp=1.0, top_p=0.9, pos=p)[0])
            for p in range(40)}
    assert seen <= {3, 7, 11} and len(seen) > 1


def test_same_seed_same_position_same_token_rows_independent():
    rng = np.random.Generator(np.random.Philox(key=5))
    lg = rng.standard_normal((3, 40)).astype(np.float32)
    a = _sample(lg, temp=1.0, seed=9, pos=4)
    b = _sample(lg, temp=1.0, seed=9, pos=4)
    assert (a == b).all()
    # a row's sample is unchanged when its neighbours' logits change
    lg2 = lg.copy()
    lg2[0] = rng.standard_normal(40)
    c = _sample(lg2, temp=1.0, seed=9, pos=4)
    assert (c[1:] == a[1:]).all()


def test_stop_hit_matches_suffix_only():
    assert stop_hit([1, 2, 3], [(2, 3)]) == (2, 3)
    assert stop_hit([1, 2, 3], [(1, 2)]) is None
    assert stop_hit([3], [(3,), (1, 3)]) == (3,)
    assert stop_hit([], [(3,)]) is None


# ---------------------------------------------------------------------------
# Engine integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                              compute_dtype="float32")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.Generator(np.random.Philox(key=11))
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in (11, 19, 7)]
    return cfg, params, prompts


def _engine(cfg, params, **over):
    kw = dict(page_size=4, max_slots=3, max_seq_len=64, chunk=16,
              min_bucket=8)
    kw.update(over)
    return Engine(cfg, params, options=EngineOptions(**kw))


SP = SamplingParams(temperature=0.8, top_k=8, top_p=0.95, seed=7)


def test_sampling_deterministic_across_batch_compositions(setup):
    """The same request + seed emits identical tokens whether it runs
    alone, continuously batched with other requests, or preempted and
    resumed mid-stream — the key serving-determinism guarantee."""
    cfg, params, prompts = setup
    alone = _engine(cfg, params)
    r = alone.submit(prompts[0], max_new_tokens=8, sampling=SP)
    alone.run_until_idle()
    want = list(r.output)
    assert len(want) == 8

    batched = _engine(cfg, params)
    r2 = batched.submit(prompts[0], max_new_tokens=8, sampling=SP)
    batched.submit(prompts[1], max_new_tokens=6)        # greedy neighbour
    batched.submit(prompts[2], max_new_tokens=7,
                   sampling=SamplingParams(temperature=1.3, seed=99))
    batched.run_until_idle()
    assert r2.output == want

    stormy = _engine(cfg, params, num_pages=10, preempt="recompute")
    r3 = stormy.submit(prompts[0], max_new_tokens=8, sampling=SP)
    stormy.submit(prompts[1], max_new_tokens=6)
    stormy.submit(prompts[2], max_new_tokens=7,
                  sampling=SamplingParams(temperature=1.3, seed=99))
    stormy.run_until_idle()
    assert stormy.preempts["recompute"] > 0
    assert r3.output == want


def test_seed_changes_sampled_stream(setup):
    cfg, params, prompts = setup
    eng = _engine(cfg, params)
    a = eng.submit(prompts[0], max_new_tokens=8,
                   sampling=dataclasses.replace(SP, seed=1))
    b = eng.submit(prompts[0], max_new_tokens=8,
                   sampling=dataclasses.replace(SP, seed=2))
    eng.run_until_idle()
    assert a.output != b.output


def test_one_compile_across_sampling_settings(setup):
    """Changing sampling parameters must not re-jit: decode is one
    program, prefill one per bucket, regardless of settings."""
    cfg, params, prompts = setup
    eng = _engine(cfg, params)
    eng.warmup()
    compiles = eng.prefill_rejits
    for i, sp in enumerate([SamplingParams(),
                            SamplingParams(temperature=0.5, seed=3),
                            SamplingParams(temperature=1.0, top_k=4),
                            SamplingParams(temperature=1.0, top_p=0.5)]):
        eng.submit(prompts[i % 3], max_new_tokens=4, sampling=sp)
    eng.run_until_idle()
    assert eng.prefill_rejits == compiles


def test_stop_sequence_stops_and_streams(setup):
    cfg, params, prompts = setup
    ref_eng = _engine(cfg, params)
    r = ref_eng.submit(prompts[0], max_new_tokens=6)
    ref_eng.run_until_idle()
    ref = list(r.output)

    eng = _engine(cfg, params)
    streamed = []
    r2 = eng.submit(prompts[0], max_new_tokens=6, stop=[ref[1:3], [12345]],
                    on_token=lambda t, _r: streamed.append(t))
    eng.run_until_idle()
    assert r2.output == ref[:3]                 # stopped at the match
    assert r2.finish_reason == "stop"
    assert streamed == r2.output
    assert r2.token_times == sorted(r2.token_times)
    assert len(r2.itl_s) == len(r2.output) - 1
