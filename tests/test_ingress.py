"""HTTP/SSE ingress tier (repro.serve.ingress): end-to-end streaming
over real sockets bit-exact vs the in-process engine, client-disconnect
→ Engine.cancel propagation with allocator integrity, both load-shed
policies, request validation, and the ingress metric/span families."""
import concurrent.futures
import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.obs import PID_INGRESS, Recorder, Tracer
from repro.serve import (Engine, EngineOptions, IngressClient,
                         IngressOptions, IngressServer,
                         dense_greedy_reference as ref_decode)

PROMPT_LENS = (13, 29, 7)
MAX_NEW = (6, 8, 5)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                              compute_dtype="float32")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.Generator(np.random.Philox(key=7))
    prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
               for n in PROMPT_LENS]
    refs = [ref_decode(params, cfg, p, m)
            for p, m in zip(prompts, MAX_NEW)]
    return cfg, params, prompts, refs


@pytest.fixture(scope="module")
def eng(setup):
    cfg, params, _, _ = setup
    e = Engine(cfg, params, options=EngineOptions(
        page_size=4, max_slots=3, max_seq_len=64, chunk=16, min_bucket=8,
        obs=Recorder(tracer=Tracer())))
    e.warmup()
    return e


class _serve:
    """Start an IngressServer over the shared engine for one test."""

    def __init__(self, eng, **opts):
        self.srv = IngressServer(eng, options=IngressOptions(**opts))

    def __enter__(self):
        self.srv.start()
        return self.srv, IngressClient(self.srv.host, self.srv.port)

    def __exit__(self, *exc):
        self.srv.stop()


def _wait(pred, timeout=10.0):
    deadline = time.perf_counter() + timeout
    while time.perf_counter() < deadline:
        if pred():
            return True
        time.sleep(0.005)
    return False


def test_sse_stream_token_exact(setup, eng):
    """Concurrent SSE streams emit exactly the tokens of the in-process
    Engine.step() loop (itself pinned to the dense reference)."""
    cfg, params, prompts, refs = setup
    with _serve(eng) as (srv, cli):
        assert cli.healthz()
        with concurrent.futures.ThreadPoolExecutor(3) as ex:
            futs = [ex.submit(cli.generate, p, max_new_tokens=m)
                    for p, m in zip(prompts, MAX_NEW)]
            results = [f.result(timeout=60) for f in futs]
    for res, ref in zip(results, refs):
        assert res.status == 200 and not res.degraded
        assert res.tokens == ref                 # bit-exact end to end
        assert res.finish_reason == "length"
        assert res.ttft_s > 0 and res.latency_s >= res.ttft_s
    eng.kv.check_integrity()
    # ingress admission counter saw the three accepted streams
    snap = eng.obs.registry.snapshot()
    assert snap["repro_ingress_requests_total"]['outcome="accepted"'] >= 3


def test_sse_eos_and_sampling_fields(setup, eng):
    cfg, params, prompts, refs = setup
    with _serve(eng) as (_, cli):
        res = cli.generate(prompts[0], max_new_tokens=MAX_NEW[0],
                           eos_id=refs[0][1])
        assert res.tokens == refs[0][:2] and res.finish_reason == "eos"
        # sampled stream: valid tokens, still per-step SSE
        res = cli.generate(prompts[2], max_new_tokens=4,
                           temperature=0.8, top_k=8, seed=3)
        assert res.status == 200 and len(res.tokens) == 4
        assert all(0 <= t < cfg.vocab_size for t in res.tokens)


def test_request_validation(setup, eng):
    cfg, params, prompts, _ = setup
    with _serve(eng, max_body_bytes=256) as (srv, cli):
        assert cli.generate([], max_new_tokens=4).status == 400
        # over engine capacity -> submit's ValueError surfaces as a 400
        assert cli.generate(prompts[0],
                            max_new_tokens=100000).status == 400
        # an oversized body is shed before parsing
        assert cli.generate(list(range(300)),
                            max_new_tokens=4).status == 413
        import socket as _s
        with _s.create_connection((srv.host, srv.port), 10) as sock:
            sock.sendall(b"GET /nope HTTP/1.1\r\nHost: x\r\n\r\n")
            assert b" 404 " in sock.makefile("rb").readline()
        with _s.create_connection((srv.host, srv.port), 10) as sock:
            sock.sendall(b"POST /generate HTTP/1.1\r\nHost: x\r\n"
                         b"Content-Length: 9\r\n\r\nnot json!")
            assert b" 400 " in sock.makefile("rb").readline()


def test_disconnect_cancels_and_frees(setup, eng):
    """A client that hangs up mid-stream cancels its request: the slot
    and pages come back while the engine is still running (the output
    stops well short of the budget), with the refcount audit clean."""
    cfg, params, prompts, refs = setup
    before = eng.stats()
    with _serve(eng) as (srv, cli):
        res = cli.generate(prompts[0], max_new_tokens=40,
                           disconnect_after=2)
        assert res.tokens == refs[0][:2]         # exact up to the hangup
        assert _wait(lambda: eng.stats()["requests_cancelled"]
                     == before["requests_cancelled"] + 1)
        assert _wait(lambda: not eng.has_work)
    victim = eng.cancelled[-1]
    assert victim.finish_reason == "cancelled"
    assert victim.slot == -1
    assert len(victim.output) < 40               # freed mid-decode
    stages = eng.stats()["cancelled_by_stage"]
    assert stages.get("decode", 0) >= 1
    eng.kv.check_integrity()
    snap = eng.obs.registry.snapshot()
    assert snap["repro_ingress_disconnects_total"] >= 1


def test_disconnect_before_first_token(setup, eng):
    """disconnect_after=0: the socket closes right after the response
    headers — the request dies in whatever stage it reached."""
    cfg, params, prompts, refs = setup
    before = eng.stats()["requests_cancelled"]
    with _serve(eng) as (srv, cli):
        res = cli.generate(prompts[1], max_new_tokens=30,
                           disconnect_after=0)
        assert res.status == 200 and not res.tokens
        assert _wait(lambda: eng.stats()["requests_cancelled"]
                     == before + 1)
        assert _wait(lambda: not eng.has_work)
    eng.kv.check_integrity()


def test_shed_reject(setup, eng):
    """Past the admission bound, 'reject' answers 429 + Retry-After and
    never touches the engine; capacity recovers once the queue drains."""
    cfg, params, prompts, refs = setup
    with _serve(eng, admission_queue=1,
                shed_policy="reject") as (srv, cli):
        with concurrent.futures.ThreadPoolExecutor(1) as ex:
            blocker = ex.submit(cli.generate, prompts[1],
                                max_new_tokens=35)
            assert _wait(lambda: srv._inflight >= 1)
            shed = cli.generate(prompts[0], max_new_tokens=4)
            assert shed.status == 429 and not shed.tokens
            assert shed.retry_after_s >= 1.0
            assert blocker.result(timeout=60).tokens == \
                ref_decode(params, cfg, prompts[1], 35)
        # queue drained: admitted again
        assert cli.generate(prompts[0],
                            max_new_tokens=MAX_NEW[0]).tokens == refs[0]
    snap = eng.obs.registry.snapshot()
    assert snap["repro_ingress_requests_total"]['outcome="rejected"'] >= 1


def test_shed_degrade(setup, eng):
    """'degrade' admits past the bound with max_new_tokens clamped: the
    client still gets tokens, and they are a prefix of exactly what the
    unclamped run would have produced."""
    cfg, params, prompts, refs = setup
    with _serve(eng, admission_queue=1, shed_policy="degrade",
                degrade_max_new=2) as (srv, cli):
        with concurrent.futures.ThreadPoolExecutor(1) as ex:
            blocker = ex.submit(cli.generate, prompts[1],
                                max_new_tokens=35)
            assert _wait(lambda: srv._inflight >= 1)
            res = cli.generate(prompts[0], max_new_tokens=MAX_NEW[0])
            assert res.status == 200 and res.degraded
            assert res.tokens == refs[0][:2]     # clamped, still exact
            assert res.finish_reason == "length"
            blocker.result(timeout=60)
    eng.kv.check_integrity()


def test_ingress_spans(eng):
    """STREAM spans land on the ingress pid with balanced begin/end."""
    ev = eng.obs.tracer.export()["traceEvents"]
    streams = [e for e in ev
               if e.get("pid") == PID_INGRESS and e["name"] == "STREAM"]
    assert sum(e["ph"] == "B" for e in streams) \
        == sum(e["ph"] == "E" for e in streams) > 0
    procs = {e["pid"]: e["args"]["name"] for e in ev
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert procs[PID_INGRESS] == "ingress"
