"""Multi-device correctness via subprocess (8 fake CPU devices — the
main test process must keep seeing exactly 1 device; see
``tests/mesh_harness.py`` for the shared runner + JSON protocol)."""
import pytest

from mesh_harness import run_mesh_script

_SCRIPT = r"""
import jax, jax.numpy as jnp, dataclasses, json
from repro.configs import get_config
from repro.models import lm
from repro.distributed.context import DistContext

base = get_config('moe-gpt3-s').reduced()
base = dataclasses.replace(base, compute_dtype='float32')
key, k2 = jax.random.PRNGKey(0), jax.random.PRNGKey(1)
B, S = 4, 32
batch = {'tokens': jax.random.randint(key, (B, S), 0, base.vocab_size),
         'labels': jax.random.randint(k2, (B, S), 0, base.vocab_size)}
cfg = dataclasses.replace(base, moe=dataclasses.replace(
    base.moe, num_partitions=2, memory_reuse_strategy='s4'))
params = lm.init(cfg, key)
loss_ref, _ = lm.loss_fn(params, batch, cfg)
g_ref = jax.grad(lambda p: lm.loss_fn(p, batch, cfg)[0])(params)

from repro.compat import make_mesh, set_mesh
mesh = make_mesh((2, 4), ('data', 'model'))
dist = DistContext(mesh=mesh, dp_axes=('data',), ep_axis='model',
                   tp_axis='model')
with set_mesh(mesh):
    loss_d = jax.jit(lambda p, b: lm.loss_fn(p, b, cfg, dist=dist)[0]
                     )(params, batch)
    g_d = jax.jit(jax.grad(lambda p, b: lm.loss_fn(p, b, cfg,
                                                   dist=dist)[0])
                  )(params, batch)
diffs = jax.tree_util.tree_map(
    lambda a, b: float(jnp.abs(a - b).max()), g_ref, g_d)
print(json.dumps({
    'n_devices': len(jax.devices()),
    'loss_diff': abs(float(loss_ref) - float(loss_d)),
    'max_grad_diff': max(jax.tree_util.tree_leaves(diffs)),
}))
"""

_DECODE_SCRIPT = r"""
import jax, jax.numpy as jnp, dataclasses, json
from repro.configs import get_config
from repro.models import lm
from repro.distributed.context import DistContext

base = get_config('deepseek-v2-lite-16b').reduced()
cfg = dataclasses.replace(base, compute_dtype='float32')
key = jax.random.PRNGKey(0)
params = lm.init(cfg, key)
B = 4
tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
cache0 = lm.init_cache(cfg, B, max_len=32, dtype=jnp.float32)
ref, _ = lm.decode_step(params, cache0, tok, cfg)

from repro.compat import make_mesh, set_mesh
mesh = make_mesh((2, 4), ('data', 'model'))
dist = DistContext(mesh=mesh, dp_axes=('data',), ep_axis='model',
                   tp_axis='model')
with set_mesh(mesh):
    cache1 = lm.init_cache(cfg, B, max_len=32, dtype=jnp.float32)
    out, _ = jax.jit(lambda p, c, t: lm.decode_step(p, c, t, cfg,
                                                    dist=dist)
                     )(params, cache1, tok)
print(json.dumps({'decode_diff': float(jnp.abs(ref - out).max())}))
"""


@pytest.mark.slow
def test_ep_shard_map_matches_single_device():
    res = run_mesh_script(_SCRIPT)
    assert res["n_devices"] == 8
    assert res["loss_diff"] < 1e-3
    assert res["max_grad_diff"] < 5e-3


@pytest.mark.slow
def test_moe_decode_replicated_path_matches():
    res = run_mesh_script(_DECODE_SCRIPT)
    assert res["decode_diff"] < 1e-3


def test_sharding_rules_divisibility_fallback():
    import jax
    from repro.distributed.sharding import make_rules
    from repro.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = make_rules(mesh, "train", fsdp=True)
    # heads=56 does not divide the (trivial 1-sized here) axis product —
    # use a synthetic check through spec_for with a fake big extent
    spec = rules.spec_for((56, 128), ("heads", "head_dim"), "wq")
    assert spec is not None


def test_make_production_mesh_requires_512_devices():
    from repro.launch.mesh import make_production_mesh
    with pytest.raises(Exception):
        make_production_mesh()        # only 1 device in this process
