"""The HLO static profiler: exact dot-flop counting through scan loops."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis as H


def _compiled_scan_matmul(reps=7, n=64, k=32):
    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()
    ws = jax.ShapeDtypeStruct((reps, n, n), jnp.float32)
    x = jax.ShapeDtypeStruct((k, n), jnp.float32)
    return jax.jit(f).lower(ws, x).compile()


def test_flops_exact_through_while_loops():
    reps, n, k = 7, 64, 32
    compiled = _compiled_scan_matmul(reps, n, k)
    res = H.analyze(compiled.as_text())
    true = 2 * reps * k * n * n
    assert abs(res["flops"] - true) / true < 0.01


def test_trip_count_multipliers():
    compiled = _compiled_scan_matmul(reps=5)
    comps = H.parse_hlo(compiled.as_text())
    mult = H.execution_multipliers(comps)
    assert any(abs(m - 5.0) < 1e-6 for m in mult.values())


def test_collective_parser_on_psum():
    def f(x):
        return jax.lax.psum(x, "i")
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, set_mesh, shard_map
    mesh = make_mesh((1,), ("i",))
    with set_mesh(mesh):
        g = jax.jit(shard_map(f, mesh=mesh, in_specs=P("i"),
                              out_specs=P()))
        compiled = g.lower(
            jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile()
    coll = H.collective_bytes(compiled.as_text())
    # single-device: collective may be optimized away; parser must not
    # crash and must return the full kind map
    assert set(coll) >= {"all-reduce", "all-gather", "all-to-all"}


def test_roofline_terms_and_dominant():
    terms = H.roofline_terms(1e15, 1e12, {"all-reduce": 4e9}, chips=256)
    assert terms["compute_s"] > 0
    assert H.dominant_term(terms) == "compute_s"
    terms2 = H.roofline_terms(1e12, 8.19e12, {"all-reduce": 0.0},
                              chips=256)
    assert H.dominant_term(terms2) == "memory_s"
