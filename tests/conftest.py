# NOTE: no XLA_FLAGS here — smoke tests/benches must see 1 device.
# Multi-device behaviour is tested via subprocesses (test_distributed.py).
import jax

jax.config.update("jax_platform_name", "cpu")
