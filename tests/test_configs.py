"""Config registry / shape / applicability invariants."""
import pytest

from repro.configs import (ARCHS, ASSIGNED, SHAPES, applicable, get_config)


def test_all_assigned_present():
    for a in ASSIGNED:
        assert a in ARCHS
    assert len(ASSIGNED) == 10


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_period_divides_depth(name):
    cfg = get_config(name)
    assert cfg.num_layers % cfg.period == 0
    assert cfg.num_periods >= 1
    roles = cfg.layer_roles()
    assert len(roles) == cfg.period


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_reduced_is_small(name):
    r = get_config(name).reduced()
    assert r.d_model <= 64
    assert r.vocab_size <= 256
    assert r.param_count() < 2_000_000


def test_applicability_matrix():
    # long_500k only for sub-quadratic archs
    ok, _ = applicable(get_config("llama3-8b"), SHAPES["long_500k"])
    assert not ok
    ok, _ = applicable(get_config("jamba-1.5-large-398b"),
                       SHAPES["long_500k"])
    assert ok
    ok, _ = applicable(get_config("xlstm-1.3b"), SHAPES["long_500k"])
    assert ok
    # everything runs train
    for a in ASSIGNED:
        ok, _ = applicable(get_config(a), SHAPES["train_4k"])
        assert ok


def test_exact_assigned_specs():
    j = get_config("jamba-1.5-large-398b")
    assert (j.num_layers, j.d_model, j.attn.num_heads,
            j.attn.num_kv_heads, j.d_ff, j.vocab_size) == \
        (72, 8192, 64, 8, 24576, 65536)
    assert j.moe.num_experts == 16 and j.moe.top_k == 2

    a = get_config("arctic-480b")
    assert a.moe.num_experts == 128 and a.moe.top_k == 2
    assert a.moe.dense_residual and a.d_ff == 4864

    d = get_config("deepseek-v2-lite-16b")
    assert d.attn.mla.kv_lora_rank == 512
    assert d.moe.num_experts == 64 and d.moe.top_k == 6
    assert d.moe.num_shared_experts == 2

    q = get_config("qwen1.5-110b")
    assert q.attn.qkv_bias and q.num_layers == 80 and q.d_ff == 49152

    g = get_config("gemma3-12b")
    assert g.attn.global_period == 6 and g.attn.window == 1024
    assert g.vocab_size == 262144


def test_param_counts_in_band():
    """Full configs should land near their nameplate sizes."""
    bands = {
        "llama3-8b": (7e9, 9e9),
        "h2o-danube-1.8b": (1.5e9, 2.2e9),
        "qwen1.5-110b": (95e9, 125e9),
        "arctic-480b": (400e9, 520e9),
        "jamba-1.5-large-398b": (330e9, 440e9),
        "deepseek-v2-lite-16b": (12e9, 20e9),
        "gemma3-12b": (9e9, 14e9),
        "xlstm-1.3b": (1.0e9, 2.6e9),
        "qwen2-vl-2b": (1.2e9, 2.5e9),
        "whisper-medium": (0.6e9, 1.1e9),
    }
    for name, (lo, hi) in bands.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, (name, n)
