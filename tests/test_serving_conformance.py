"""Serving conformance matrix + jit-compile-count regression.

The matrix pins the tentpole contract of the serving engine across its
whole configuration surface: **greedy decode is token-exact vs the
single-device dense golden loop** for every
``preempt x devices x kv_sharding`` combination —

    preempt     ∈ {never, recompute, offload}
    devices     ∈ {1, 8}
    kv_sharding ∈ {replicated, dp}

— skipping only the structurally undefined combos (``kv_sharding="dp"``
on one device has no mesh data axis to shard over; the engine refuses
it, see ``test_kv_sharding_dp_requires_a_mesh``). The preemptive combos
run over constrained pools so the storms actually fire; "never" runs
blocking admission over an ample pool. Multi-device combos run through
``tests/mesh_harness.py``; one subprocess per ``kv_sharding`` computes
all three preempt modes (amortizing jax init + golden refs) and the
parametrized tests assert their slice.

The **arch axis** extends the matrix over the StateCache kinds: plain
attention (h2o-danube, sliding window), pure mamba, pure xLSTM, MLA
latent paging (deepseek-v2-lite) and the jamba attn+mamba composite —
each × every preempt mode on the 8-device mesh, with preemption storms
*forced* via ``EngineOptions.storm_every`` (a constant-state cache
holds O(1) bytes per slot and never runs dry on its own; forcing makes
the storm legs uniform across kinds while the moe-gpt3-s matrix above
keeps pinning the organic pool-dry path). Drain checks are protocol-
level (``used_bytes == 0`` / ``free_units`` full / nothing parked) so
they hold for every cache kind.

The **attn_kernel axis** runs the moe-gpt3-s storm legs twice per
``kv_sharding`` — once on the legacy ``gather_pages`` + dense-attention
baseline, once on the fused Pallas paged-decode kernel
(``EngineOptions.attn_kernel``) — and pins them token-exact against the
dense golden loop *and* jit-counter-identical against each other: the
kernel selection is trace-static, so switching it may not add a single
trace or compile. The dp leg additionally lowers the compiled decode
program and asserts the Pallas HLO contains **no all-gather of the page
pool and zero wide (rank >= 4) f32 collectives** — the cross-shard KV
traffic XLA emits for the sharded ``gather_pages`` path (masked gather
+ rank-5 f32 all-reduce per attention layer) must be gone, because the
kernel reads pages shard-locally under ``shard_map``. The gather leg is
asserted to still carry that traffic, so the assertion keeps teeth.

The compile-count regression pins the PR 4 one-committed-placement
gotcha under the DP-KV layout: every step input must enter jit with one
committed sharding (``Engine._put`` / ``_put_slots`` / the cache's
``device_*`` buffers) and step outputs must be pinned back to the
pool layout (``pin_pools``) — otherwise the jit caches churn on
sharding mismatches. Steady state must compile the decode body exactly
once and each reachable prefill bucket exactly once, counted by the
engine's own trace counters (``decode_traces`` / ``prefill_traces`` —
the jitted bodies increment them only while tracing). The arch axis
asserts the same counters, so the invariant holds for recurrent state
threading (slot-sliced prefill writes, frozen inactive decode slots)
too. A **telemetry leg** replays the recompute storm with the
``repro.obs`` span tracer live and must match the telemetry-off leg's
jit counters exactly — instrumentation runs at trace time only, so
telemetry on/off cannot change compiled HLO (see
``docs/observability.md``).
"""
import pytest

from mesh_harness import run_mesh_script

PREEMPTS = ("never", "recompute", "offload")
DEVICES = (1, 8)
KV_SHARDINGS = ("replicated", "dp")

# decode-heavy budgets (10..14 pages at page_size 4) over a 30-page pool
# (replicated: 29 usable; dp=2: 14 usable per shard): growth overcommits
# both layouts, so recompute/offload storms fire per shard
_LENS = (13, 29, 7, 21, 5)
_MAX_NEW = (26, 24, 28, 25, 27)
_STORM_PAGES = 30

# the golden setup (model, prompts, dense references) is ONE source
# block: the subprocess template embeds it and the in-process
# single-device fixture exec()s the very same string, so the
# devices=1 and devices=8 legs of the matrix can never drift onto
# different models or workloads
_GOLDEN_SETUP = r"""
import dataclasses
import jax
import numpy as np
from repro.configs import get_config
from repro.models import lm
from repro.serve import Engine, EngineOptions, dense_greedy_reference

cfg = get_config('moe-gpt3-s').reduced()
cfg = dataclasses.replace(
    cfg, compute_dtype='float32',
    moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
params = lm.init(cfg, jax.random.PRNGKey(0))
rng = np.random.Generator(np.random.Philox(key=7))
lens, max_new = %(lens)r, %(max_new)r
prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
           for n in lens]
refs = [dense_greedy_reference(params, cfg, p, m)
        for p, m in zip(prompts, max_new)]
"""

_COMMON = _GOLDEN_SETUP + r"""
import json

def run_engine(**over):
    kw = dict(page_size=4, max_slots=4, max_seq_len=64, chunk=16,
              min_bucket=8, devices=8, kv_sharding=%(kv)r)
    kw.update(over)
    eng = Engine(cfg, params, options=EngineOptions(**kw))
    eng.warmup()
    for p, m in zip(prompts, max_new):
        eng.submit(p, max_new_tokens=m, arrival_s=0.0)
    eng.run_until_idle()
    outs = [r.output for r in sorted(eng.done, key=lambda r: r.rid)]
    return eng, outs

def report(eng, outs):
    kv, s = eng.kv, eng.stats()
    return {
        'token_exact': outs == refs,
        'preempt_recompute': eng.preempts['recompute'],
        'preempt_offload': eng.preempts['offload'],
        'swap_out': s['swap_out_bytes'], 'swap_in': s['swap_in_bytes'],
        'kv_shards': kv.n_shards,
        'drained': all(
            sorted(kv._free_by_shard[sh]) == list(
                range(sh * kv.pages_per_shard + 1,
                      (sh + 1) * kv.pages_per_shard))
            for sh in range(kv.n_shards)),
        'offloaded_left': kv.offloaded_count,
        'decode_traces': s['decode_traces'],
        'prefill_traces': s['prefill_traces'],
        'prefill_compiles': s['prefill_compiles'],
        'buckets': len(eng.adaptive.resolutions),
        'sticky': all(r.kv_shard in range(kv.n_shards)
                      for r in eng.done),
    }
"""

_MATRIX_SCRIPT = _COMMON + r"""
out = {}
for mode in ('never', 'recompute', 'offload'):
    eng, outs = run_engine(
        preempt=mode, num_pages=(0 if mode == 'never' else %(pages)d))
    out[mode] = report(eng, outs)

# telemetry leg: the recompute storm again with the span tracer live —
# instrumentation inside the jitted bodies runs at trace time only, so
# the jit trace/compile counters must not move by a single trace
from repro.obs import Recorder, Tracer
obs = Recorder(tracer=Tracer())
eng, outs = run_engine(preempt='recompute', num_pages=%(pages)d, obs=obs)
out['telemetry'] = report(eng, outs)
out['telemetry']['trace_events'] = len(obs.tracer.export()['traceEvents'])
print(json.dumps(out))
"""

_matrix_cache = {}


def _matrix(kv_sharding: str) -> dict:
    """One subprocess per kv_sharding computes all preempt modes.
    Three engine runs + golden refs per subprocess is ~1.5x the PR 4
    storm script, hence the raised timeout."""
    if kv_sharding not in _matrix_cache:
        _matrix_cache[kv_sharding] = run_mesh_script(
            _MATRIX_SCRIPT % {"kv": kv_sharding, "lens": _LENS,
                              "max_new": _MAX_NEW,
                              "pages": _STORM_PAGES},
            timeout=1800)
    return _matrix_cache[kv_sharding]


# -- single-device leg (in-process) -----------------------------------------

@pytest.fixture(scope="module")
def single_device_setup():
    """exec() the exact setup source the subprocess template embeds —
    one block, two legs, zero drift."""
    ns: dict = {}
    exec(_GOLDEN_SETUP % {"lens": _LENS, "max_new": _MAX_NEW}, ns)
    return ns["cfg"], ns["params"], ns["prompts"], ns["refs"]


def _run_single(setup, preempt: str) -> dict:
    from repro.serve import Engine, EngineOptions
    cfg, params, prompts, refs = setup
    eng = Engine(cfg, params, options=EngineOptions(
        page_size=4, max_slots=4, max_seq_len=64, chunk=16, min_bucket=8,
        preempt=preempt,
        num_pages=(0 if preempt == "never" else _STORM_PAGES)))
    for p, m in zip(prompts, _MAX_NEW):
        eng.submit(p, max_new_tokens=m, arrival_s=0.0)
    eng.run_until_idle()
    outs = [r.output for r in sorted(eng.done, key=lambda r: r.rid)]
    return {
        "token_exact": outs == refs,
        "preempt_recompute": eng.preempts["recompute"],
        "preempt_offload": eng.preempts["offload"],
        "swap_out": eng.kv.swap_out_bytes,
        "swap_in": eng.kv.swap_in_bytes,
        "drained": sorted(eng.kv._free) == list(
            range(1, eng.kv.num_pages)),
        "offloaded_left": eng.kv.offloaded_count,
    }


# -- the matrix --------------------------------------------------------------

def _check_combo(r: dict, preempt: str) -> None:
    assert r["token_exact"]
    assert r["drained"] and r["offloaded_left"] == 0
    if preempt == "never":
        assert r["preempt_recompute"] == 0 and r["preempt_offload"] == 0
    elif preempt == "recompute":
        assert r["preempt_recompute"] > 0 and r["preempt_offload"] == 0
        assert r["swap_out"] == 0
    else:
        assert r["preempt_offload"] > 0 and r["preempt_recompute"] == 0
        assert r["swap_out"] > 0 and r["swap_in"] == r["swap_out"]


@pytest.mark.parametrize("kv_sharding", KV_SHARDINGS)
@pytest.mark.parametrize("devices", DEVICES)
@pytest.mark.parametrize("preempt", PREEMPTS)
@pytest.mark.slow
def test_conformance_matrix_token_exact(preempt, devices, kv_sharding,
                                        single_device_setup):
    """Every defined (preempt, devices, kv_sharding) combo emits exactly
    the dense golden loop's greedy tokens and drains its allocator."""
    if devices == 1 and kv_sharding == "dp":
        pytest.skip("structurally undefined: a single device has no "
                    "mesh data axis to shard the KV pools over")
    if devices == 1:
        r = _run_single(single_device_setup, preempt)
    else:
        r = _matrix(kv_sharding)[preempt]
    _check_combo(r, preempt)


def test_matrix_covers_every_defined_combo():
    """The skip rule above is the ONLY hole: 3 x 2 x 2 = 12 combos, 3
    structurally undefined, 9 asserted."""
    defined = [(p, d, k) for p in PREEMPTS for d in DEVICES
               for k in KV_SHARDINGS if not (d == 1 and k == "dp")]
    assert len(defined) == 9


# -- jit-compile-count regression (one-committed-placement gotcha) -----------

@pytest.mark.slow
def test_dp_sharded_steady_state_compiles_once():
    """Mixed prefill/decode run with kv_sharding='dp': the decode body
    traces exactly once and each reachable prefill bucket exactly once —
    a second trace of any body means a step input arrived with a new
    committed sharding (the PR 4 jit-cache-churn gotcha, now with three
    input layouts in play: page-sharded pools, slot-sharded decode
    batch, replicated prefill rows)."""
    res = _matrix("dp")
    for mode in PREEMPTS:
        r = res[mode]
        assert r["kv_shards"] == 2                 # dp=2 x ep=4 mesh
        assert r["decode_traces"] == 1, \
            f"{mode}: decode compiled {r['decode_traces']}x"
        # every prefill jit traced exactly once...
        assert r["prefill_traces"] == r["prefill_compiles"], mode
        # ...and warmup's bucket sweep covered everything reachable (no
        # new compiles appeared mid-run, through preemption resumes
        # included)
        assert r["prefill_compiles"] == r["buckets"], mode


@pytest.mark.slow
def test_replicated_steady_state_compiles_once():
    """Same invariant for the replicated layout (the PR 4 baseline)."""
    res = _matrix("replicated")
    for mode in PREEMPTS:
        assert res[mode]["decode_traces"] == 1, mode
        assert res[mode]["prefill_traces"] == \
            res[mode]["prefill_compiles"], mode


@pytest.mark.slow
@pytest.mark.parametrize("kv_sharding", KV_SHARDINGS)
def test_telemetry_adds_zero_jit_traces(kv_sharding):
    """The span-tracer-on leg replays the recompute storm with every
    span/instant live (engine steps, request lifecycle, jit.trace
    instants inside the jitted bodies): jit trace and compile counts
    must be identical to the telemetry-off recompute leg — tracer calls
    inside jitted Python run at trace time only and can never change
    compiled HLO — and the run stays token-exact with a non-empty
    exported trace."""
    res = _matrix(kv_sharding)
    off, on = res["recompute"], res["telemetry"]
    assert on["token_exact"]
    assert on["drained"] and on["preempt_recompute"] > 0
    for k in ("decode_traces", "prefill_traces", "prefill_compiles",
              "buckets"):
        assert on[k] == off[k], f"{k}: {on[k]} != {off[k]}"
    assert on["trace_events"] > 0


# -- attn_kernel axis: fused Pallas paged decode vs gather baseline ----------

_KERNEL_SCRIPT = _COMMON + r"""
import re

out = {}
for kern in ('gather', 'pallas'):
    leg = {}
    for mode in ('recompute', 'offload'):
        eng, outs = run_engine(preempt=mode, num_pages=%(pages)d,
                               attn_kernel=kern)
        leg[mode] = report(eng, outs)
    if %(kv)r == 'dp':
        # lower the live engine's compiled decode program (same arg
        # construction as Engine.warmup) and count its collectives —
        # after the reports, since .lower() re-traces the decode body
        kvc = eng.kv
        with eng._mesh_scope():
            hlo = eng._decode_fn.lower(
                eng.params, kvc.pools,
                kvc.device_page_table(), kvc.device_lens(),
                eng._put_slots(np.zeros((kvc.max_slots, 1), np.int32)),
                eng._put_slots(np.zeros((kvc.max_slots,), bool)),
                eng._decode_sinks,
                *eng._sample_args([None] * kvc.max_slots, slots=True)
            ).compile().as_text()
        coll = [l for l in hlo.splitlines()
                if re.search(r'(all-gather|all-reduce|all-to-all|'
                             r'collective-permute|reduce-scatter)\(', l)]
        leg['collectives'] = len(coll)
        # XLA implements the sharded-pool gather as masked local gather
        # + a wide f32 all-reduce over the gathered-KV extent (rank 5),
        # not a literal pool all-gather — count both signatures
        leg['f32_wide_collectives'] = sum(
            1 for l in coll if re.search(r'f32\[\d+(,\d+){3,}\]', l))
        leg['pool_all_gathers'] = sum(
            1 for l in coll if 'all-gather' in l
            and ',%%d,' %% kvc.num_pages
            in l.replace('[', ',').replace(']', ','))
    out[kern] = leg
print(json.dumps(out))
"""

_kernel_cache = {}


def _kernel_matrix(kv_sharding: str) -> dict:
    """One subprocess per kv_sharding runs both attn kernels through
    both storm modes (4 engine runs + golden refs + one HLO lowering)."""
    if kv_sharding not in _kernel_cache:
        _kernel_cache[kv_sharding] = run_mesh_script(
            _KERNEL_SCRIPT % {"kv": kv_sharding, "lens": _LENS,
                              "max_new": _MAX_NEW,
                              "pages": _STORM_PAGES},
            timeout=1800)
    return _kernel_cache[kv_sharding]


@pytest.mark.parametrize("kv_sharding", KV_SHARDINGS)
@pytest.mark.parametrize("kern", ("gather", "pallas"))
@pytest.mark.slow
def test_attn_kernel_matrix_token_exact(kern, kv_sharding):
    """Both attention kernels emit exactly the dense golden loop's
    greedy tokens through recompute AND offload preemption storms, at
    both KV shardings, and drain their allocators — so the fused kernel
    is token-for-token interchangeable with the gather baseline."""
    res = _kernel_matrix(kv_sharding)[kern]
    for mode in ("recompute", "offload"):
        _check_combo(res[mode], mode)


@pytest.mark.parametrize("kv_sharding", KV_SHARDINGS)
@pytest.mark.slow
def test_attn_kernel_compile_counts_pinned(kv_sharding):
    """attn_kernel is trace-static: per kv_sharding, the Pallas legs'
    decode/prefill trace and compile counts equal the gather legs'
    exactly (and decode still compiles once) — selecting the kernel
    cannot churn the jit caches."""
    res = _kernel_matrix(kv_sharding)
    for mode in ("recompute", "offload"):
        g, p = res["gather"][mode], res["pallas"][mode]
        for k in ("decode_traces", "prefill_traces",
                  "prefill_compiles", "buckets"):
            assert g[k] == p[k], f"{mode}/{k}: {g[k]} != {p[k]}"
        assert p["decode_traces"] == 1, mode


@pytest.mark.slow
def test_attn_kernel_dp_hlo_shard_local():
    """The dp-leg decode HLO: the Pallas kernel reads pages shard-local
    under shard_map, so its program contains no all-gather of the page
    pool and zero wide (rank >= 4) f32 collectives; the gather leg must
    still carry that cross-shard KV traffic (teeth — if XLA ever
    optimizes it away, the baseline changed and this pin should be
    revisited, not the kernel)."""
    res = _kernel_matrix("dp")
    g, p = res["gather"], res["pallas"]
    assert p["pool_all_gathers"] == 0
    assert p["f32_wide_collectives"] == 0
    assert g["f32_wide_collectives"] > 0
    assert p["collectives"] < g["collectives"]


# -- arch axis: every StateCache kind x every preempt mode -------------------

# one leg per cache geometry the StateCache protocol serves:
#   plain-attn  h2o-danube-1.8b       paged      sliding-window attention
#   mamba       synthetic pure-mamba  constant   conv window + SSM state
#   xlstm       xlstm-1.3b            constant   mLSTM matrix + sLSTM cell
#   mla         deepseek-v2-lite-16b  paged      compressed c_kv latents
#   jamba       jamba-1.5-large-398b  composite  paged attn + constant mamba
ARCH_KIND = {
    "h2o-danube-1.8b": "paged",
    "pure-mamba": "constant",
    "xlstm-1.3b": "constant",
    "deepseek-v2-lite-16b": "paged",
    "jamba-1.5-large-398b": "composite",
}
ARCH_AXIS = tuple(sorted(ARCH_KIND))

_ARCH_LENS = (13, 7, 21)
_ARCH_MAX_NEW = (10, 12, 9)
# constant-state slots hold O(1) bytes and never run dry, so the storm
# legs force preemption on a fixed step cadence instead of starving the
# pool — uniform across cache kinds (the moe-gpt3-s matrix above keeps
# the organic pool-dry path pinned)
_ARCH_STORM_EVERY = 7

_ARCH_SETUP = r"""
import dataclasses
import jax
import numpy as np
from repro.configs import get_config
from repro.models import lm
from repro.serve import Engine, EngineOptions, dense_greedy_reference

def _golden(name):
    cfg = get_config(name).reduced()
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(moe, capacity_factor=8.0)
    return dataclasses.replace(cfg, compute_dtype='float32', moe=moe)

arch = %(arch)r
if arch == 'pure-mamba':
    # no registry entry is mixer-pure mamba; synthesize one from jamba's
    # mamba geometry so the constant-kind path is pinned without an
    # attention layer anywhere in the stack
    cfg = dataclasses.replace(_golden('jamba-1.5-large-398b'),
                              block_pattern=('mamba',), moe=None)
else:
    cfg = _golden(arch)
params = lm.init(cfg, jax.random.PRNGKey(0))
rng = np.random.Generator(np.random.Philox(key=7))
lens, max_new = %(lens)r, %(max_new)r
prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
           for n in lens]
refs = [dense_greedy_reference(params, cfg, p, m)
        for p, m in zip(prompts, max_new)]
"""

_ARCH_SCRIPT = _ARCH_SETUP + r"""
import json

def run_engine(mode):
    kw = dict(page_size=4, max_slots=2, max_seq_len=64, chunk=16,
              min_bucket=8, devices=8, kv_sharding=%(kv)r, preempt=mode)
    if mode != 'never':
        kw['storm_every'] = %(storm)d
    eng = Engine(cfg, params, options=EngineOptions(**kw))
    eng.warmup()
    full = eng.kv.free_units                 # fresh-cache capacity
    for p, m in zip(prompts, max_new):
        eng.submit(p, max_new_tokens=m, arrival_s=0.0)
    eng.run_until_idle()
    outs = [r.output for r in sorted(eng.done, key=lambda r: r.rid)]
    kv, s = eng.kv, eng.stats()
    return {
        'cache_kind': eng.cache_kind,
        'token_exact': outs == refs,
        'preempt_recompute': eng.preempts['recompute'],
        'preempt_offload': eng.preempts['offload'],
        'swap_out': s['swap_out_bytes'], 'swap_in': s['swap_in_bytes'],
        # protocol-level drain: holds for paged, constant and composite
        'drained': kv.used_bytes == 0 and kv.free_units == full,
        'offloaded_left': kv.offloaded_count,
        'decode_traces': s['decode_traces'],
        'prefill_traces': s['prefill_traces'],
        'prefill_compiles': s['prefill_compiles'],
    }

out = {}
for mode in ('never', 'recompute', 'offload'):
    out[mode] = run_engine(mode)
print(json.dumps(out))
"""

_arch_cache = {}


def _arch_matrix(arch: str, kv_sharding: str = "replicated") -> dict:
    """One subprocess per (arch, kv_sharding) computes all preempt
    modes, amortizing jax init + model init + golden refs."""
    key = (arch, kv_sharding)
    if key not in _arch_cache:
        _arch_cache[key] = run_mesh_script(
            _ARCH_SCRIPT % {"arch": arch, "kv": kv_sharding,
                            "lens": _ARCH_LENS, "max_new": _ARCH_MAX_NEW,
                            "storm": _ARCH_STORM_EVERY},
            timeout=1800)
    return _arch_cache[key]


@pytest.mark.parametrize("arch", ARCH_AXIS)
@pytest.mark.parametrize("preempt", PREEMPTS)
@pytest.mark.slow
def test_arch_matrix_token_exact(preempt, arch):
    """Every cache kind x preempt mode on the 8-device mesh: greedy
    decode is token-exact vs the dense golden loop, through forced
    recompute/offload preemption storms, and the cache drains back to
    its fresh capacity (slots, pages and host snapshots all returned)."""
    r = _arch_matrix(arch)[preempt]
    assert r["cache_kind"] == ARCH_KIND[arch]
    _check_combo(r, preempt)


def test_arch_axis_covers_every_cache_kind():
    """The axis spans all three StateCache kinds and the full 5 x 3
    grid is asserted (no skips on this axis)."""
    assert sorted(set(ARCH_KIND.values())) == \
        ["composite", "constant", "paged"]
    assert len(ARCH_AXIS) * len(PREEMPTS) == 15


@pytest.mark.parametrize("arch", ARCH_AXIS)
@pytest.mark.slow
def test_arch_steady_state_compiles_once(arch):
    """Compile-count regression extended across cache kinds: recurrent
    state threading (slot-sliced prefill writes, frozen inactive decode
    slots, constant-state dummy page tables) must not add jit cache
    entries — one decode trace, one trace per prefill bucket, in every
    preempt mode."""
    res = _arch_matrix(arch)
    for mode in PREEMPTS:
        r = res[mode]
        assert r["decode_traces"] == 1, \
            f"{arch}/{mode}: decode compiled {r['decode_traces']}x"
        assert r["prefill_traces"] == r["prefill_compiles"], \
            f"{arch}/{mode}"


@pytest.mark.slow
def test_constant_state_dp_sharded_leg():
    """Slot-sharded constant-state cache over the mesh data axis: xlstm
    with kv_sharding='dp' (dense model => dp spans all 8 devices) stays
    token-exact through forced storms, with host snapshots pinned to a
    sticky shard across offload/restore."""
    res = _arch_matrix("xlstm-1.3b", "dp")
    for mode in PREEMPTS:
        _check_combo(res[mode], mode)


# -- attn_kernel x arch: the MLA latent and composite paged paths ------------

_ARCH_KERNEL_SCRIPT = _ARCH_SETUP + r"""
import json

def run_engine(kern, mode):
    eng = Engine(cfg, params, options=EngineOptions(
        page_size=4, max_slots=2, max_seq_len=64, chunk=16,
        min_bucket=8, devices=8, kv_sharding=%(kv)r, preempt=mode,
        storm_every=%(storm)d, attn_kernel=kern))
    eng.warmup()
    for p, m in zip(prompts, max_new):
        eng.submit(p, max_new_tokens=m, arrival_s=0.0)
    eng.run_until_idle()
    outs = [r.output for r in sorted(eng.done, key=lambda r: r.rid)]
    s = eng.stats()
    return outs, {
        'cache_kind': eng.cache_kind,
        'token_exact': outs == refs,
        'preempts': eng.preempts['recompute'] + eng.preempts['offload'],
        'decode_traces': s['decode_traces'],
        'prefill_traces': s['prefill_traces'],
        'prefill_compiles': s['prefill_compiles'],
    }

out = {}
for mode in ('recompute', 'offload'):
    legs = {}
    for kern in ('gather', 'pallas'):
        toks, rep = run_engine(kern, mode)
        legs[kern] = toks
        out[f'{kern}_{mode}'] = rep
    out[f'tokens_equal_{mode}'] = legs['gather'] == legs['pallas']
print(json.dumps(out))
"""

# deepseek pins the MLA compressed-latent kernel path, jamba the
# composite (paged attn + constant mamba) path; the shardings are split
# across them so the arch x kernel axis touches both layouts without
# doubling the subprocess count (the moe-gpt3-s kernel matrix above
# already runs the full kernel x kv_sharding x storm cross)
ARCH_KERNEL_AXIS = (("deepseek-v2-lite-16b", "dp"),
                    ("jamba-1.5-large-398b", "replicated"))

_arch_kernel_cache = {}


def _arch_kernel_matrix(arch: str, kv_sharding: str) -> dict:
    key = (arch, kv_sharding)
    if key not in _arch_kernel_cache:
        _arch_kernel_cache[key] = run_mesh_script(
            _ARCH_KERNEL_SCRIPT % {"arch": arch, "kv": kv_sharding,
                                   "lens": _ARCH_LENS,
                                   "max_new": _ARCH_MAX_NEW,
                                   "storm": _ARCH_STORM_EVERY},
            timeout=1800)
    return _arch_kernel_cache[key]


@pytest.mark.parametrize("arch,kv_sharding", ARCH_KERNEL_AXIS)
@pytest.mark.slow
def test_attn_kernel_archs_token_exact(arch, kv_sharding):
    """MLA latent decode (deepseek) and the composite jamba cache run
    the fused kernel through forced recompute/offload storms on the
    8-device mesh: both kernels token-exact vs the dense golden loop
    and bit-identical to each other, with identical jit counters."""
    res = _arch_kernel_matrix(arch, kv_sharding)
    for mode in ("recompute", "offload"):
        assert res[f"tokens_equal_{mode}"], mode
        for kern in ("gather", "pallas"):
            r = res[f"{kern}_{mode}"]
            assert r["cache_kind"] == ARCH_KIND[arch]
            assert r["token_exact"], f"{kern}/{mode}"
            assert r["preempts"] > 0, f"{kern}/{mode}: storm never fired"
        g, p = res[f"gather_{mode}"], res[f"pallas_{mode}"]
        for k in ("decode_traces", "prefill_traces", "prefill_compiles"):
            assert g[k] == p[k], f"{mode}/{k}"
        assert p["decode_traces"] == 1


# -- prefix_cache axis: cross-request page sharing vs the off baseline -------

_PREFIX_SCRIPT = _COMMON + r"""
def run_prefix(**over):
    kw = dict(page_size=4, max_slots=4, max_seq_len=64, chunk=16,
              min_bucket=8, devices=8, kv_sharding=%(kv)r,
              prefix_cache='on')
    kw.update(over)
    eng = Engine(cfg, params, options=EngineOptions(**kw))
    eng.warmup()
    waves, hits = [], []
    for wave in range(2):
        rs = [eng.submit(p, max_new_tokens=m, arrival_s=0.0)
              for p, m in zip(prompts, max_new)]
        eng.run_until_idle()
        waves.append([r.output for r in rs])
        hits.append(eng.stats()['prefix_hits'])
    return eng, waves, hits

out = {}
for mode in ('never', 'recompute', 'offload'):
    eng, waves, hits = run_prefix(
        preempt=mode, num_pages=(0 if mode == 'never' else %(pages)d))
    kv, s = eng.kv, eng.stats()
    kv.check_integrity()        # raises -> subprocess fails the leg
    # every trie page must live on the shard of its root: a dp hit can
    # only ever bind pages of the shard the request was placed on
    local = True
    for sh in range(kv.n_shards):
        stack = [kv._trie_roots[sh]]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if node.page >= 0 and kv.shard_of_page(node.page) != sh:
                local = False
    out[mode] = {
        'token_exact': waves[0] == refs and waves[1] == refs,
        'cold_hits': hits[0], 'warm_hits': hits[1] - hits[0],
        'hit_tokens': s['prefix_hit_tokens'],
        'cow_copies': s['prefix_cow_copies'],
        'preempts': (eng.preempts['recompute']
                     + eng.preempts['offload']),
        'kv_shards': kv.n_shards, 'shard_local': local,
        'decode_traces': s['decode_traces'],
        'prefill_traces': s['prefill_traces'],
        'prefill_compiles': s['prefill_compiles'],
        'buckets': len(eng.adaptive.resolutions),
    }
print(json.dumps(out))
"""

_prefix_cache_results = {}


def _prefix_matrix(kv_sharding: str) -> dict:
    if kv_sharding not in _prefix_cache_results:
        _prefix_cache_results[kv_sharding] = run_mesh_script(
            _PREFIX_SCRIPT % {"kv": kv_sharding, "lens": _LENS,
                              "max_new": _MAX_NEW,
                              "pages": _STORM_PAGES},
            timeout=1800)
    return _prefix_cache_results[kv_sharding]


@pytest.mark.parametrize("kv_sharding", KV_SHARDINGS)
@pytest.mark.parametrize("preempt", PREEMPTS)
@pytest.mark.slow
def test_prefix_cache_matrix_token_exact(preempt, kv_sharding):
    """prefix_cache='on' x kv_sharding x preempt on the 8-device mesh:
    the standard trace plus a warm resubmission wave stays bit-identical
    to the dense golden loop (so to the prefix-off legs), the warm wave
    actually hits the published prefixes, and the allocator passes the
    full refcount-conservation audit after both waves."""
    r = _prefix_matrix(kv_sharding)[preempt]
    assert r["token_exact"]
    assert r["shard_local"]
    if preempt == "never":
        # worst-case pool: nothing evicts and nothing diverges mid-page
        # (full-reserve hits are page-aligned), so resubmissions hit —
        # all of them replicated; under dp the cache-aware placement is
        # a hint, and a request whose prefix shard has no free slot
        # falls back to the other shard and misses (observed: 4/5)
        assert r["cow_copies"] == 0
        floor = len(_LENS) if r["kv_shards"] == 1 else len(_LENS) - 2
        assert r["warm_hits"] >= floor
        assert r["hit_tokens"] > 0
    elif preempt == "recompute":
        # recompute resumes re-prefill prompts whose prefixes were
        # published at prefill-end, so the storm itself produces hits
        assert r["warm_hits"] >= 1
        assert r["preempts"] > 0
    else:
        # offload: restores bypass prefill entirely (no hit path) and
        # the tight storm pool evicts trie entries as fast as retires
        # publish them — hits are possible but not guaranteed; the leg
        # pins exactness + conservation under sharing, not hit rate
        assert r["preempts"] > 0


@pytest.mark.parametrize("kv_sharding", KV_SHARDINGS)
@pytest.mark.slow
def test_prefix_cache_jit_counts_match_off_leg(kv_sharding):
    """Prefix caching must not perturb compiled shapes: jit trace and
    compile counters on the prefix-on legs equal the prefix-off matrix
    legs — skipped prefill only shortens chunk loops over the same
    warmup-swept buckets, it never introduces a new traced body."""
    on, off = _prefix_matrix(kv_sharding), _matrix(kv_sharding)
    for mode in PREEMPTS:
        for k in ("decode_traces", "prefill_traces",
                  "prefill_compiles", "buckets"):
            assert on[mode][k] == off[mode][k], \
                f"{mode}/{k}: {on[mode][k]} != {off[mode][k]}"
        assert on[mode]["decode_traces"] == 1


@pytest.mark.slow
def test_prefix_cache_dp_hits_stay_shard_local():
    """dp-sharded pools: the trie is per shard and every hit binds only
    pages of the request's own shard (cross-shard sharing would read
    pages a device does not hold)."""
    res = _prefix_matrix("dp")
    for mode in PREEMPTS:
        assert res[mode]["kv_shards"] == 2
        assert res[mode]["shard_local"]
