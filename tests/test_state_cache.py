"""StateCache protocol: cache-kind dispatch over every config in
``repro.configs``, the constant-state slot allocator (unit +
hypothesis property), composite fan-out, and the grep-style guard that
``Engine``/``Scheduler`` stay implementation-agnostic."""
import dataclasses
import pathlib

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.api import serving_support
from repro.serve import (CompositeStateCache, ConstantStateCache,
                         PagedKVCache, StateCache, make_state_cache)

# every registered config must land in exactly one of these buckets —
# a new config that serves under a wrong kind (or silently falls off
# the matrix) fails here, and refusals must be stable strings from the
# one central serving_support
EXPECTED_KIND = {
    "arctic-480b": "paged",
    "deepseek-v2-lite-16b": "paged",
    "gemma3-12b": "paged",
    "h2o-danube-1.8b": "paged",
    "jamba-1.5-large-398b": "composite",
    "llama3-8b": "paged",
    "moe-bert-l": "paged",       # paper sizing, registered decoder-style
    "moe-gpt3-s": "paged",
    "moe-gpt3-xl": "paged",
    "qwen1.5-110b": "paged",
    "qwen2-vl-2b": None,         # vision frontend + m-rope
    "whisper-medium": None,      # encoder-decoder
    "xlstm-1.3b": "constant",
}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_every_config_servable_or_refused(name):
    assert name in EXPECTED_KIND, \
        f"new config {name!r}: add it to EXPECTED_KIND (and the " \
        f"serving conformance matrix if servable)"
    kind, why = serving_support(get_config(name).reduced())
    assert kind == EXPECTED_KIND[name]
    if kind is None:
        assert why, "refusals must carry a stable reason"
    else:
        assert why == ""


def test_refusal_reasons_are_central():
    """The VL and encdec refusals come from serving_support — one place,
    one stable string each."""
    kind, why = serving_support(get_config("whisper-medium").reduced())
    assert kind is None and "decoder-only" in why
    kind, why = serving_support(get_config("qwen2-vl-2b").reduced())
    assert kind is None and "frontend" in why


# ---------------------------------------------------------------------------
# make_state_cache / kinds
# ---------------------------------------------------------------------------

def _reduced(name):
    cfg = get_config(name).reduced()
    return dataclasses.replace(cfg, compute_dtype="float32")


def _cache(name, **over):
    cfg = _reduced(name)
    kind, _ = serving_support(cfg)
    kw = dict(num_pages=12, page_size=2, max_slots=4, max_pages_per_seq=4,
              max_seq_len=8, dtype=np.float32)
    kw.update(over)
    return make_state_cache(cfg, kind, **kw)


def test_make_state_cache_kinds():
    paged = _cache("llama3-8b")
    const = _cache("xlstm-1.3b")
    comp = _cache("jamba-1.5-large-398b")
    assert isinstance(paged, PagedKVCache) and paged.kind == "paged"
    assert isinstance(const, ConstantStateCache) and \
        const.kind == "constant"
    assert isinstance(comp, CompositeStateCache) and \
        comp.kind == "composite"
    for kv in (paged, const, comp):
        assert isinstance(kv, StateCache)
        assert kv.max_slot_tokens >= 8
        assert kv.page_table_width >= 1
        assert kv.cache_bytes > 0 and kv.used_bytes == 0
    # paged ceiling = page table x page size (capped by shard capacity);
    # constant ceiling = the configured budget; composite = the min
    assert paged.max_slot_tokens == 8
    assert const.max_slot_tokens == 8
    assert comp.max_slot_tokens == 8
    with pytest.raises(ValueError, match="unknown cache kind"):
        make_state_cache(_reduced("llama3-8b"), "bogus", num_pages=4,
                         page_size=2, max_slots=1, max_pages_per_seq=2,
                         max_seq_len=4)


def test_constant_admission_and_accounting():
    kv = _cache("xlstm-1.3b", max_slots=2)
    assert kv.free_units == 2 and kv.slot_bytes > 0
    assert kv.admissible(8) and not kv.admissible(9)
    assert not kv.admissible(0)
    assert kv.can_admit(4) and kv.best_shard(4) == 0
    kv.alloc_slot(0, 4)
    kv.alloc_slot(1, 8)
    assert kv.used_bytes == kv.cache_bytes == 2 * kv.slot_bytes
    assert not kv.can_admit(4) and kv.best_shard(4) is None
    assert kv.free_units == 0
    # growth is free: state is O(1) in sequence length
    assert kv.grow_slot(0) and kv.slot_capacity(0) == 8
    assert kv.held_bytes(0) == kv.slot_bytes
    kv.free_slot(0)
    assert kv.held_bytes(0) == 0 and kv.can_admit(4)
    assert kv.peak_used_bytes == 2 * kv.slot_bytes


def test_constant_alloc_zeroes_slot():
    """Zero-at-alloc is load-bearing: slot reuse must not leak the
    previous request's recurrent state, and a recompute-resume must
    re-prefill from the zero state."""
    kv = _cache("xlstm-1.3b", max_slots=2)
    kv.alloc_slot(0, 4)
    kv.pools = jax.tree_util.tree_map(
        lambda leaf: leaf.at[:, 0].set(1.25), kv.pools)
    kv.free_slot(0)
    kv.alloc_slot(0, 4)
    for leaf in jax.tree_util.tree_leaves(kv.pools):
        assert not np.asarray(leaf[:, 0]).any()


def test_composite_shares_lens_and_fans_out():
    kv = _cache("jamba-1.5-large-398b", max_slots=2)
    assert kv.lens is kv.paged.lens and kv.lens is kv.state.lens
    assert set(kv.pools) == set(kv.paged.pools) | set(kv.state.pools)
    kv.alloc_slot(0, 4)
    kv.lens[0] = 4
    assert kv.state._allocated[0] and kv.paged.slot_page_count(0) > 0
    assert kv.held_bytes(0) == \
        kv.paged.held_bytes(0) + kv.state.held_bytes(0) > 0
    assert kv.used_bytes == kv.paged.used_bytes + kv.state.used_bytes
    n_out = kv.offload_slot(0, rid=7)
    assert n_out > 0 and kv.offloaded_count == 1
    assert kv.host_bytes == kv.paged.host_bytes + kv.state.host_bytes
    assert int(kv.lens[0]) == 0
    assert kv.can_restore(7)
    n_in = kv.restore_slot(7, 0, tokens=4)
    assert n_in == n_out and kv.offloaded_count == 0
    assert int(kv.lens[0]) == 4 and int(kv.paged.lens[0]) == 4
    kv.free_slot(0)
    assert kv.used_bytes == 0


def test_composite_admission_gated_by_both_sides():
    kv = _cache("jamba-1.5-large-398b", max_slots=2)
    assert kv.can_admit(4)
    kv.alloc_slot(0, 4)
    kv.alloc_slot(1, 4)
    # slots exhausted: the constant side refuses even though the paged
    # side may still hold free pages
    assert not kv.can_admit(4) and kv.best_shard(4) is None


# ---------------------------------------------------------------------------
# Hypothesis property: the constant-state slot allocator
# ---------------------------------------------------------------------------

def _allocator_interleaving(kv, ops, seed):
    """Interpreter for one random op sequence; asserts the invariants
    after every op (see test docstring)."""
    rng = np.random.Generator(np.random.Philox(key=seed))
    live = {}     # slot -> (rid, expected state rows, tokens)
    parked = {}   # rid -> (expected state rows, tokens)
    next_rid = 0

    def rand_rows(slot):
        rows = jax.tree_util.tree_map(
            lambda leaf: rng.standard_normal(
                leaf[:, slot].shape).astype(leaf.dtype), kv.pools)
        kv.pools = jax.tree_util.tree_map(
            lambda leaf, r: leaf.at[:, slot].set(r), kv.pools, rows)
        return rows

    for op, pick in ops:
        if op == 0:                                   # alloc
            free = [s for s in range(kv.max_slots)
                    if s not in live and not kv._allocated[s]]
            if not free:
                continue
            slot = free[pick % len(free)]
            tokens = int(rng.integers(1, 33))
            kv.alloc_slot(slot, tokens)
            for leaf in jax.tree_util.tree_leaves(kv.pools):
                assert not np.asarray(leaf[:, slot]).any()
            kv.lens[slot] = tokens                    # engine-side write
            live[slot] = (next_rid, rand_rows(slot), tokens)
            next_rid += 1
        elif op == 1:                                 # free
            if not live:
                continue
            slot = sorted(live)[pick % len(live)]
            del live[slot]
            kv.free_slot(slot)
            assert int(kv.lens[slot]) == 0
        elif op == 2:                                 # offload (snapshot)
            if not live:
                continue
            slot = sorted(live)[pick % len(live)]
            rid, rows, tokens = live.pop(slot)
            kv.offload_slot(slot, rid)
            host, shard = kv._offloaded[rid]
            assert shard == kv.shard_of_slot(slot)
            jax.tree_util.tree_map(
                lambda h, r: np.testing.assert_array_equal(h, r),
                host, rows)
            parked[rid] = (rows, tokens)
        else:                                         # restore
            if not parked:
                continue
            rid = sorted(parked)[pick % len(parked)]
            assert kv.can_restore(rid)
            shard = kv.offloaded_shard(rid)
            free = [s for s in kv.slots_of(shard)
                    if s not in live and not kv._allocated[s]]
            if not free:
                continue
            slot = free[pick % len(free)]
            rows, tokens = parked.pop(rid)
            kv.restore_slot(rid, slot, tokens)
            back = jax.tree_util.tree_map(
                lambda leaf: np.asarray(leaf[:, slot]), kv.pools)
            jax.tree_util.tree_map(                   # bit-exact
                lambda b, r: np.testing.assert_array_equal(b, r),
                back, rows)
            assert int(kv.lens[slot]) == tokens
            live[slot] = (rid, rows, tokens)
        # -- invariants after every op --------------------------------
        assert {s for s in range(kv.max_slots) if kv._allocated[s]} \
            == set(live)                              # no aliasing
        rids = [rid for rid, _, _ in live.values()]
        assert len(rids) == len(set(rids))
        assert kv.offloaded_count == len(parked)
        assert kv.used_bytes == len(live) * kv.slot_bytes
        for s in range(kv.max_slots):
            if s not in live:
                assert int(kv.lens[s]) == 0


def test_constant_allocator_interleavings():
    """Random alloc/free/offload(snapshot)/restore interleavings:

    * slots never alias — a slot is bound to at most one request, a
      parked request restores only onto a free slot of its own shard;
    * offload -> restore round-trips the slot's state **bit-exact**;
    * lens / used_bytes / offloaded_count bookkeeping never drifts.
    """
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    cfg = _reduced("xlstm-1.3b")

    @settings(max_examples=30, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=st.lists(st.tuples(st.integers(0, 3), st.integers(0, 7)),
                        min_size=1, max_size=40),
           seed=st.integers(0, 2**31 - 1))
    def run(ops, seed):
        kv = ConstantStateCache(cfg, max_slots=4, max_seq_len=32,
                                dtype=np.float32, shards=2)
        _allocator_interleaving(kv, ops, seed)

    run()


def test_constant_allocator_fixed_interleavings():
    """Hypothesis-free fallback (hypothesis is optional in CI): a few
    deterministic op sequences through the same interpreter, covering
    alloc->offload->restore->free cycles, slot reuse and cross-shard
    restores."""
    cfg = _reduced("xlstm-1.3b")
    sequences = [
        [(0, 0), (0, 1), (2, 0), (3, 0), (1, 0), (0, 0)],
        [(0, 3), (2, 0), (0, 2), (2, 0), (3, 1), (3, 0), (1, 0)],
        [(0, i % 4) for i in range(8)] + [(2, 0), (2, 0), (3, 0),
                                          (1, 1), (3, 0)],
        [(0, 0), (1, 0)] * 6 + [(0, 5), (2, 0), (3, 3)],
    ]
    for seed, ops in enumerate(sequences):
        kv = ConstantStateCache(cfg, max_slots=4, max_seq_len=32,
                                dtype=np.float32, shards=2)
        _allocator_interleaving(kv, ops, seed)


def test_restore_refuses_foreign_shard():
    kv = ConstantStateCache(_reduced("xlstm-1.3b"), max_slots=4,
                            max_seq_len=32, dtype=np.float32, shards=2)
    kv.alloc_slot(0, 4)                               # shard 0
    kv.offload_slot(0, rid=1)
    with pytest.raises(AssertionError, match="sticky"):
        kv.restore_slot(1, kv.max_slots - 1, 4)       # shard 1 slot
    assert kv.offloaded_count == 1                    # state not lost
    kv.restore_slot(1, 0, 4)
    assert kv.offloaded_count == 0


# ---------------------------------------------------------------------------
# Grep guard: engine/scheduler never touch a concrete cache
# ---------------------------------------------------------------------------

def test_engine_scheduler_are_cache_agnostic():
    """Outside cache construction, ``engine.py`` / ``scheduler.py`` must
    program against the StateCache protocol only — no paged-specific
    attribute access, no concrete class names."""
    import repro.serve.engine as engine_mod
    import repro.serve.scheduler as sched_mod
    deny = ("PagedKVCache", "ConstantStateCache", "CompositeStateCache",
            "kv.pages_for(", "kv.page_bytes", "kv.slot_page_count(",
            "kv.num_pages", "kv.free_pages", "kv.pages_per_shard",
            "kv.shard_capacity_pages", "kv.max_pages_per_seq",
            "kv.page_size", "kv.page_table[", "_free_by_shard",
            "kv.offloaded_pages", "kv.sink_page", "kv.shard_of_page")
    for mod in (engine_mod, sched_mod):
        src = pathlib.Path(mod.__file__).read_text()
        for needle in deny:
            assert needle not in src, \
                f"{mod.__name__} uses cache-specific {needle!r}"
