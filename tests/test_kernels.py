"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret
mode on CPU), per the deliverable-(c) requirement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (flash_attention,
                                           flash_attention_ref)
from repro.kernels.grouped_ffn import grouped_ffn, grouped_ffn_ref
from repro.kernels.topk_router import topk_router, topk_router_ref


@pytest.mark.parametrize("e,c,m,h", [(4, 64, 128, 256), (2, 100, 256, 512),
                                     (8, 32, 128, 128), (1, 256, 512, 256)])
@pytest.mark.parametrize("gated", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_ffn_matches_ref(e, c, m, h, gated, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (e, c, m), dtype)
    wu = jax.random.normal(ks[1], (e, m, h), dtype) / np.sqrt(m)
    wg = (jax.random.normal(ks[2], (e, m, h), dtype) / np.sqrt(m)
          if gated else None)
    wd = jax.random.normal(ks[3], (e, h, m), dtype) / np.sqrt(h)
    out = grouped_ffn(x, wu, wg, wd, "silu")
    ref = grouped_ffn_ref(x, wu, wg, wd, act="silu").astype(dtype)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_grouped_ffn_gradients_match_ref():
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    e, c, m, h = 2, 64, 64, 128
    x = jax.random.normal(ks[0], (e, c, m))
    wu = jax.random.normal(ks[1], (e, m, h)) / np.sqrt(m)
    wg = jax.random.normal(ks[2], (e, m, h)) / np.sqrt(m)
    wd = jax.random.normal(ks[3], (e, h, m)) / np.sqrt(h)
    g1 = jax.grad(lambda *a: grouped_ffn(*a, "silu").sum(),
                  argnums=(0, 1, 2, 3))(x, wu, wg, wd)
    g2 = jax.grad(lambda *a: grouped_ffn_ref(*a, act="silu").sum(),
                  argnums=(0, 1, 2, 3))(x, wu, wg, wd)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("t,e,k", [(512, 64, 1), (300, 16, 2),
                                   (256, 128, 6), (64, 8, 2)])
def test_topk_router_matches_ref(t, e, k):
    logits = jax.random.normal(jax.random.PRNGKey(2), (t, e))
    p1, i1 = topk_router(logits, k)
    p2, i2 = topk_router_ref(logits, k)
    assert (np.asarray(i1) == np.asarray(i2)).all()
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=1e-6)


@pytest.mark.parametrize(
    "b,sq,sk,hq,kv,d,causal,window",
    [(2, 128, 128, 4, 2, 64, True, 0),
     (1, 200, 200, 4, 4, 32, True, 64),
     (2, 64, 256, 8, 2, 64, False, 0),
     (1, 130, 130, 2, 1, 128, True, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, sq, sk, hq, kv, d, causal, window,
                                     dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, sk, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, sk, kv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64)
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, d)
    ref = flash_attention_ref(qf, kf, vf, causal=causal, window=window)
    ref = ref.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_model_flash_matches_kernel():
    """The model's scan-based flash equals the Pallas kernel equals the
    naive oracle (three-way agreement)."""
    from repro.models.layers.attention import flash_attention as model_fa
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    b, s, h, d = 2, 96, 4, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    o_model = model_fa(q, k, v, causal=True, q_block=32, kv_block=32)
    o_kernel = flash_attention(q, k, v, causal=True, block_q=32,
                               block_k=32)
    np.testing.assert_allclose(np.asarray(o_model), np.asarray(o_kernel),
                               rtol=2e-5, atol=2e-5)
