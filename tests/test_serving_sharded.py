"""Mesh-sharded serving (8 fake CPU devices via subprocess, like
test_distributed.py): the engine on a dp x ep mesh — EP-sharded chunked
prefill through pipelined_moe's ``sharded`` layout, replicated
psum-combine decode, replicated paged KV pools — must emit exactly the
tokens of the single-device dense golden loop, including through
recompute and offload preemption storms. Plus in-process unit tests for
the mesh construction helpers (no multi-device requirement).

Subprocess pattern + JSON result protocol: ``tests/mesh_harness.py``.
The (preempt x devices x kv_sharding) conformance matrix and the
jit-compile-count regression live in
``tests/test_serving_conformance.py``."""
import pytest

from mesh_harness import run_mesh_script

_COMMON = r"""
import dataclasses, json
import jax
import numpy as np
from repro.configs import get_config
from repro.models import lm
from repro.serve import Engine, EngineOptions, dense_greedy_reference

cfg = get_config('moe-gpt3-s').reduced()
cfg = dataclasses.replace(
    cfg, compute_dtype='float32',
    moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
params = lm.init(cfg, jax.random.PRNGKey(0))
rng = np.random.Generator(np.random.Philox(key=7))
lens, max_new = (13, 29, 7, 21, 5), (6, 4, 8, 5, 7)
prompts = [rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
           for n in lens]
refs = [dense_greedy_reference(params, cfg, p, m)
        for p, m in zip(prompts, max_new)]

def run_engine(**over):
    kw = dict(page_size=4, max_slots=3, max_seq_len=64, chunk=16,
              min_bucket=8, devices=8)
    kw.update(over)
    eng = Engine(cfg, params, options=EngineOptions(**kw))
    for p, m in zip(prompts, max_new):
        eng.submit(p, max_new_tokens=m, arrival_s=0.0)
    eng.run_until_idle()
    outs = [r.output for r in sorted(eng.done, key=lambda r: r.rid)]
    return eng, outs
"""

_EXACT_SCRIPT = _COMMON + r"""
eng, outs = run_engine()
s = eng.stats()
print(json.dumps({
    'n_devices': len(jax.devices()),
    'devices': s['devices'], 'ep': s['ep_size'], 'dp': s['dp_size'],
    'token_exact': outs == refs,
    'buckets': len(eng.adaptive.resolutions),
    'kv_drained': eng.kv.free_pages == eng.kv.num_pages - 1,
}))
"""

_STORM_SCRIPT = _COMMON + r"""
out = {}
for mode in ('recompute', 'offload'):
    eng, outs = run_engine(num_pages=12, preempt=mode)
    s = eng.stats()
    out[mode] = {
        'token_exact': outs == refs,
        'preempts': eng.preempts[mode],
        'other_mode_preempts': eng.preempts[
            'offload' if mode == 'recompute' else 'recompute'],
        'swap_out': s['swap_out_bytes'], 'swap_in': s['swap_in_bytes'],
        'kv_drained': eng.kv.free_pages == eng.kv.num_pages - 1,
        'offloaded_left': eng.kv.offloaded_count,
    }
print(json.dumps(out))
"""


@pytest.mark.slow
def test_sharded_engine_token_exact_vs_dense_golden():
    """EP-parallel prefill + replicated decode on a 2x4 (dp x ep) mesh
    emits exactly the single-device dense greedy tokens."""
    res = run_mesh_script(_EXACT_SCRIPT)
    assert res["n_devices"] == 8 and res["devices"] == 8
    # moe-gpt3-s-reduced has 4 experts -> ep=4, dp=2
    assert res["ep"] == 4 and res["dp"] == 2
    assert res["token_exact"]
    assert res["buckets"] >= 2                  # mixed-length prompts
    assert res["kv_drained"]


@pytest.mark.slow
def test_sharded_preemption_storm_token_exact():
    """Recompute and offload preemption storms while sharded: the host
    offload pool round-trips through the replicated device pools and
    tokens stay exact."""
    res = run_mesh_script(_STORM_SCRIPT)
    for mode in ("recompute", "offload"):
        r = res[mode]
        assert r["token_exact"], mode
        assert r["preempts"] > 0 and r["other_mode_preempts"] == 0
        assert r["kv_drained"] and r["offloaded_left"] == 0
    assert res["offload"]["swap_out"] > 0
    assert res["offload"]["swap_in"] == res["offload"]["swap_out"]
    assert res["recompute"]["swap_out"] == 0


# ---------------------------------------------------------------------------
# Mesh construction helpers (single-device, in-process)
# ---------------------------------------------------------------------------

def test_ep_split_prefers_largest_expert_divisor():
    from repro.distributed.context import ep_split
    assert ep_split(8, 4) == (2, 4)       # moe-gpt3-s-reduced on 8 dev
    assert ep_split(8, 64) == (1, 8)      # full-size paper MoE
    assert ep_split(8, 6) == (4, 2)       # partial divisor
    assert ep_split(8, 3) == (8, 1)       # nothing divides -> pure dp
    assert ep_split(8, 0) == (8, 1)       # dense model
    assert ep_split(1, 64) == (1, 1)


def test_make_serving_context_single_device_is_none():
    from repro.distributed.context import make_serving_context
    assert make_serving_context(0) is None
    assert make_serving_context(1, num_experts=64) is None


def test_make_serving_context_rejects_missing_devices():
    # the main test process sees exactly 1 device (conftest)
    from repro.distributed.context import make_serving_context
    with pytest.raises(RuntimeError, match="host_platform_device_count"):
        make_serving_context(8, num_experts=4)


def test_engine_options_devices_defaults_off():
    from repro.serve import EngineOptions
    assert EngineOptions().devices == 0
    assert EngineOptions().kv_sharding == "replicated"


def test_kv_sharding_dp_requires_a_mesh():
    """kv_sharding='dp' without a data axis to shard over is
    structurally undefined — the engine must refuse, not silently
    degrade."""
    from repro.configs import get_config
    from repro.serve import Engine, EngineOptions
    cfg = get_config("moe-gpt3-s").reduced()
    with pytest.raises(ValueError, match="kv_sharding='dp'"):
        Engine(cfg, options=EngineOptions(devices=0, kv_sharding="dp",
                                          max_slots=2, max_seq_len=32))
