"""Shared harness for multi-device tests on virtual CPU devices.

The main pytest process must keep seeing exactly **1** device (smoke
tests and benches depend on it — see ``tests/conftest.py``), and XLA
only honours ``--xla_force_host_platform_device_count`` before the
first jax import. So every multi-device test runs its body in a
subprocess with the flag set in the environment, and reports its
results back over a one-line JSON protocol:

* the script under test prints **one ``json.dumps(...)`` object as its
  last stdout line** (anything before it — warnings, progress — is
  ignored);
* :func:`run_mesh_script` spawns the subprocess with ``devices``
  virtual CPU devices and the repo's ``src/`` on ``PYTHONPATH``,
  asserts a zero exit (surfacing the stderr tail on failure), and
  returns the decoded JSON.

Used by ``tests/test_distributed.py`` (training-side EP/decode
parity), ``tests/test_serving_sharded.py`` (mesh-sharded serving
token-exactness) and ``tests/test_serving_conformance.py`` (the
serving conformance matrix + jit-compile-count regression).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

__all__ = ["SRC_PATH", "run_mesh_script"]

SRC_PATH = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "src"))


def run_mesh_script(script: str, *, devices: int = 8,
                    timeout: float = 600, extra_env=None) -> dict:
    """Run ``script`` under ``devices`` virtual CPU devices; return the
    JSON object printed as its final stdout line."""
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        PYTHONPATH=SRC_PATH + (
            os.pathsep + os.environ["PYTHONPATH"]
            if os.environ.get("PYTHONPATH") else ""))
    if extra_env:
        env.update(extra_env)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, \
        f"mesh subprocess failed (exit {out.returncode}):\n" \
        f"{out.stderr[-2000:] or out.stdout[-2000:]}"
    lines = out.stdout.strip().splitlines()
    assert lines, f"mesh subprocess printed nothing:\n{out.stderr[-1000:]}"
    return json.loads(lines[-1])
