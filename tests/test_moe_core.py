"""MPipeMoE core invariants: pipelining & memory-reuse strategies change
memory behavior, never math."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.pipeline_moe import capacity_for, pipelined_moe
from repro.models import lm
from repro.moe import dispatch as D


def _cfg(n=1, strat="none", unroll=True):
    base = get_config("moe-gpt3-s").reduced()
    return dataclasses.replace(
        base, compute_dtype="float32",
        moe=dataclasses.replace(base.moe, num_partitions=n,
                                memory_reuse_strategy=strat,
                                pipeline_unroll=unroll))


def _run(cfg, key, batch):
    params = lm.init(cfg, key)
    loss, _ = lm.loss_fn(params, batch, cfg)
    g = jax.grad(lambda p: lm.loss_fn(p, batch, cfg)[0])(params)
    gn = jax.tree_util.tree_reduce(lambda a, x: a + jnp.sum(x * x), g, 0.0)
    return float(loss), float(gn)


@pytest.fixture(scope="module")
def batch():
    key = jax.random.PRNGKey(0)
    k2 = jax.random.PRNGKey(1)
    cfg = _cfg()
    return {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size),
            "labels": jax.random.randint(k2, (2, 32), 0, cfg.vocab_size)}


def test_strategies_are_math_identical(batch):
    """Within a fixed n, every restore strategy gives identical loss+grads
    (they change WHERE activations live, not WHAT is computed)."""
    key = jax.random.PRNGKey(0)
    ref = _run(_cfg(n=2, strat="none"), key, batch)
    for strat in ("s1", "s2", "s3", "s4"):
        got = _run(_cfg(n=2, strat=strat), key, batch)
        assert got[0] == pytest.approx(ref[0], abs=1e-5), strat
        assert got[1] == pytest.approx(ref[1], rel=1e-4), strat


def test_pipeline_partitions_close(batch):
    """Across n the math differs only via per-chunk capacity rounding."""
    key = jax.random.PRNGKey(0)
    ref = _run(_cfg(n=1), key, batch)
    for n in (2, 4):
        got = _run(_cfg(n=n, strat="s4"), key, batch)
        assert got[0] == pytest.approx(ref[0], abs=5e-3)


def test_scan_mode_matches_unroll(batch):
    key = jax.random.PRNGKey(0)
    a = _run(_cfg(n=4, strat="s4", unroll=True), key, batch)
    b = _run(_cfg(n=4, strat="s4", unroll=False), key, batch)
    assert a[0] == pytest.approx(b[0], abs=1e-5)
    assert a[1] == pytest.approx(b[1], rel=1e-4)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def test_sort_dispatch_matches_einsum_oracle():
    key = jax.random.PRNGKey(3)
    t, k, e, cap, m = 64, 2, 8, 16, 16
    tokens = jax.random.normal(key, (t, m))
    probs = jax.nn.softmax(jax.random.normal(key, (t, e)))
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / top_p.sum(-1, keepdims=True)

    dest, valid = D.dispatch_plan(top_i.astype(jnp.int32), e, cap)
    buf = D.dispatch(tokens, dest, e, cap)
    out_sort = D.combine(buf, dest, top_p, t)

    mask, cw = D.einsum_dispatch_mask(top_i.astype(jnp.int32), top_p, e,
                                      cap)
    buf_ein = jnp.einsum("tec,tm->ecm", mask.astype(tokens.dtype), tokens)
    out_ein = jnp.einsum("ecm,tec->tm", buf_ein, cw)

    assert jnp.allclose(buf, buf_ein, atol=1e-5)
    assert jnp.allclose(out_sort, out_ein, atol=1e-5)


def test_dispatch_respects_capacity():
    # all tokens to expert 0 -> only `cap` survive
    t, e, cap, m = 32, 4, 8, 4
    tokens = jnp.ones((t, m))
    eidx = jnp.zeros((t, 1), jnp.int32)
    dest, valid = D.dispatch_plan(eidx, e, cap)
    assert int(valid.sum()) == cap
    buf = D.dispatch(tokens, dest, e, cap)
    assert float(buf[0].sum()) == cap * m
    assert float(buf[1:].sum()) == 0.0


def test_capacity_for_rounds_up():
    assert capacity_for(100, 2, 1.25, 16) % 8 == 0
    assert capacity_for(100, 2, 1.25, 16) >= 100 * 2 * 1.25 / 16
    assert capacity_for(1, 1, 1.0, 64) >= 1


def test_single_device_moe_runs_all_modes():
    cfg = _cfg(n=2, strat="s4")
    key = jax.random.PRNGKey(0)
    tokens = jax.random.normal(key, (64, cfg.d_model))
    params = {"router": {"w_gate": jax.random.normal(
        key, (cfg.d_model, cfg.moe.num_experts)) * 0.02},
        "experts": {
            "w_up": jax.random.normal(
                key, (cfg.moe.num_experts, cfg.d_model,
                      cfg.moe.d_expert)) * 0.05,
            "w_down": jax.random.normal(
                key, (cfg.moe.num_experts, cfg.moe.d_expert,
                      cfg.d_model)) * 0.05}}
    for mode in ("train", "prefill", "decode"):
        out, aux = pipelined_moe(params, tokens, cfg=cfg, ep_size=1,
                                 mode=mode)
        assert out.shape == tokens.shape
        assert jnp.isfinite(out).all()
