"""Serving benchmark: replay a synthetic Poisson arrival trace through
the continuous-batching engine and report throughput, latency
percentiles and KV memory accounting.

    PYTHONPATH=src python benchmarks/serving.py --smoke \
        [--out BENCH_serving.json]

``--smoke`` is the CI configuration (reduced MoE arch on CPU, small
trace) that seeds the perf trajectory: the emitted JSON carries
requests/s, p50/p99 request latency, TTFT and inter-token-latency
percentiles (reported *separately* — folding a preempted-and-resumed
request's stall into a single latency mix hides where time went), peak
``cache_bytes`` in use, and the per-bucket MPipeMoE (n, strategy)
resolutions.

``--overload`` runs the overload scenario instead: calibrate the
sustainable request rate with the admission-blocking baseline, then
replay a Poisson trace at **2x** that rate through a page pool sized for
only ~2 full request budgets — once with the blocking baseline
(``preempt="never"``) and once with the preemptive scheduler — and
report goodput (tokens of requests meeting the baseline's median-TTFT
SLO per second), preemption counts, swap bytes and tail latency. The
preemptive run is also checked token-exact against the dense golden
loop.

``--devices N`` runs the mesh-sharded scenario (default out:
``BENCH_serving_sharded.json``): the same trace is replayed through a
single-device engine and through an engine on an N-device dp x ep mesh
(EP-sharded prefill, replicated psum decode, replicated paged KV — see
``docs/distributed.md``), both over a constrained pool so preemption
fires while sharded; both runs are checked token-exact against the
dense golden loop. On CPU the benchmark re-execs itself with
``--xla_force_host_platform_device_count=N`` when fewer than N devices
are attached:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python benchmarks/serving.py --devices 8 --smoke

``--devices N --kv-sharding dp`` runs the DP-sharded-KV scenario
instead (default out: ``BENCH_serving_dp.json``): replicated vs
DP-sharded pools on the same mesh and trace, reporting (a) per-device
peak KV bytes under the same load with ample pools (sharded is ~1/dp of
replicated) and (b) concurrent requests admitted before the first
preemption at equal **per-device** page budget (sharded admits ~dp×).
All four runs are golden-verified:

    PYTHONPATH=src python benchmarks/serving.py --devices 8 \
        --kv-sharding dp --smoke --slots 8

``--compare-arch`` runs the architecture comparison (default out:
``BENCH_serving_arch.json``): a constant-state recurrent model
(xlstm-1.3b, reduced) and a plain-attention model (h2o-danube-1.8b,
reduced) serve the same burst, both golden-verified, reporting decode
tok/s and the per-slot device bytes at full budget — the recurrent slot
is O(1) in the budget where the paged slot is O(budget):

    PYTHONPATH=src python benchmarks/serving.py --compare-arch --smoke

``--obs-overhead`` runs the telemetry scenario (default out:
``BENCH_obs_overhead.json``): the same burst drained with telemetry
fully off vs fully on (span tracer + live ``/metrics`` exporter scraped
over HTTP), reporting best-of-reps tok/s per leg and the jit-trace
counts of both (telemetry must not add compiles), and writing the
Perfetto trace (``BENCH_obs_trace.json``) plus the scraped Prometheus
exposition (``BENCH_obs_metrics.prom``) as artifacts — see
``docs/observability.md``:

    PYTHONPATH=src python benchmarks/serving.py --obs-overhead --smoke

``--attn-kernel-compare`` runs the paged-attention kernel scenario
(default out: ``BENCH_paged_attention.json``): the same burst drained
once with the fused Pallas paged-decode kernel
(``attn_kernel="pallas"``; interpret mode on CPU) and once with the
``gather_pages`` baseline, both golden-verified and checked
token-identical to each other, reporting decode tok/s, peak KV bytes
and the jit-trace counts per leg (selecting the kernel may not add
compiles) — see ``docs/serving.md``:

    PYTHONPATH=src python benchmarks/serving.py --attn-kernel-compare \
        --smoke

``--prefix-cache-compare`` runs the prefix-cache scenario (default out:
``BENCH_prefix_cache.json``): a multi-turn chat trace — every request
shares one system prompt, and each conversation's second turn
re-submits its full first-turn history plus a short follow-up — served
once with ``--prefix-cache on`` and once ``off``. Both legs are checked
token-identical; the report is the warm-turn page hit rate (skipped
prompt tokens / warm prompt tokens), warm-turn TTFT p50/p99 per leg,
CoW copy counts and the effective-capacity ratio (peak logical slot
pages per distinct physical page) — see ``docs/serving.md``:

    PYTHONPATH=src python benchmarks/serving.py --prefix-cache-compare \
        --smoke

``--ingress-loadgen`` runs the HTTP ingress scenario (default out:
``BENCH_serving_ingress.json``): calibrate the sustainable rate with
the in-process replay path, then drive the same trace through the real
asyncio HTTP/SSE ingress tier (``repro.serve.ingress``) with a
closed-loop client fleet at 1x/2x/4x that rate, once per shed policy —
no shedding, ``reject`` (429 + Retry-After) and ``degrade``
(``max_new_tokens`` clamp) — reporting SLO-goodput per leg (SLO = the
unshedded 1x leg's median client-side TTFT). Hard invariant: every
token streamed over SSE is checked against the in-process replay
outputs (degraded streams as a prefix) — see ``docs/serving.md``:

    PYTHONPATH=src python benchmarks/serving.py --ingress-loadgen \
        --smoke

Every scenario's JSON also embeds a full ``repro.obs`` registry
snapshot under ``"telemetry"``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax

from repro.configs import get_config
from repro.core import resolve_hw
from repro.models import lm
from repro.serve import (Engine, EngineOptions, dense_greedy_reference,
                         poisson_trace, replay, run_poisson)


def _engine_stats(engine, wall_s: float) -> dict:
    s = engine.stats()
    return {
        "wall_s": wall_s,
        "requests_per_s": s["requests_done"] / wall_s,
        "tokens_per_s": s["tokens_generated"] / wall_s,
        "tokens_generated": s["tokens_generated"],
        "p50_latency_s": s["p50_latency_s"],
        "p99_latency_s": s["p99_latency_s"],
        "p50_ttft_s": s["p50_ttft_s"],
        "p99_ttft_s": s["p99_ttft_s"],
        "p50_itl_s": s["p50_itl_s"],
        "p99_itl_s": s["p99_itl_s"],
        "engine_steps": s["engine_steps"],
        "prefill_compiles": s["prefill_compiles"],
        "preempt_recompute": s["preempt_recompute"],
        "preempt_offload": s["preempt_offload"],
        "resumes": s["resumes"],
        "swap_out_bytes": s["swap_out_bytes"],
        "swap_in_bytes": s["swap_in_bytes"],
        "cache_bytes": s["cache_bytes"],
        "peak_kv_used_bytes": s["peak_kv_used_bytes"],
        "per_device_cache_bytes": s["per_device_cache_bytes"],
        "per_device_peak_kv_used_bytes":
            s["per_device_peak_kv_used_bytes"],
        "kv_shards": s["kv_shards"],
        "peak_running_preempt_free": s["peak_running_preempt_free"],
        "resolutions": s["resolutions"],
        # full repro.obs registry snapshot: per-stage step timings,
        # queue/pool gauges, preempt/admit counters — the trajectory
        # gains per-stage breakdowns without bespoke plumbing per key
        "telemetry": engine.obs.registry.snapshot(),
    }


def run(*, arch: str, requests: int, rate: float, slots: int, chunk: int,
        page_size: int, prompt_max: int, gen_max: int, seed: int,
        hw_name: str, time_scale: float, preempt: str = "auto") -> dict:
    cfg = get_config(arch).reduced()
    hw = resolve_hw(hw_name)
    opts = EngineOptions(page_size=page_size, max_slots=slots,
                         max_seq_len=prompt_max + gen_max, chunk=chunk,
                         hw=hw, preempt=preempt)
    engine, wall_s = run_poisson(cfg, opts, requests=requests, rate=rate,
                                 prompt_max=prompt_max, gen_max=gen_max,
                                 seed=seed, time_scale=time_scale)
    return {
        "arch": cfg.name,
        "hw": hw.name,
        "requests": requests,
        "rate_req_s": rate,
        "slots": slots,
        "chunk": chunk,
        "page_size": page_size,
        "preempt": preempt,
        **_engine_stats(engine, wall_s),
    }


# ---------------------------------------------------------------------------
# Telemetry overhead scenario (span tracer + live /metrics on vs off)
# ---------------------------------------------------------------------------

def run_obs_overhead(*, arch: str, requests: int, slots: int, chunk: int,
                     page_size: int, prompt_max: int, gen_max: int,
                     seed: int, hw_name: str, reps: int = 3,
                     trace_out: str = "BENCH_obs_trace.json",
                     metrics_out: str = "BENCH_obs_metrics.prom") -> dict:
    """The same burst drained twice: telemetry fully off (the default
    no-op recorder every test runs under) vs fully on (span tracer plus
    a live ``/metrics`` exporter scraped over HTTP mid-run). Reports
    best-of-``reps`` tok/s per leg — the committed trajectory entry
    pins the <2%% overhead budget — plus the jit-trace counts of both
    legs (must match: telemetry may not add compiles) and writes the
    Perfetto trace and the scraped exposition as artifacts."""
    import urllib.request

    from repro.obs import MetricsServer, Recorder, Tracer

    cfg = get_config(arch).reduced()
    hw = resolve_hw(hw_name)
    params = lm.init(cfg, jax.random.PRNGKey(0))

    def one(obs=None, on_engine=None):
        opts = EngineOptions(page_size=page_size, max_slots=slots,
                             max_seq_len=prompt_max + gen_max,
                             chunk=chunk, hw=hw, obs=obs)
        return run_poisson(cfg, opts, requests=requests, rate=50.0,
                           prompt_max=prompt_max, gen_max=gen_max,
                           seed=seed, time_scale=0.0, params=params,
                           on_engine=on_engine)

    def tok_s(engine, wall_s):
        return sum(len(r.output) for r in engine.done) / wall_s

    tok_off = 0.0
    for _ in range(reps):
        off_engine, wall_s = one()
        tok_off = max(tok_off, tok_s(off_engine, wall_s))

    tok_on, scrape, health = 0.0, "", ""
    for _ in range(reps):
        obs = Recorder(tracer=Tracer())
        holder = {}

        def attach(engine, _obs=obs, _holder=holder):
            _holder["server"] = MetricsServer(
                _obs.registry, port=0,
                refresh=engine._refresh_gauges).start()

        on_engine, wall_s = one(obs, attach)
        server = holder["server"]
        scrape = urllib.request.urlopen(
            server.url + "/metrics", timeout=10).read().decode()
        health = urllib.request.urlopen(
            server.url + "/healthz", timeout=10).read().decode()
        server.stop()
        tok_on = max(tok_on, tok_s(on_engine, wall_s))

    obs.tracer.write(trace_out)
    with open(metrics_out, "w") as f:
        f.write(scrape)
    return {
        "arch": cfg.name,
        "hw": hw.name,
        "requests": requests,
        "slots": slots,
        "chunk": chunk,
        "page_size": page_size,
        "reps": reps,
        "tok_s_off": tok_off,
        "tok_s_on": tok_on,
        "overhead_pct": 100.0 * (1.0 - tok_on / tok_off),
        "decode_traces_off": off_engine.decode_traces,
        "decode_traces_on": on_engine.decode_traces,
        "prefill_traces_off": off_engine.prefill_traces,
        "prefill_traces_on": on_engine.prefill_traces,
        "trace_events": len(obs.tracer.export()["traceEvents"]),
        "trace_out": trace_out,
        "metrics_out": metrics_out,
        "metrics_lines": len(scrape.splitlines()),
        "healthz": health.strip(),
        "telemetry": obs.registry.snapshot(),
    }


def _print_obs(res: dict) -> None:
    print(f"\ntelemetry overhead ({res['arch']} on {res['hw']}, "
          f"{res['requests']}-request burst, best of {res['reps']}):")
    print(f"  off {res['tok_s_off']:.1f} tok/s | on {res['tok_s_on']:.1f} "
          f"tok/s (tracer + live /metrics) -> "
          f"{res['overhead_pct']:+.2f}% overhead")
    print(f"  jit traces off/on: decode {res['decode_traces_off']}/"
          f"{res['decode_traces_on']}, prefill "
          f"{res['prefill_traces_off']}/{res['prefill_traces_on']}")
    print(f"  artifacts: {res['trace_out']} ({res['trace_events']} "
          f"events), {res['metrics_out']} ({res['metrics_lines']} lines, "
          f"healthz={res['healthz']})")


# ---------------------------------------------------------------------------
# Overload scenario (arrival rate >= 2x sustainable)
# ---------------------------------------------------------------------------

def _golden_cfg(arch: str):
    """Config whose paged/chunked execution is bit-exact vs the dense
    loop (float32, no dropped MoE tokens) so overload runs can be
    verified against the golden reference."""
    cfg = get_config(arch).reduced()
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(moe, capacity_factor=8.0)
    return dataclasses.replace(cfg, compute_dtype="float32", moe=moe)


def _dense_refs(cfg, params, trace) -> list:
    """Golden greedy outputs of every trace entry via the dense loop."""
    return [dense_greedy_reference(params, cfg, e.prompt,
                                   e.max_new_tokens) for e in trace]


def _goodput(engine, wall_s: float, slo_ttft_s: float) -> float:
    """Tokens/s of requests whose TTFT met the SLO."""
    good = sum(len(r.output) for r in engine.done
               if r.ttft_s <= slo_ttft_s)
    return good / wall_s


def run_overload(*, arch: str, requests: int, slots: int, chunk: int,
                 page_size: int, prompt_max: int, gen_max: int, seed: int,
                 hw_name: str, preempt: str = "auto",
                 pool_budgets: float = 1.25) -> dict:
    import time

    # pool_budgets sizes the page pool in units of the *maximum* request
    # budget: ~1.25 lets the blocking baseline run only 1-2 requests at
    # a time while the preemptive engine packs all slots on demand
    cfg = _golden_cfg(arch)
    hw = resolve_hw(hw_name)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    budget = prompt_max + gen_max
    pages_per_budget = -(-budget // page_size)
    num_pages = int(pool_budgets * pages_per_budget) + 1
    common = dict(page_size=page_size, max_slots=slots,
                  max_seq_len=budget, chunk=chunk, hw=hw,
                  num_pages=num_pages)
    # one trace, replayed by every engine: generation runs long enough
    # (>= gen_max/2) that page demand, not prefill, dominates occupancy.
    # Arrivals are generated at 1 req/s and rescaled via time_scale below.
    trace = poisson_trace(requests, rate=1.0, vocab_size=cfg.vocab_size,
                          prompt_len_range=(8, prompt_max),
                          gen_len_range=(max(2, gen_max // 2), gen_max),
                          seed=seed)

    def one(preempt_mode: str, time_scale: float):
        opts = EngineOptions(preempt=preempt_mode, **common)
        engine = Engine(cfg, params, options=opts)
        engine.warmup()
        t0 = time.perf_counter()
        replay(engine, trace, time_scale=time_scale)
        return engine, time.perf_counter() - t0

    # phase 1: sustainable rate = the blocking baseline draining a burst
    # (all arrivals at t=0) as fast as it can
    _, cal_wall = one("never", time_scale=0.0)
    sustainable = requests / cal_wall
    rate = 2.0 * sustainable

    # phase 2: both engines replay the same trace with arrivals rescaled
    # to 2x the sustainable rate, in real time
    ts = 1.0 / rate
    block_engine, block_wall = one("never", time_scale=ts)
    pre_engine, pre_wall = one(preempt, time_scale=ts)

    # token-exactness of the preemptive run vs the dense golden loop
    refs = _dense_refs(cfg, params, trace)
    outs = [r.output for r in sorted(pre_engine.done, key=lambda r: r.rid)]
    token_exact = outs == refs

    # goodput SLO: the blocking baseline's own median TTFT — by
    # construction half the baseline's requests meet it
    slo = block_engine.stats()["p50_ttft_s"]
    block = dict(_engine_stats(block_engine, block_wall),
                 goodput_tok_s=_goodput(block_engine, block_wall, slo))
    pre = dict(_engine_stats(pre_engine, pre_wall),
               goodput_tok_s=_goodput(pre_engine, pre_wall, slo))
    return {
        "scenario": "overload",
        "arch": cfg.name,
        "hw": hw.name,
        "requests": requests,
        "slots": slots,
        "chunk": chunk,
        "page_size": page_size,
        "num_pages": num_pages,
        "pool_budgets": pool_budgets,
        "sustainable_req_s": sustainable,
        "overload_rate_req_s": rate,
        "overload_factor": 2.0,
        "slo_ttft_s": slo,
        "preempt_policy": preempt,
        "token_exact": token_exact,
        "blocking": block,
        "preemptive": pre,
        "goodput_ratio": (pre["goodput_tok_s"]
                          / max(block["goodput_tok_s"], 1e-12)),
    }


# ---------------------------------------------------------------------------
# Mesh-sharded scenario (--devices N)
# ---------------------------------------------------------------------------

def run_sharded(*, arch: str, devices: int, requests: int, slots: int,
                chunk: int, page_size: int, prompt_max: int, gen_max: int,
                seed: int, hw_name: str, preempt: str = "auto",
                pool_budgets: float = 1.25) -> dict:
    """Single-device vs mesh-sharded engine over one trace, both golden-
    verified. The pool is constrained (like --overload) so the sharded
    run also exercises preemption — offload round-trips must survive the
    replicated pools."""
    import time

    cfg = _golden_cfg(arch)
    hw = resolve_hw(hw_name)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    budget = prompt_max + gen_max
    pages_per_budget = -(-budget // page_size)
    num_pages = int(pool_budgets * pages_per_budget) + 1
    common = dict(page_size=page_size, max_slots=slots, max_seq_len=budget,
                  chunk=chunk, hw=hw, num_pages=num_pages, preempt=preempt)
    trace = poisson_trace(requests, rate=1.0, vocab_size=cfg.vocab_size,
                          prompt_len_range=(8, prompt_max),
                          gen_len_range=(max(2, gen_max // 2), gen_max),
                          seed=seed)
    refs = _dense_refs(cfg, params, trace)

    def one(n_devices: int):
        opts = EngineOptions(devices=n_devices, **common)
        engine = Engine(cfg, params, options=opts)
        engine.warmup()
        t0 = time.perf_counter()
        replay(engine, trace, time_scale=0.0)       # drain a burst
        wall = time.perf_counter() - t0
        outs = [r.output
                for r in sorted(engine.done, key=lambda r: r.rid)]
        return engine, wall, outs == refs

    single_engine, single_wall, single_exact = one(0)
    sharded_engine, sharded_wall, sharded_exact = one(devices)
    s = sharded_engine.stats()
    return {
        "scenario": "sharded",
        "arch": cfg.name,
        "hw": hw.name,
        "devices": devices,
        "ep_size": s["ep_size"],
        "dp_size": s["dp_size"],
        "requests": requests,
        "slots": slots,
        "chunk": chunk,
        "page_size": page_size,
        "num_pages": num_pages,
        "preempt_policy": preempt,
        "token_exact": sharded_exact,
        "token_exact_single": single_exact,
        "single": _engine_stats(single_engine, single_wall),
        "sharded": _engine_stats(sharded_engine, sharded_wall),
        # virtual CPU devices make this < 1; on real accelerators it is
        # the EP-parallel prefill speedup
        "sharded_vs_single_tok_s": (
            (s["tokens_generated"] / sharded_wall)
            / max(single_engine.stats()["tokens_generated"]
                  / single_wall, 1e-12)),
    }


# ---------------------------------------------------------------------------
# DP-sharded KV scenario (--devices N --kv-sharding dp)
# ---------------------------------------------------------------------------

def run_dp(*, arch: str, devices: int, requests: int, slots: int,
           chunk: int, page_size: int, prompt_max: int, gen_max: int,
           seed: int, hw_name: str, pool_budgets: float = 1.25) -> dict:
    """Replicated vs DP-sharded paged KV pools on the same mesh, same
    trace, every run golden-verified. Two paired comparisons measure the
    two halves of the headline claim:

    * **ample pools** (worst-case sizing, nothing preempts): the same
      workload's peak KV residency per device — DP-sharded is
      ``~1/dp`` of replicated, because each device holds only its
      shard's pages instead of every page;
    * **constrained pools at equal per-device budget** (the blocking
      ``preempt="never"`` baseline, so admission capacity is the thing
      measured): replicated can use only one device's worth of pages
      globally, DP-sharded aggregates ``dp`` of them — it admits
      ``~dp×`` the concurrent requests before anything would preempt.

    The trace is **uniform-budget** (every request is prompt_max +
    gen_max) so the capacity comparison is structural, not
    trace-lottery: each engine admits exactly
    ``floor(usable_pages / budget_pages)`` requests per shard.
    """
    import time

    cfg = _golden_cfg(arch)
    hw = resolve_hw(hw_name)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    budget = prompt_max + gen_max
    pages_per_budget = -(-budget // page_size)
    # per-DEVICE page budget for the constrained comparison (~1.25
    # request budgets, like --overload)
    per_dev_pages = int(pool_budgets * pages_per_budget) + 1
    common = dict(page_size=page_size, max_slots=slots, max_seq_len=budget,
                  chunk=chunk, hw=hw, devices=devices)
    trace = poisson_trace(requests, rate=1.0, vocab_size=cfg.vocab_size,
                          prompt_len_range=(prompt_max, prompt_max),
                          gen_len_range=(gen_max, gen_max),
                          seed=seed)
    refs = _dense_refs(cfg, params, trace)

    def one(kv_sharding: str, num_pages: int, preempt: str):
        opts = EngineOptions(kv_sharding=kv_sharding, num_pages=num_pages,
                             preempt=preempt, **common)
        engine = Engine(cfg, params, options=opts)
        engine.warmup()
        t0 = time.perf_counter()
        replay(engine, trace, time_scale=0.0)       # drain a burst
        wall = time.perf_counter() - t0
        outs = [r.output
                for r in sorted(engine.done, key=lambda r: r.rid)]
        return dict(_engine_stats(engine, wall), token_exact=outs == refs,
                    num_pages=engine.kv.num_pages), engine

    # ample pools: measure per-device peak residency of the same load
    amp_repl, eng = one("replicated", 0, "auto")
    dp_size = eng.stats()["dp_size"]
    amp_dp, _ = one("dp", 0, "auto")
    # constrained pools at equal per-device budget: measure admission
    # capacity with the blocking baseline (no preemption noise)
    con_repl, _ = one("replicated", per_dev_pages, "never")
    con_dp, _ = one("dp", dp_size * per_dev_pages, "never")
    return {
        "scenario": "serving_dp",
        "arch": cfg.name,
        "hw": hw.name,
        "devices": devices,
        "dp_size": dp_size,
        "ep_size": eng.stats()["ep_size"],
        "requests": requests,
        "slots": slots,
        "chunk": chunk,
        "page_size": page_size,
        "per_device_pool_pages": per_dev_pages,
        "token_exact": all(r["token_exact"] for r in
                           (amp_repl, amp_dp, con_repl, con_dp)),
        "ample": {
            "replicated": amp_repl,
            "dp": amp_dp,
            # the headline: per-device peak KV bytes under the same load
            "per_device_peak_ratio": (
                amp_dp["per_device_peak_kv_used_bytes"]
                / max(amp_repl["per_device_peak_kv_used_bytes"], 1)),
        },
        "constrained": {
            "replicated": con_repl,
            "dp": con_dp,
            # concurrent requests admitted before the first would-be
            # preemption, at equal per-device page budget
            "admitted_replicated": con_repl["peak_running_preempt_free"],
            "admitted_dp": con_dp["peak_running_preempt_free"],
            "admitted_ratio": (con_dp["peak_running_preempt_free"]
                               / max(con_repl["peak_running_preempt_free"],
                                     1)),
        },
    }


def _print_dp(res: dict) -> None:
    a, c = res["ample"], res["constrained"]
    print(f"\nserving_dp: {res['arch']} on {res['hw']}, "
          f"{res['devices']} devices = dp {res['dp_size']} x "
          f"ep {res['ep_size']}, {res['requests']} requests")
    print(f"  ample pools   — per-device peak KV: "
          f"replicated {a['replicated']['per_device_peak_kv_used_bytes']/2**20:.2f}MiB"
          f" vs dp {a['dp']['per_device_peak_kv_used_bytes']/2**20:.2f}MiB"
          f" ({a['per_device_peak_ratio']:.2f}x, ~1/dp expected)")
    print(f"  equal budget  — concurrent requests before first "
          f"preemption: replicated {c['admitted_replicated']} vs dp "
          f"{c['admitted_dp']} ({c['admitted_ratio']:.1f}x, ~dp "
          f"expected) at {res['per_device_pool_pages']} pages/device")
    print(f"  token-exact vs dense golden (all 4 runs): "
          f"{res['token_exact']}")


def _print_sharded(res: dict) -> None:
    print(f"\nsharded: {res['arch']} on {res['hw']}, "
          f"{res['devices']} devices = dp {res['dp_size']} x "
          f"ep {res['ep_size']}, {res['requests']} requests, "
          f"pool {res['num_pages']} pages")
    for name in ("single", "sharded"):
        r = res[name]
        print(f"  {name:8s}: {r['tokens_per_s']:8.1f} tok/s | "
              f"ttft p50 {r['p50_ttft_s']*1e3:.0f}ms | "
              f"itl p50 {r['p50_itl_s']*1e3:.1f}ms | "
              f"preempts {r['preempt_recompute']}r/"
              f"{r['preempt_offload']}o | "
              f"{r['prefill_compiles']} prefill compiles")
    print(f"  sharded/single tok/s: {res['sharded_vs_single_tok_s']:.2f}x"
          f" | token-exact vs dense golden: sharded={res['token_exact']} "
          f"single={res['token_exact_single']}")


# ---------------------------------------------------------------------------
# Architecture comparison (--compare-arch): recurrent vs plain-attn
# ---------------------------------------------------------------------------

ARCH_COMPARE = ("xlstm-1.3b", "h2o-danube-1.8b")


def _slot_bytes(engine, budget: int) -> int:
    """Device-cache bytes one request holds at its full token budget —
    the admission-relevant per-slot cost. Paged caches grow with the
    budget; constant-state caches hold one fixed slot row."""
    kv = engine.kv
    if engine.cache_kind == "paged":
        return kv.pages_for(budget) * kv.page_bytes
    if engine.cache_kind == "constant":
        return kv.cache_bytes // kv.max_slots
    return (kv.paged.pages_for(budget) * kv.paged.page_bytes
            + kv.state.cache_bytes // kv.state.max_slots)


def run_arch_compare(*, requests: int, slots: int, chunk: int,
                     page_size: int, prompt_max: int, gen_max: int,
                     seed: int, hw_name: str,
                     archs=ARCH_COMPARE) -> dict:
    """Constant-state recurrent serving vs paged plain-attention serving
    over the same request shape, both golden-verified. The headline
    numbers are decode tok/s and the per-slot device bytes at full
    budget: a recurrent slot is O(1) in the budget while a paged slot is
    O(budget), which is the whole admission-capacity story."""
    import time

    hw = resolve_hw(hw_name)
    budget = prompt_max + gen_max
    out = {}
    for arch in archs:
        cfg = _golden_cfg(arch)
        params = lm.init(cfg, jax.random.PRNGKey(0))
        opts = EngineOptions(page_size=page_size, max_slots=slots,
                             max_seq_len=budget, chunk=chunk, hw=hw)
        engine = Engine(cfg, params, options=opts)
        engine.warmup()
        trace = poisson_trace(requests, rate=1.0,
                              vocab_size=cfg.vocab_size,
                              prompt_len_range=(8, prompt_max),
                              gen_len_range=(max(2, gen_max // 2),
                                             gen_max),
                              seed=seed)
        refs = _dense_refs(cfg, params, trace)
        for e in trace:
            engine.submit(e.prompt, max_new_tokens=e.max_new_tokens,
                          arrival_s=0.0)
        decode_s = prefill_s = 0.0
        decode_toks = 0
        t0 = time.perf_counter()
        while engine.has_work:                 # drain a burst, timing
            s0 = time.perf_counter()           # each step kind apart
            info = engine.step()
            dt = time.perf_counter() - s0
            if info["kind"] == "decode":
                decode_s += dt
                decode_toks += info["tokens"]
            elif info["kind"] == "prefill":
                prefill_s += dt
        wall = time.perf_counter() - t0
        outs = [r.output
                for r in sorted(engine.done, key=lambda r: r.rid)]
        slot_bytes = _slot_bytes(engine, budget)
        out[arch] = {
            "cache_kind": engine.cache_kind,
            "token_exact": outs == refs,
            "tokens_per_s": sum(len(r.output) for r in engine.done)
            / wall,
            "decode_tok_s": decode_toks / max(decode_s, 1e-12),
            "decode_s": decode_s,
            "prefill_s": prefill_s,
            "cache_bytes": engine.kv.cache_bytes,
            "slot_bytes_at_budget": slot_bytes,
            "bytes_per_cached_token": slot_bytes / budget,
            "prefill_compiles": engine.prefill_rejits,
            "decode_traces": engine.decode_traces,
        }
    recurrent, attn = archs
    return {
        "scenario": "serving_arch",
        "hw": hw.name,
        "requests": requests,
        "slots": slots,
        "chunk": chunk,
        "page_size": page_size,
        "budget_tokens": budget,
        "recurrent_arch": recurrent,
        "attn_arch": attn,
        "archs": out,
        "token_exact": all(a["token_exact"] for a in out.values()),
        # how many x smaller one recurrent slot is than one paged slot
        # at the same token budget
        "slot_bytes_ratio": (out[attn]["slot_bytes_at_budget"]
                             / max(out[recurrent]["slot_bytes_at_budget"],
                                   1)),
    }


def _print_arch(res: dict) -> None:
    print(f"\nserving_arch: {res['recurrent_arch']} (recurrent) vs "
          f"{res['attn_arch']} (plain attn) on {res['hw']}, "
          f"{res['requests']} requests, budget {res['budget_tokens']} "
          f"tokens")
    for arch, r in res["archs"].items():
        print(f"  {arch:18s} [{r['cache_kind']:9s}]: "
              f"decode {r['decode_tok_s']:8.1f} tok/s | "
              f"slot@budget {r['slot_bytes_at_budget']/2**10:.1f}KiB "
              f"({r['bytes_per_cached_token']:.1f} B/token) | "
              f"token-exact {r['token_exact']}")
    print(f"  paged/recurrent slot bytes: {res['slot_bytes_ratio']:.1f}x")


# ---------------------------------------------------------------------------
# Paged-attention kernel comparison (--attn-kernel-compare)
# ---------------------------------------------------------------------------

def run_attn_kernel_compare(*, arch: str, requests: int, slots: int,
                            chunk: int, page_size: int, prompt_max: int,
                            gen_max: int, seed: int, hw_name: str) -> dict:
    """Fused Pallas paged-decode kernel vs the gather baseline over one
    burst, same engine geometry, both golden-verified. The contract is
    bit-identical tokens (the exactness tier pins it at kernel level;
    this pins it end-to-end on a real trace) at identical jit-trace
    counts; the perf split reported is decode tok/s and peak KV bytes.
    On CPU the Pallas leg runs in interpret mode, so its tok/s is an
    exactness datapoint, not a speedup claim — the kernel's win is
    shard-local page reads on the mesh (no gathered-KV materialization
    or cross-shard KV collectives, see docs/serving.md)."""
    import time

    cfg = _golden_cfg(arch)
    hw = resolve_hw(hw_name)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    trace = poisson_trace(requests, rate=1.0, vocab_size=cfg.vocab_size,
                          prompt_len_range=(8, prompt_max),
                          gen_len_range=(max(2, gen_max // 2), gen_max),
                          seed=seed)
    refs = _dense_refs(cfg, params, trace)

    legs, outs = {}, {}
    for kern in ("gather", "pallas"):
        opts = EngineOptions(page_size=page_size, max_slots=slots,
                             max_seq_len=prompt_max + gen_max,
                             chunk=chunk, hw=hw, attn_kernel=kern)
        engine = Engine(cfg, params, options=opts)
        engine.warmup()
        for e in trace:
            engine.submit(e.prompt, max_new_tokens=e.max_new_tokens,
                          arrival_s=0.0)
        decode_s, decode_toks = 0.0, 0
        t0 = time.perf_counter()
        while engine.has_work:                 # drain a burst, timing
            s0 = time.perf_counter()           # decode steps apart
            info = engine.step()
            if info["kind"] == "decode":
                decode_s += time.perf_counter() - s0
                decode_toks += info["tokens"]
        wall = time.perf_counter() - t0
        outs[kern] = [r.output
                      for r in sorted(engine.done, key=lambda r: r.rid)]
        legs[kern] = dict(
            _engine_stats(engine, wall),
            token_exact=outs[kern] == refs,
            decode_tok_s=decode_toks / max(decode_s, 1e-12),
            decode_s=decode_s,
            decode_traces=engine.decode_traces,
            prefill_traces=engine.prefill_traces,
            attn_kernel=engine.stats()["attn_kernel"])
    return {
        "scenario": "paged_attention",
        "arch": cfg.name,
        "hw": hw.name,
        "requests": requests,
        "slots": slots,
        "chunk": chunk,
        "page_size": page_size,
        "tokens_equal": outs["pallas"] == outs["gather"],
        "token_exact": all(l["token_exact"] for l in legs.values()),
        "traces_equal": all(
            legs["pallas"][k] == legs["gather"][k]
            for k in ("decode_traces", "prefill_traces")),
        "kernel_vs_gather_decode_tok_s": (
            legs["pallas"]["decode_tok_s"]
            / max(legs["gather"]["decode_tok_s"], 1e-12)),
        "pallas": legs["pallas"],
        "gather": legs["gather"],
    }


def _print_attn_kernel(res: dict) -> None:
    print(f"\npaged_attention: {res['arch']} on {res['hw']}, "
          f"{res['requests']} requests, {res['slots']} slots, "
          f"page {res['page_size']}")
    for kern in ("gather", "pallas"):
        r = res[kern]
        print(f"  {kern:7s}: decode {r['decode_tok_s']:8.1f} tok/s | "
              f"peak KV {r['peak_kv_used_bytes']/2**20:.2f}MiB | "
              f"decode traces {r['decode_traces']} | "
              f"token-exact {r['token_exact']}")
    print(f"  tokens pallas==gather: {res['tokens_equal']} | jit "
          f"counts equal: {res['traces_equal']} | "
          f"pallas/gather decode tok/s: "
          f"{res['kernel_vs_gather_decode_tok_s']:.2f}x "
          f"(interpret mode on CPU)")


# ---------------------------------------------------------------------------
# Prefix-cache scenario (--prefix-cache-compare)
# ---------------------------------------------------------------------------

def run_prefix_compare(*, arch: str, requests: int, slots: int,
                       chunk: int, page_size: int, prompt_max: int,
                       gen_max: int, seed: int, hw_name: str) -> dict:
    """Cross-request prefix cache on vs off over a chat-shaped trace:
    every request shares one system prompt, and after the first turn
    drains each conversation re-submits its full history plus a short
    follow-up (the multi-turn pattern the cache exists for). Both legs
    replay the identical trace and must emit bit-identical tokens; the
    perf split reported is the warm-turn page hit rate, warm-turn TTFT
    p50/p99 (the hit skips the history's prefill), CoW copies, and the
    effective-capacity ratio (peak logical pages bound across slots /
    distinct physical pages — shared pages count once, so the same pool
    holds more conversations)."""
    import time

    import numpy as np

    cfg = _golden_cfg(arch)
    hw = resolve_hw(hw_name)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.Generator(np.random.Philox(key=seed))
    sys_len = max(3 * page_size, (2 * prompt_max) // 3)
    system = rng.integers(0, cfg.vocab_size, size=sys_len, dtype=np.int32)
    user_max = max(3, prompt_max - sys_len)
    turn1 = [np.concatenate([system, rng.integers(
        0, cfg.vocab_size, size=int(rng.integers(2, user_max + 1)),
        dtype=np.int32)]) for _ in range(requests)]
    gens = [int(rng.integers(max(2, gen_max // 2), gen_max + 1))
            for _ in range(requests)]
    follow = [rng.integers(0, cfg.vocab_size,
                           size=int(rng.integers(2, 9)), dtype=np.int32)
              for _ in range(requests)]

    def one(mode: str):
        opts = EngineOptions(
            page_size=page_size, max_slots=slots,
            max_seq_len=prompt_max + 2 * gen_max + 16,
            chunk=chunk, hw=hw, prefix_cache=mode)
        eng = Engine(cfg, params, options=opts)
        eng.warmup()
        peak_sharing = 1.0

        def drain():
            # effective capacity: logical pages bound across running
            # slots over distinct physical pages — >1 means the pool is
            # serving more conversation-pages than it holds (the trie's
            # retained pages are deliberately excluded: retention is a
            # cache, sharing is the capacity win)
            nonlocal peak_sharing
            while eng.has_work:
                eng.step()
                held = [p for s in list(eng.scheduler.running)
                        for p in eng.kv._slot_pages[s]]
                if held:
                    peak_sharing = max(peak_sharing,
                                       len(held) / len(set(held)))

        t0 = time.perf_counter()
        r1 = [eng.submit(p, max_new_tokens=g,
                         arrival_s=time.perf_counter())
              for p, g in zip(turn1, gens)]
        drain()
        cold = dict(eng.stats())
        turn2 = [np.concatenate([p, np.asarray(r.output, np.int32), f])
                 for p, r, f in zip(turn1, r1, follow)]
        r2 = [eng.submit(p, max_new_tokens=g,
                         arrival_s=time.perf_counter())
              for p, g in zip(turn2, gens)]
        drain()
        wall = time.perf_counter() - t0
        s = eng.stats()
        warm_ttft = sorted(r.ttft_s for r in r2)
        warm_tokens = sum(len(p) for p in turn2)
        leg = dict(
            _engine_stats(eng, wall),
            warm_hit_tokens=(s["prefix_hit_tokens"]
                             - cold["prefix_hit_tokens"]),
            warm_hits=s["prefix_hits"] - cold["prefix_hits"],
            warm_prompt_tokens=warm_tokens,
            warm_hit_rate=(s["prefix_hit_tokens"]
                           - cold["prefix_hit_tokens"]) / warm_tokens,
            warm_ttft_p50_s=warm_ttft[len(warm_ttft) // 2],
            warm_ttft_p99_s=warm_ttft[-1],
            peak_page_sharing_x=peak_sharing,
            prefix_hits=s["prefix_hits"],
            prefix_hit_rate=s["prefix_hit_rate"],
            prefix_cow_copies=s["prefix_cow_copies"],
            prefix_evicted_pages=s["prefix_evicted_pages"])
        outs = ([list(r.output) for r in r1]
                + [list(r.output) for r in r2])
        if mode == "on":
            eng.kv.check_integrity()
        return leg, outs

    legs, outs = {}, {}
    for mode in ("off", "on"):
        legs[mode], outs[mode] = one(mode)
    return {
        "scenario": "prefix_cache",
        "arch": cfg.name,
        "hw": hw.name,
        "requests": requests,
        "turns": 2,
        "slots": slots,
        "chunk": chunk,
        "page_size": page_size,
        "system_prompt_len": sys_len,
        "tokens_equal": outs["on"] == outs["off"],
        "warm_hit_rate": legs["on"]["warm_hit_rate"],
        "effective_capacity_x": legs["on"]["peak_page_sharing_x"],
        "warm_ttft_p50_ratio": (
            legs["on"]["warm_ttft_p50_s"]
            / max(legs["off"]["warm_ttft_p50_s"], 1e-12)),
        "on": legs["on"],
        "off": legs["off"],
    }


def _print_prefix(res: dict) -> None:
    print(f"\nprefix_cache: {res['arch']} on {res['hw']}, "
          f"{res['requests']} conversations x {res['turns']} turns, "
          f"shared system prompt {res['system_prompt_len']} tokens, "
          f"page {res['page_size']}")
    for mode in ("off", "on"):
        r = res[mode]
        print(f"  {mode:3s}: warm-turn TTFT "
              f"p50 {r['warm_ttft_p50_s']*1e3:7.0f}ms "
              f"p99 {r['warm_ttft_p99_s']*1e3:7.0f}ms | "
              f"peak KV {r['per_device_peak_kv_used_bytes']/2**20:.2f}"
              f"MiB | hits {r['prefix_hits']} | "
              f"CoW {r['prefix_cow_copies']}")
    on = res["on"]
    print(f"  warm-turn hit rate: {100*res['warm_hit_rate']:.0f}% "
          f"({on['warm_hit_tokens']}/{on['warm_prompt_tokens']} prompt "
          f"tokens skipped) | effective capacity "
          f"{res['effective_capacity_x']:.2f}x | warm TTFT on/off "
          f"{res['warm_ttft_p50_ratio']:.2f}x | tokens on==off: "
          f"{res['tokens_equal']}")


# ---------------------------------------------------------------------------
# Ingress load-generation scenario (--ingress-loadgen)
# ---------------------------------------------------------------------------

def run_ingress_loadgen(*, arch: str, requests: int, slots: int,
                        chunk: int, page_size: int, prompt_max: int,
                        gen_max: int, seed: int, hw_name: str,
                        factors=(1.0, 2.0, 4.0),
                        num_clients: int = 12) -> dict:
    """Closed-loop client fleet over the real HTTP/SSE ingress tier.

    Calibrate the sustainable rate by draining the trace as a burst
    through the in-process replay path, then drive the same trace over
    real sockets at ``factors`` x that rate — once per shed policy
    ("none" = an admission bound that never binds, then "reject" and
    "degrade" with a tight bound) — and report SLO-goodput per leg
    (tokens/s of requests whose client-side TTFT met the 1x baseline's
    median). Hard invariant: every streamed token is checked against
    the in-process replay outputs — completed streams exactly,
    degraded streams as a prefix; rejected streams contribute nothing.
    """
    import threading
    import time

    from repro.obs import quantile
    from repro.serve import IngressClient, IngressOptions, IngressServer

    cfg = _golden_cfg(arch)
    hw = resolve_hw(hw_name)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    common = dict(page_size=page_size, max_slots=slots,
                  max_seq_len=prompt_max + gen_max, chunk=chunk, hw=hw)
    # The overload window must be long enough for queue buildup (the
    # thing shedding protects against) to dominate per-request jitter:
    # stretch short traces to at least 8 slots' worth of requests.
    requests = max(requests, 8 * slots)
    # Deliberately decode-dominant: single-chunk prompts and long
    # decodes, so clamping max_new under `degrade` sheds real work (a
    # prefill-heavy mix would leave the degraded leg just as overloaded
    # as the unshedded one).
    trace = poisson_trace(requests, rate=1.0, vocab_size=cfg.vocab_size,
                          prompt_len_range=(4, max(4, min(prompt_max,
                                                          chunk))),
                          gen_len_range=(max(4, (3 * gen_max) // 4),
                                         gen_max),
                          seed=seed)

    # sustainable rate + golden outputs, both from the in-process
    # replay path the SSE streams must match bit for bit
    cal = Engine(cfg, params, options=EngineOptions(**common))
    cal.warmup()
    t0 = time.perf_counter()
    replay(cal, trace, time_scale=0.0)
    cal_wall = time.perf_counter() - t0
    sustainable = requests / cal_wall
    refs = [r.output for r in sorted(cal.done, key=lambda r: r.rid)]

    admission = max(2, slots)
    exact = [True]

    def fleet(policy: str, rate: float):
        """One leg: fresh engine + ingress, num_clients workers
        issuing the trace entries at their rescaled arrival times."""
        opts = IngressOptions(
            admission_queue=(10 * requests if policy == "none"
                             else admission),
            shed_policy=("reject" if policy == "none" else policy),
            degrade_max_new=max(1, gen_max // 4))
        engine = Engine(cfg, params, options=EngineOptions(**common))
        engine.warmup()
        srv = IngressServer(engine, options=opts).start()
        results = [None] * len(trace)
        pending = iter(range(len(trace)))
        lock = threading.Lock()
        t_leg = time.perf_counter()

        def worker():
            cli = IngressClient(srv.host, srv.port, timeout=300.0)
            while True:
                with lock:
                    i = next(pending, None)
                if i is None:
                    return
                e = trace[i]
                delay = (e.arrival_s / rate
                         - (time.perf_counter() - t_leg))
                if delay > 0:
                    time.sleep(delay)
                results[i] = cli.generate(
                    e.prompt, max_new_tokens=e.max_new_tokens)

        workers = [threading.Thread(target=worker, daemon=True)
                   for _ in range(num_clients)]
        for w in workers:
            w.start()
        for w in workers:
            w.join()
        wall = time.perf_counter() - t_leg
        srv.stop()
        snap = engine.obs.registry.snapshot()
        return results, wall, snap

    def summarize(policy: str, factor: float, results, wall, snap):
        completed = rejected = degraded = tokens = 0
        per_req = []                     # (client ttft, token count)
        for i, res in enumerate(results):
            if res.status == 429:
                rejected += 1
                continue
            if res.status != 200:
                exact[0] = False         # nothing else may fail here
                continue
            ref = refs[i]
            if res.degraded:
                degraded += 1
                ok = bool(res.tokens) and res.tokens == ref[:len(
                    res.tokens)]
            else:
                ok = res.tokens == ref
            if not ok:
                exact[0] = False
            completed += 1
            tokens += len(res.tokens)
            per_req.append((res.ttft_s, len(res.tokens)))
        ttfts = [t for t, _ in per_req]
        return {
            "policy": policy, "factor": factor,
            "rate_req_s": factor * sustainable, "wall_s": wall,
            "completed": completed, "rejected": rejected,
            "degraded": degraded, "tokens": tokens,
            "tokens_per_s": tokens / wall,
            "p50_ttft_s": quantile(ttfts, 50.0),
            "p99_ttft_s": quantile(ttfts, 99.0),
            "ingress": {k: v for k, v in snap.items()
                        if k.startswith("repro_ingress")},
            "_per_req": per_req,
        }

    legs = []
    for factor in factors:
        for policy in ("none", "reject", "degrade"):
            results, wall, snap = fleet(policy, factor * sustainable)
            legs.append(summarize(policy, factor, results, wall, snap))

    # SLO = twice the unshedded 1x leg's median client-side TTFT
    # ("within 2x unloaded latency"); goodput of every leg is measured
    # against that one bar
    slo = 2.0 * next(l["p50_ttft_s"] for l in legs
                     if l["policy"] == "none" and l["factor"] == factors[0])
    for leg in legs:
        good = sum(n for t, n in leg.pop("_per_req") if t <= slo)
        leg["goodput_tok_s"] = good / leg["wall_s"]
    by = {(l["policy"], l["factor"]): l for l in legs}
    ratios = {
        pol: (by[(pol, 2.0)]["goodput_tok_s"]
              / max(by[("none", 2.0)]["goodput_tok_s"], 1e-12))
        for pol in ("reject", "degrade")
        if (pol, 2.0) in by and ("none", 2.0) in by}
    return {
        "scenario": "ingress_loadgen",
        "arch": cfg.name,
        "hw": hw.name,
        "requests": requests,
        "slots": slots,
        "chunk": chunk,
        "page_size": page_size,
        "num_clients": num_clients,
        "admission_queue": admission,
        "factors": list(factors),
        "sustainable_req_s": sustainable,
        "slo_ttft_s": slo,
        "token_exact": exact[0],
        "legs": legs,
        "goodput_vs_none_at_2x": ratios,
        "telemetry": cal.obs.registry.snapshot(),
    }


def _print_ingress(res: dict) -> None:
    print(f"\ningress_loadgen: {res['arch']} on {res['hw']}, "
          f"{res['requests']} requests over HTTP/SSE x "
          f"{res['num_clients']} clients, sustainable "
          f"{res['sustainable_req_s']:.2f} req/s, SLO "
          f"ttft<={res['slo_ttft_s']*1e3:.0f}ms")
    for leg in res["legs"]:
        print(f"  {leg['policy']:7s} @ {leg['factor']:.0f}x: goodput "
              f"{leg['goodput_tok_s']:8.1f} tok/s | done "
              f"{leg['completed']:3d} rej {leg['rejected']:3d} deg "
              f"{leg['degraded']:3d} | ttft p50 "
              f"{leg['p50_ttft_s']*1e3:6.0f}ms p99 "
              f"{leg['p99_ttft_s']*1e3:6.0f}ms")
    for pol, ratio in sorted(res["goodput_vs_none_at_2x"].items()):
        print(f"  goodput {pol}/none @ 2x: {ratio:.2f}x")
    print(f"  token-exact vs in-process replay: {res['token_exact']}")


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _print_overload(res: dict) -> None:
    print(f"\noverload: {res['arch']} on {res['hw']}, {res['requests']} "
          f"requests @ {res['overload_rate_req_s']:.2f} req/s "
          f"(2x sustainable {res['sustainable_req_s']:.2f}), "
          f"pool {res['num_pages']} pages (~{res['pool_budgets']} budgets)")
    for name in ("blocking", "preemptive"):
        r = res[name]
        print(f"  {name:10s}: goodput {r['goodput_tok_s']:8.1f} tok/s "
              f"(SLO ttft<={res['slo_ttft_s']*1e3:.0f}ms) | "
              f"ttft p50 {r['p50_ttft_s']*1e3:.0f}ms "
              f"p99 {r['p99_ttft_s']*1e3:.0f}ms | "
              f"itl p99 {r['p99_itl_s']*1e3:.1f}ms | "
              f"lat p99 {r['p99_latency_s']*1e3:.0f}ms | "
              f"preempts {r['preempt_recompute']}r/"
              f"{r['preempt_offload']}o | "
              f"swap {r['swap_out_bytes']/2**20:.2f}MiB")
    print(f"  goodput ratio (preemptive/blocking): "
          f"{res['goodput_ratio']:.2f}x | token-exact vs dense golden: "
          f"{res['token_exact']}")


def _print_standard(res: dict) -> None:
    print(f"\n{res['arch']} on {res['hw']}: {res['requests']} requests @ "
          f"{res['rate_req_s']} req/s (Poisson), {res['slots']} slots, "
          f"chunk {res['chunk']}, page {res['page_size']}, "
          f"preempt {res['preempt']}")
    print(f"throughput {res['requests_per_s']:.2f} req/s, "
          f"{res['tokens_per_s']:.1f} tok/s")
    print(f"latency p50 {res['p50_latency_s']*1e3:.0f}ms, "
          f"p99 {res['p99_latency_s']*1e3:.0f}ms; "
          f"TTFT p50 {res['p50_ttft_s']*1e3:.0f}ms, "
          f"p99 {res['p99_ttft_s']*1e3:.0f}ms; "
          f"ITL p50 {res['p50_itl_s']*1e3:.1f}ms, "
          f"p99 {res['p99_itl_s']*1e3:.1f}ms")
    print(f"KV pool {res['cache_bytes']/2**20:.2f}MiB, peak used "
          f"{res['peak_kv_used_bytes']/2**20:.2f}MiB")
    for bucket, (n, strat) in sorted(res["resolutions"].items(),
                                     key=lambda kv: int(kv[0])):
        print(f"  bucket {int(bucket):4d} -> n={n} strategy={strat}")


def main():
    # sizing flags default to None so an explicitly passed value always
    # beats the --smoke profile (argparse can't otherwise distinguish
    # "left unset" from "explicitly passed the default")
    full = dict(requests=32, rate=20.0, slots=8, chunk=32, page_size=8,
                prompt_max=48, gen_max=24)
    smoke = dict(requests=12, rate=50.0, slots=4, chunk=16, page_size=4,
                 prompt_max=32, gen_max=12)
    # the overload scenario replaces `rate` with the calibrated 2x rate,
    # uses fewer requests (each one is also golden-verified) and longer
    # generations (page demand, not prefill, must dominate occupancy)
    over = {"full": dict(requests=16, gen_max=32),
            "smoke": dict(requests=8, gen_max=24)}
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="moe-gpt3-s")
    for name, v in full.items():
        ap.add_argument(f"--{name.replace('_', '-')}", type=type(v),
                        default=None, help=f"default {v} ({smoke[name]} "
                        f"with --smoke)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--hw", default="auto")
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="arrival time multiplier (0 = all at once)")
    # default None so "explicitly asked for a policy" is detectable —
    # the --kv-sharding dp scenario drives its own policies and must
    # reject the flag rather than silently drop it
    ap.add_argument("--preempt", default=None,
                    choices=["auto", "recompute", "offload", "never"])
    ap.add_argument("--overload", action="store_true",
                    help="overload scenario: blocking vs preemptive at "
                         "2x the sustainable rate on a constrained pool")
    ap.add_argument("--devices", type=int, default=0,
                    help="mesh-sharded scenario: single-device vs an "
                         "N-device dp x ep mesh over the same trace "
                         "(0 = off); CPU re-execs with virtual host "
                         "devices when fewer are attached")
    ap.add_argument("--kv-sharding", default="replicated",
                    choices=["replicated", "dp"],
                    help="with --devices N: 'dp' switches to the "
                         "DP-sharded-KV scenario (replicated vs "
                         "dp-sharded pools on the same mesh: per-device "
                         "peak KV bytes and admission capacity at equal "
                         "per-device budget; out defaults to "
                         "BENCH_serving_dp.json)")
    ap.add_argument("--compare-arch", action="store_true",
                    help="architecture scenario: constant-state "
                         "recurrent (xlstm) vs paged plain-attn "
                         "(h2o-danube) serving the same burst, both "
                         "golden-verified (out defaults to "
                         "BENCH_serving_arch.json)")
    ap.add_argument("--attn-kernel-compare", action="store_true",
                    help="paged-attention kernel scenario: fused Pallas "
                         "page-walking decode vs the gather baseline "
                         "over the same burst, both golden-verified and "
                         "token-identical (out defaults to "
                         "BENCH_paged_attention.json)")
    ap.add_argument("--prefix-cache-compare", action="store_true",
                    help="prefix-cache scenario: a multi-turn trace "
                         "with a shared system prompt served with "
                         "--prefix-cache on vs off, both checked "
                         "token-identical, reporting warm-turn page "
                         "hit rate, TTFT p50/p99 and the effective "
                         "capacity ratio (out defaults to "
                         "BENCH_prefix_cache.json)")
    ap.add_argument("--ingress-loadgen", action="store_true",
                    help="HTTP ingress scenario: a closed-loop client "
                         "fleet drives the asyncio SSE ingress at "
                         "1x/2x/4x the calibrated sustainable rate "
                         "under each shed policy, reporting SLO-goodput "
                         "and checking every streamed token against the "
                         "in-process replay path (out defaults to "
                         "BENCH_serving_ingress.json)")
    ap.add_argument("--obs-overhead", action="store_true",
                    help="telemetry scenario: the same burst with "
                         "telemetry off vs span tracer + live /metrics "
                         "on; writes the Perfetto trace and scraped "
                         "exposition as artifacts (out defaults to "
                         "BENCH_obs_overhead.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI configuration")
    ap.add_argument("--out", default=None,
                    help="output JSON (default: BENCH_serving.json / "
                         "BENCH_serving_overload.json / "
                         "BENCH_serving_sharded.json by scenario)")
    args = ap.parse_args()

    if sum(map(bool, (args.overload, args.devices, args.compare_arch,
                      args.obs_overhead, args.attn_kernel_compare,
                      args.prefix_cache_compare,
                      args.ingress_loadgen))) > 1:
        ap.error("--overload, --devices, --compare-arch, "
                 "--obs-overhead, --attn-kernel-compare, "
                 "--prefix-cache-compare and --ingress-loadgen are "
                 "separate scenarios")
    if args.ingress_loadgen and args.preempt is not None:
        ap.error("--ingress-loadgen drives the default policy over an "
                 "ample pool (the cancel/shed machinery, not "
                 "preemption, is under test); --preempt does not apply")
    if args.prefix_cache_compare and args.preempt is not None:
        ap.error("--prefix-cache-compare compares cache legs on the "
                 "default policy (the conformance matrix covers the "
                 "storm legs); --preempt does not apply")
    if args.obs_overhead and args.preempt is not None:
        ap.error("--obs-overhead compares telemetry legs on the default "
                 "policy; --preempt does not apply")
    if args.attn_kernel_compare and args.preempt is not None:
        ap.error("--attn-kernel-compare compares kernel legs on the "
                 "default policy (the conformance matrix covers the "
                 "storm legs); --preempt does not apply")
    if args.compare_arch and args.arch != "moe-gpt3-s":
        ap.error("--compare-arch runs its fixed arch pair "
                 f"({' vs '.join(ARCH_COMPARE)}); --arch does not apply")
    if args.kv_sharding == "dp" and not args.devices:
        ap.error("--kv-sharding dp needs --devices N (the DP-sharded "
                 "scenario runs on a mesh)")
    if args.kv_sharding == "dp" and args.preempt is not None:
        ap.error("--kv-sharding dp drives its own preempt policies "
                 "(auto for the ample-pool runs, never for the "
                 "capacity comparison); --preempt does not apply")
    if args.devices and args.devices < 2:
        ap.error("--devices needs >= 2 devices to compare against the "
                 "single-device engine (0 = off)")
    if args.devices > 1:
        from repro.compat import ensure_host_device_count
        ensure_host_device_count(args.devices)

    profile = smoke if args.smoke else full
    kw = dict(arch=args.arch, seed=args.seed, hw_name=args.hw)
    for name in full:
        v = getattr(args, name)
        kw[name] = profile[name] if v is None else v
    if (args.overload or args.devices or args.compare_arch
            or args.obs_overhead or args.attn_kernel_compare
            or args.prefix_cache_compare or args.ingress_loadgen):
        # these scenarios drive their own arrivals over the constrained-
        # pool sizing profile (the ingress fleet keeps the standard
        # sizing — its pressure comes from the admission queue)
        if args.rate is not None or args.time_scale != 1.0:
            ap.error("--overload/--devices/--compare-arch/--obs-overhead"
                     "/--attn-kernel-compare/--prefix-cache-compare/"
                     "--ingress-loadgen drive their own arrivals; "
                     "--rate/--time-scale do not apply")
        kw.pop("rate")
        if not args.ingress_loadgen:
            for name, v in over["smoke" if args.smoke else "full"].items():
                if getattr(args, name) is None:
                    kw[name] = v
    if args.ingress_loadgen:
        out = args.out or "BENCH_serving_ingress.json"
        res = run_ingress_loadgen(**kw)
        _print_ingress(res)
    elif args.prefix_cache_compare:
        out = args.out or "BENCH_prefix_cache.json"
        res = run_prefix_compare(**kw)
        _print_prefix(res)
    elif args.attn_kernel_compare:
        out = args.out or "BENCH_paged_attention.json"
        res = run_attn_kernel_compare(**kw)
        _print_attn_kernel(res)
    elif args.obs_overhead:
        out = args.out or "BENCH_obs_overhead.json"
        res = run_obs_overhead(**kw)
        _print_obs(res)
    elif args.compare_arch:
        out = args.out or "BENCH_serving_arch.json"
        kw.pop("arch")
        res = run_arch_compare(**kw)
        _print_arch(res)
    elif args.overload:
        out = args.out or "BENCH_serving_overload.json"
        res = run_overload(preempt=args.preempt or "auto", **kw)
        _print_overload(res)
    elif args.devices and args.kv_sharding == "dp":
        out = args.out or "BENCH_serving_dp.json"
        res = run_dp(devices=args.devices, **kw)
        _print_dp(res)
    elif args.devices:
        out = args.out or "BENCH_serving_sharded.json"
        res = run_sharded(devices=args.devices,
                          preempt=args.preempt or "auto",
                          **kw)
        _print_sharded(res)
    else:
        out = args.out or "BENCH_serving.json"
        res = run(time_scale=args.time_scale,
                  preempt=args.preempt or "auto", **kw)
        _print_standard(res)
    with open(out, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
