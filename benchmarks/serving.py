"""Serving benchmark: replay a synthetic Poisson arrival trace through
the continuous-batching engine and report throughput, latency
percentiles and KV memory accounting.

    PYTHONPATH=src python benchmarks/serving.py --smoke \
        [--out BENCH_serving.json]

``--smoke`` is the CI configuration (reduced MoE arch on CPU, small
trace) that seeds the perf trajectory: the emitted JSON carries
requests/s, p50/p99 request latency, p50 TTFT, peak ``cache_bytes`` in
use, and the per-bucket MPipeMoE (n, strategy) resolutions.
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config
from repro.core import resolve_hw
from repro.serve import EngineOptions, run_poisson


def run(*, arch: str, requests: int, rate: float, slots: int, chunk: int,
        page_size: int, prompt_max: int, gen_max: int, seed: int,
        hw_name: str, time_scale: float) -> dict:
    cfg = get_config(arch).reduced()
    hw = resolve_hw(hw_name)
    opts = EngineOptions(page_size=page_size, max_slots=slots,
                         max_seq_len=prompt_max + gen_max, chunk=chunk,
                         hw=hw)
    engine, wall_s = run_poisson(cfg, opts, requests=requests, rate=rate,
                                 prompt_max=prompt_max, gen_max=gen_max,
                                 seed=seed, time_scale=time_scale)
    s = engine.stats()
    ttfts = sorted(r.ttft_s for r in engine.done)
    return {
        "arch": cfg.name,
        "hw": hw.name,
        "requests": requests,
        "rate_req_s": rate,
        "slots": slots,
        "chunk": chunk,
        "page_size": page_size,
        "wall_s": wall_s,
        "requests_per_s": s["requests_done"] / wall_s,
        "tokens_per_s": s["tokens_generated"] / wall_s,
        "tokens_generated": s["tokens_generated"],
        "p50_latency_s": s["p50_latency_s"],
        "p99_latency_s": s["p99_latency_s"],
        "p50_ttft_s": ttfts[len(ttfts) // 2] if ttfts else 0.0,
        "engine_steps": s["engine_steps"],
        "prefill_compiles": s["prefill_compiles"],
        "cache_bytes": s["cache_bytes"],
        "peak_kv_used_bytes": s["peak_kv_used_bytes"],
        "resolutions": s["resolutions"],
    }


def main():
    # sizing flags default to None so an explicitly passed value always
    # beats the --smoke profile (argparse can't otherwise distinguish
    # "left unset" from "explicitly passed the default")
    full = dict(requests=32, rate=20.0, slots=8, chunk=32, page_size=8,
                prompt_max=48, gen_max=24)
    smoke = dict(requests=12, rate=50.0, slots=4, chunk=16, page_size=4,
                 prompt_max=32, gen_max=12)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="moe-gpt3-s")
    for name, v in full.items():
        ap.add_argument(f"--{name.replace('_', '-')}", type=type(v),
                        default=None, help=f"default {v} ({smoke[name]} "
                        f"with --smoke)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--hw", default="auto")
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="arrival time multiplier (0 = all at once)")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI configuration")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()

    profile = smoke if args.smoke else full
    kw = dict(arch=args.arch, seed=args.seed, hw_name=args.hw,
              time_scale=args.time_scale)
    for name in full:
        v = getattr(args, name)
        kw[name] = profile[name] if v is None else v
    res = run(**kw)

    print(f"\n{res['arch']} on {res['hw']}: {res['requests']} requests @ "
          f"{res['rate_req_s']} req/s (Poisson), {res['slots']} slots, "
          f"chunk {res['chunk']}, page {res['page_size']}")
    print(f"throughput {res['requests_per_s']:.2f} req/s, "
          f"{res['tokens_per_s']:.1f} tok/s")
    print(f"latency p50 {res['p50_latency_s']*1e3:.0f}ms, "
          f"p99 {res['p99_latency_s']*1e3:.0f}ms; "
          f"TTFT p50 {res['p50_ttft_s']*1e3:.0f}ms")
    print(f"KV pool {res['cache_bytes']/2**20:.2f}MiB, peak used "
          f"{res['peak_kv_used_bytes']/2**20:.2f}MiB")
    for bucket, (n, strat) in sorted(res["resolutions"].items(),
                                     key=lambda kv: int(kv[0])):
        print(f"  bucket {int(bucket):4d} -> n={n} strategy={strat}")
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
