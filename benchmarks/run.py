"""Benchmark runner: one function per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV (us_per_call empty for analytic
benches; derived is a compact JSON of the row)."""
from __future__ import annotations

import json
import sys


def main() -> None:
    from benchmarks import microbench, paper_figures, roofline

    rows = []
    for fn in paper_figures.ALL:
        rows.extend(fn())
    for fn in microbench.ALL:
        rows.extend(fn())
    try:
        rows.extend(roofline.roofline_rows())
    except Exception as e:                        # dry-run not yet executed
        print(f"# roofline records unavailable: {e}", file=sys.stderr)

    print("name,us_per_call,derived")
    for r in rows:
        name = r.pop("bench")
        sub = "/".join(str(r[k]) for k in ("model", "arch", "variant",
                                           "strategy", "B", "shape", "n",
                                           "N")
                       if k in r and r[k] is not None)
        us = r.pop("us_per_call", "")
        print(f"{name}:{sub},{us},{json.dumps(r, sort_keys=True)}")


if __name__ == "__main__":
    main()
