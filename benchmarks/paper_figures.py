"""One benchmark per MPipeMoE table/figure (paper-validation harness).

All quantities that need real hardware timing use the analytic models
(Eq. 10 + the pipeline simulator) with TPU v5e constants; memory numbers
are exact formula evaluations (Eqs. 1-6) cross-checked against compiled
buffer sizes where possible. Output: ``name,us_per_call,derived`` CSV
rows via ``benchmarks.run``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (MoEMemory, MoEWorkload, Strategy, TPU_V5E,
                        all_costs, make_searcher, select_strategy,
                        simulate, sweep_partitions)

# the paper's Table III layers
PAPER_MODELS = {
    "gpt3-s": (768, 3072),
    "gpt3-xl": (2048, 8192),
    "bert-l": (1024, 4096),
}
EP = 16          # one pod row of the production mesh


def fig2_memory_breakdown() -> List[Dict]:
    """Fig. 2: model-states/activations/temp-buffers ratio vs batch."""
    rows = []
    for name, (m, h) in PAPER_MODELS.items():
        for b in (256, 1024, 4096, 16384):
            mm = MoEMemory(b=b, m=m, h=h, e=64, n=1)
            tot = mm.m_ms + mm.m_act + mm.m_buf
            rows.append({
                "bench": "fig2_memory_breakdown",
                "model": name, "B": b,
                "model_states_pct": round(100 * mm.m_ms / tot, 1),
                "activations_pct": round(100 * mm.m_act / tot, 1),
                "temp_buffers_pct": round(100 * mm.m_buf / tot, 1),
            })
    return rows


def fig8_pipeline_speedup() -> List[Dict]:
    """Fig. 8: PipeMoE (adaptive n) vs serial expert parallelism
    (PipeMoE(n=1) = FastMoE-style synchronous execution)."""
    rows = []
    for name, (m, h) in PAPER_MODELS.items():
        for b in (4096, 8192, 16384, 32768):
            w = MoEWorkload(b=b, m=m, h=h, k=1, ep=EP)
            serial = simulate(w, TPU_V5E, 1, Strategy.NONE)
            sweep = sweep_partitions(w, TPU_V5E, strategy=Strategy.NONE)
            best_n = min(sweep, key=sweep.get)
            rows.append({
                "bench": "fig8_pipeline_speedup",
                "model": name, "B": b, "best_n": best_n,
                "serial_us": round(serial * 1e6, 1),
                "piped_us": round(sweep[best_n] * 1e6, 1),
                "speedup": round(serial / sweep[best_n], 3),
            })
    return rows


def fig9_10_memory_reduction() -> List[Dict]:
    """Fig. 9/10: MPipeMoE memory vs no-reuse baseline + achieved ratio
    vs the Eq. 6 theoretical bound phi."""
    rows = []
    for name, (m, h) in PAPER_MODELS.items():
        for n in (2, 4, 8):
            for b in (4096, 16384, 32768):
                mm = MoEMemory(b=b, m=m, h=h, e=64, n=n)
                baseline = mm.m_ms + mm.m_act_pipe + mm.m_buf_pipe
                reused = baseline - mm.delta_act - mm.delta_buf
                rows.append({
                    "bench": "fig10_memory_ratio",
                    "model": name, "B": b, "n": n,
                    "phi_theory": round(mm.phi, 4),
                    "mem_ratio": round(reused / baseline, 4),
                })
    return rows


def fig12_granularity() -> List[Dict]:
    """Fig. 12: adaptive granularity tracks the best fixed n across B
    (gpt3-xl, as in the paper)."""
    m, h = PAPER_MODELS["gpt3-xl"]
    searcher = make_searcher(
        dataclasses.replace(get_config("moe-gpt3-xl"),
                            d_model=m, d_ff=h),
        EP, TPU_V5E, strategy=Strategy.NONE)
    rows = []
    for b in (2048, 4096, 8192, 16384, 22000, 32768, 65536):
        w = MoEWorkload(b=b, m=m, h=h, k=1, ep=EP)
        sweep = sweep_partitions(w, TPU_V5E, strategy=Strategy.NONE)
        adaptive_n = searcher.best_n(b)
        best_fixed = min(sweep, key=sweep.get)
        rows.append({
            "bench": "fig12_granularity",
            "B": b, "adaptive_n": adaptive_n, "best_fixed_n": best_fixed,
            "adaptive_us": round(sweep[adaptive_n] * 1e6, 1),
            "best_us": round(sweep[best_fixed] * 1e6, 1),
            "regret_pct": round(100 * (sweep[adaptive_n]
                                       / sweep[best_fixed] - 1), 2),
        })
    return rows


def fig13_strategy_overhead() -> List[Dict]:
    """Fig. 13: per-strategy cost across cluster sizes N; the adaptive
    selector must match the per-(N,B) argmin."""
    m, h = PAPER_MODELS["gpt3-xl"]
    rows = []
    for ep in (8, 16, 32, 64):
        for b in (8192, 16384):
            w = MoEWorkload(b=b, m=m, h=h, k=1, ep=ep)
            costs = all_costs(w, TPU_V5E)
            chosen = select_strategy(w, TPU_V5E).value
            best = min((v, k) for k, v in costs.items()
                       if k != "none")[1]
            rows.append({
                "bench": "fig13_strategy_overhead",
                "N": ep, "B": b, "chosen": chosen, "argmin": best,
                "selector_optimal": chosen == best,
                **{f"{k}_us": round(v * 1e6, 1) for k, v in costs.items()},
            })
    return rows


def table2_q_vectors() -> List[Dict]:
    from repro.core import Q_TABLE
    return [{
        "bench": "table2_q_vectors", "strategy": s.value,
        "q_fw": list(Q_TABLE[s][0]), "q_bw": list(Q_TABLE[s][1]),
    } for s in Strategy]


def fig11_memory_time() -> List[Dict]:
    """Fig. 11: memory-time frontier on gpt3-xl — serial vs pipelined vs
    pipelined+reuse (MPipeMoE)."""
    m, h = PAPER_MODELS["gpt3-xl"]
    b = 16384
    w = MoEWorkload(b=b, m=m, h=h, k=1, ep=EP)
    variants = {
        "fastmoe_like(n=1)": (1, Strategy.NONE, 1),
        "pipemoe(n=4)": (4, Strategy.NONE, 4),
        "pipemoe(adaptive)": (None, Strategy.NONE, None),
        "mpipemoe(adaptive)": (None, None, None),
    }
    rows = []
    for name, (n, strat, n_mem) in variants.items():
        if n is None:
            sweep = sweep_partitions(w, TPU_V5E,
                                     strategy=strat or Strategy.S4)
            n = min(sweep, key=sweep.get)
        if strat is None:
            strat = select_strategy(w, TPU_V5E)
        t = simulate(w, TPU_V5E, n, strat)
        mm = MoEMemory(b=b, m=m, h=h, e=64, n=n)
        mem = mm.m_ms + mm.m_act_pipe + mm.m_buf_pipe
        if strat != Strategy.NONE:
            mem -= mm.delta_act + mm.delta_buf
        rows.append({"bench": "fig11_memory_time", "variant": name,
                     "n": n, "strategy": strat.value,
                     "time_us": round(t * 1e6, 1),
                     "mem_mb": round(mem * 4 / 2**20, 1)})
    return rows


ALL = [fig2_memory_breakdown, fig8_pipeline_speedup,
       fig9_10_memory_reduction, fig11_memory_time, fig12_granularity,
       fig13_strategy_overhead, table2_q_vectors]
