"""Roofline table builder: reads the dry-run JSON records (deliverable g)
and emits the per-(arch x shape) three-term table + bottleneck."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List


def load_records(out_dir: str = "experiments/dryrun",
                 tag: str = "singlepod") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, f"{tag}__*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def roofline_rows(out_dir: str = "experiments/dryrun",
                  tag: str = "singlepod") -> List[Dict]:
    rows = []
    for rec in load_records(out_dir, tag):
        if "skipped" in rec or "error" in rec:
            rows.append({"bench": "roofline", "arch": rec["arch"],
                         "shape": rec["shape"],
                         "status": rec.get("skipped", "ERROR")})
            continue
        r = rec["roofline"]
        rows.append({
            "bench": "roofline", "arch": rec["arch"],
            "shape": rec["shape"], "status": "ok",
            "compute_s": round(r["compute_s"], 4),
            "memory_s": round(r["memory_s"], 4),
            "collective_s": round(r["collective_s"], 4),
            "dominant": r["dominant"],
            "useful_ratio": round(rec.get("useful_ratio", 0), 3),
            "moe": rec.get("moe"),
        })
    return rows


def markdown_table(tag: str = "singlepod",
                   out_dir: str = "experiments/dryrun") -> str:
    rows = roofline_rows(out_dir, tag)
    lines = ["| arch | shape | compute_s | memory_s | collective_s | "
             "dominant | useful |",
             "|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status']} | — |")
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']} | "
                f"{r['memory_s']} | {r['collective_s']} | {r['dominant']} "
                f"| {r['useful_ratio']} |")
    return "\n".join(lines)


ALL = [roofline_rows]
