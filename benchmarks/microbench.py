"""Wall-clock microbenchmarks that CAN run on this host (CPU, reduced
configs): kernel interpret-mode checks are correctness-only, so here we
time the pure-JAX layers + the end-to-end reduced train step, giving the
`us_per_call` column real measured numbers.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import SyntheticTokens
from repro.runtime import TrainOptions, init_state, make_train_step


def _time(fn, *args, reps=5) -> float:
    fn(*args)                      # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def bench_reduced_train_steps() -> List[Dict]:
    rows = []
    for name in ("moe-gpt3-s", "llama3-8b", "deepseek-v2-lite-16b"):
        cfg = get_config(name).reduced()
        if cfg.moe is not None:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(
                    cfg.moe, num_partitions=2, memory_reuse_strategy="s4"))
        opts = TrainOptions()
        state = init_state(cfg, jax.random.PRNGKey(0), opts)
        step = jax.jit(make_train_step(cfg, opts))
        ds = SyntheticTokens(cfg, batch=4, seq=32)
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}

        def run(s, b):
            s2, m = step(s, b)
            return m["loss"]
        us = _time(run, state, batch)
        rows.append({"bench": "reduced_train_step", "model": name,
                     "us_per_call": round(us, 1)})
    return rows


def bench_moe_pipeline_variants() -> List[Dict]:
    """Relative cost of n/strategy variants of the reduced MoE layer —
    validates that strategies change time, not correctness (CPU timing;
    the absolute numbers are NOT TPU projections)."""
    from repro.core.pipeline_moe import pipelined_moe
    from repro.models import lm
    rows = []
    base = get_config("moe-gpt3-s").reduced()
    key = jax.random.PRNGKey(0)
    tokens = jax.random.normal(key, (512, base.d_model))
    params = lm.init(base, key)["periods"]
    moe_params = jax.tree_util.tree_map(lambda x: x[0],
                                        params["l1"]["moe"])
    for n in (1, 2, 4):
        for strat in ("none", "s4"):
            cfg = dataclasses.replace(
                base, moe=dataclasses.replace(
                    base.moe, num_partitions=n,
                    memory_reuse_strategy=strat))

            @jax.jit
            def run(p, t):
                def loss(tt):
                    out, _ = pipelined_moe(p, tt, cfg=cfg, ep_size=1,
                                           mode="train")
                    return (out.astype(jnp.float32) ** 2).sum()
                return jax.grad(loss)(t)
            us = _time(run, moe_params, tokens)
            rows.append({"bench": "moe_variant_timing", "n": n,
                         "strategy": strat, "us_per_call": round(us, 1)})
    return rows


ALL = [bench_reduced_train_steps, bench_moe_pipeline_variants]
