"""Replay a varying-batch-size trace through ``train`` and compare the
online adaptive controller (paper §III-C + §III-E) against static
pipeline granularities.

    PYTHONPATH=src python benchmarks/adaptive_controller.py \
        [--steps 24] [--trace 8,16,8,4] [--static 1,4]

Reports, per run: re-jit count, retune count, Algorithm-1 measure calls,
and mean per-step wall time split into cold (first trace cycle, pays
compilation) and warm (steady state). CPU timings — the point is the
controller's re-jit/search economy, not TPU projections.
"""
from __future__ import annotations

import argparse
import dataclasses
import statistics

from repro.configs import get_config
from repro.data import VaryingSyntheticTokens
from repro.runtime import (AdaptiveController, AdaptiveOptions,
                           TrainOptions, train)


def tiny_moe_config(num_partitions: int = 0,
                    strategy: str = "adaptive"):
    base = get_config("moe-gpt3-s").reduced()
    return dataclasses.replace(
        base, num_layers=2, compute_dtype="float32",
        moe=dataclasses.replace(base.moe, num_partitions=num_partitions,
                                memory_reuse_strategy=strategy))


def run_trace(cfg, trace, *, steps: int, seq: int, adaptive):
    ds = VaryingSyntheticTokens(cfg, trace, seq=seq, seed=0)
    opts = TrainOptions(lr=1e-3, warmup=2, total_steps=steps)
    _, hist = train(cfg, steps=steps, batch_source=ds, opts=opts,
                    adaptive=adaptive)
    cold = [h["step_time_s"] for h in hist[:len(trace)]]
    warm = [h["step_time_s"] for h in hist[len(trace):]] or cold
    return hist, statistics.mean(cold), statistics.mean(warm)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--trace", default="8,16,8,4",
                    help="comma-separated batch sizes, cycled")
    ap.add_argument("--static", default="1,4",
                    help="static n baselines to compare against")
    ap.add_argument("--retune-every", type=int, default=0)
    args = ap.parse_args()
    trace = tuple(int(b) for b in args.trace.split(","))
    assert args.steps >= 2 * len(trace), "need >= 2 trace cycles"

    rows = []

    cfg = tiny_moe_config()
    opts = TrainOptions(lr=1e-3, warmup=2, total_steps=args.steps)
    ctl = AdaptiveController(
        cfg, opts, aopts=AdaptiveOptions(retune_every=args.retune_every))
    hist, cold, warm = run_trace(cfg, trace, steps=args.steps,
                                 seq=args.seq, adaptive=ctl)
    resolved = sorted({(h["n"], h["strategy"]) for h in hist})
    rows.append(("adaptive", ctl.rejit_count, ctl.retune_count,
                 ctl.resolver.search_calls, cold, warm))

    for n in (int(x) for x in args.static.split(",")):
        scfg = tiny_moe_config(num_partitions=n, strategy="s4")
        shist, scold, swarm = run_trace(scfg, trace, steps=args.steps,
                                        seq=args.seq, adaptive=False)
        # static path still re-jits per shape (jax.jit's own cache); the
        # distinct shapes in the trace are its compile count
        rows.append((f"static n={n}", len(set(trace)), 0, 0, scold,
                     swarm))

    print(f"\ntrace={trace} steps={args.steps} seq={args.seq} "
          f"retune_every={args.retune_every}")
    print(f"adaptive resolved (n, strategy): {resolved}")
    print(f"{'run':<14}{'rejits':>8}{'retunes':>9}{'measures':>10}"
          f"{'cold ms/step':>14}{'warm ms/step':>14}")
    for name, rejits, retunes, measures, cold, warm in rows:
        print(f"{name:<14}{rejits:>8}{retunes:>9}{measures:>10}"
              f"{cold * 1e3:>14.1f}{warm * 1e3:>14.1f}")


if __name__ == "__main__":
    main()
