"""Perf-iteration harness (§Perf): re-lower one cell under a knob change
and report the roofline delta vs the recorded baseline.

    PYTHONPATH=src python experiments/hillclimb.py jamba_scan
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import dataclasses
import json
import sys

from repro.launch.dryrun import lower_cell


def _moe(cfg, **kw):
    return dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, **kw))


ITERATIONS = {
    # jamba train_4k -------------------------------------------------
    "jamba_scan": dict(
        arch="jamba-1.5-large-398b", shape="train_4k",
        cfg_override=lambda c: _moe(c, pipeline_unroll=False),
        hypothesis="scan-mode chunks accumulate expert-weight grads in "
                   "the loop carry -> ONE dp-psum instead of one per "
                   "chunk (n=16): collective_bytes down ~25%"),
    "jamba_seqpar": dict(
        arch="jamba-1.5-large-398b", shape="train_4k", seq_parallel=True,
        hypothesis="seq-parallel residual: norms + residual math run "
                   "S/16-sharded; fp32 norm-backward chains shrink 16x "
                   "-> memory_s down >25%"),
    "jamba_both": dict(
        arch="jamba-1.5-large-398b", shape="train_4k", seq_parallel=True,
        cfg_override=lambda c: _moe(c, pipeline_unroll=False),
        hypothesis="combine scan chunks + seq-parallel"),
    "jamba_n4": dict(
        arch="jamba-1.5-large-398b", shape="train_4k",
        cfg_override=lambda c: _moe(c, num_partitions=4),
        hypothesis="fewer chunks (4 vs 16): less per-chunk psum traffic "
                   "at the cost of coarser overlap"),
    "jamba_scan_n4": dict(
        arch="jamba-1.5-large-398b", shape="train_4k",
        cfg_override=lambda c: _moe(c, num_partitions=4,
                                    pipeline_unroll=False),
        hypothesis="combine the two confirmed wins: scan buffers (mem "
                   "-33%) + n=4 (coll -40%); expect both to compose"),
    "jamba_n1_none": dict(
        arch="jamba-1.5-large-398b", shape="train_4k",
        cfg_override=lambda c: _moe(c, num_partitions=1,
                                    memory_reuse_strategy="none"),
        hypothesis="paper ablation: no pipelining, no reuse (FastMoE-"
                   "style) — baseline for the paper-faithful comparison"),
    "jamba_zero3": dict(
        arch="jamba-1.5-large-398b", shape="train_4k",
        cfg_override=lambda c: _moe(c, num_partitions=4,
                                    pipeline_unroll=False),
        hypothesis="explicit ZeRO-3 expert-weight gather: one RS of "
                   "weight grads instead of per-chunk psums; composes "
                   "with scan+n4"),
    # arctic train_4k ------------------------------------------------
    "arctic_scan": dict(
        arch="arctic-480b", shape="train_4k",
        cfg_override=lambda c: _moe(c, pipeline_unroll=False),
        hypothesis="128-expert EP: per-chunk grad psums dominate "
                   "collective_s (64s) -> scan mode"),
    "arctic_seqattn_fix": dict(
        arch="arctic-480b", shape="train_4k",
        hypothesis="single-q-chunk flash for the 56-head seq-parallel "
                   "fallback: scores stay S/16-sharded, killing the "
                   "2240x 224MB per-tile ARs (collective_s -60%+) "
                   "[+ ZeRO-3 gather now default]"),
    "arctic_seqpar_scan": dict(
        arch="arctic-480b", shape="train_4k", seq_parallel=True,
        cfg_override=lambda c: _moe(c, pipeline_unroll=False),
        hypothesis="scan chunks + seq-parallel residual"),
    "arctic_capacity1": dict(
        arch="arctic-480b", shape="train_4k",
        cfg_override=lambda c: _moe(c, capacity_factor=1.0,
                                    pipeline_unroll=False),
        hypothesis="cf 1.25->1.0: A2A + expert GEMM bytes down 20%"),
    # qwen2-vl train_4k ----------------------------------------------
    "qwen2vl_seqpar": dict(
        arch="qwen2-vl-2b", shape="train_4k", seq_parallel=True,
        hypothesis="12 heads % 16 != 0 forces seq-sharded attention "
                   "already; seq-parallel residual removes the gather/"
                   "scatter churn around each block"),
    "qwen2vl_remat_dots": dict(
        arch="qwen2-vl-2b", shape="train_4k",
        cfg_override=lambda c: dataclasses.replace(c,
                                                   remat_policy="dots"),
        hypothesis="2B model: full remat wastes recompute (useful 0.26); "
                   "saving matmul outputs trades HBM for -25% flops"),
    "qwen2vl_nothing": dict(
        arch="qwen2-vl-2b", shape="train_4k",
        cfg_override=lambda c: dataclasses.replace(c,
                                                   remat_policy="nothing"),
        hypothesis="2B params: no remat at all — activations fit; "
                   "removes the whole recompute pass"),
}


def main():
    names = sys.argv[1:] or list(ITERATIONS)
    out_dir = "experiments/perf"
    os.makedirs(out_dir, exist_ok=True)
    for name in names:
        it = ITERATIONS[name]
        rec = lower_cell(it["arch"], it["shape"],
                         cfg_override=it.get("cfg_override"),
                         seq_parallel=it.get("seq_parallel", False))
        rec["iteration"] = name
        rec["hypothesis"] = it["hypothesis"]
        base_path = (f"experiments/dryrun/singlepod__{it['arch']}__"
                     f"{it['shape']}.json")
        if os.path.exists(base_path):
            base = json.load(open(base_path))
            if "roofline" in base and "roofline" in rec:
                rec["delta"] = {
                    k: round(rec["roofline"][k] / max(base["roofline"][k],
                                                      1e-12), 3)
                    for k in ("compute_s", "memory_s", "collective_s")}
        with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
            json.dump(rec, f, indent=1)
        r = rec.get("roofline", {})
        print(f"{name:22s} comp={r.get('compute_s', 0):8.2f} "
              f"mem={r.get('memory_s', 0):8.2f} "
              f"coll={r.get('collective_s', 0):8.2f} "
              f"delta={rec.get('delta')}", flush=True)


if __name__ == "__main__":
    main()
