"""Gradient compression for cross-pod reduction: int8 quantization with
error feedback (residual carried to the next step so compression noise is
unbiased over time). Used by the train loop's ``compress_grads`` option —
cross-pod links are the scarcest bandwidth at 1000+ node scale.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_with_feedback", "dequantize_int8", "quantize_int8"]


def quantize_int8(x):
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True) if x.ndim else \
        jnp.abs(x)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, error: Optional[dict]):
    """grads + carried error -> (compressed-and-restored grads, new error).

    The returned grads have passed through int8 round-trip (what the wire
    would carry); the quantization residual becomes the next step's error
    feedback. Leaves with ndim 0/1 pass through uncompressed.
    """
    if error is None:
        error = jax.tree_util.tree_map(lambda g: jnp.zeros_like(
            g, jnp.float32), grads)

    def one(g, e):
        if g.ndim < 2:
            return g, jnp.zeros_like(g, jnp.float32)
        x = g.astype(jnp.float32) + e
        q, s = quantize_int8(x)
        out = dequantize_int8(q, s)
        return out.astype(g.dtype), x - out

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(treedef, [p[0] for p in pairs])
    new_e = jax.tree_util.tree_unflatten(treedef, [p[1] for p in pairs])
    return new_g, new_e
