"""Logical-axis sharding rules with divisibility-aware fallback.

Every parameter spec carries logical axis names ("embed", "mlp",
"experts", ...). Rules map logical names to mesh axes per shape *kind*
(train / prefill / decode); ``spec_for`` drops an axis whenever the dim
size is not divisible by the mesh extent (recorded so the dry-run can log
fallbacks, e.g. arctic's 56 q-heads vs the 16-way model axis).

Layout summary (DESIGN §4):
  train   — batch over dp=(pod,data); experts/mlp/heads/vocab over model;
            d_model dim of params over dp (FSDP / ZeRO-3 gather-at-use).
  prefill — like train, FSDP only for >=30B models.
  decode  — KV-cache sequence over model (flash-decode style); for
            global_batch=1 (long_500k) over (data, model).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import module

__all__ = ["AxisVal", "Rules", "batch_shardings", "cache_shardings",
           "like_params", "make_rules", "param_shardings"]

AxisVal = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass
class Rules:
    table: Dict[str, AxisVal]
    mesh: Mesh
    fallbacks: List[str] = dataclasses.field(default_factory=list)

    def _extent(self, val: AxisVal) -> int:
        if val is None:
            return 1
        axes = (val,) if isinstance(val, str) else val
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def spec_for(self, shape: Tuple[int, ...],
                 axes: Tuple[Optional[str], ...], tag: str = "") -> P:
        entries: List[AxisVal] = []
        used: set = set()
        for dim, name in zip(shape, axes):
            val = self.table.get(name) if name else None
            if val is not None:
                flat = (val,) if isinstance(val, str) else tuple(val)
                flat = tuple(a for a in flat if a not in used)
                val = flat if len(flat) > 1 else (flat[0] if flat else None)
            if val is not None and dim % self._extent(val) != 0:
                self.fallbacks.append(
                    f"{tag}:{name}={dim} !% {val}({self._extent(val)})")
                val = None
            if val is not None:
                flat = (val,) if isinstance(val, str) else tuple(val)
                used.update(flat)
            entries.append(val)
        return P(*entries)

    def sharding_for(self, shape, axes, tag: str = "") -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(shape, axes, tag))


def make_rules(mesh: Mesh, kind: str, *, fsdp: bool = True,
               seq_shard_cache: AxisVal = "model") -> Rules:
    names = mesh.axis_names
    dp: Tuple[str, ...] = tuple(a for a in names if a in ("pod", "data"))
    tp = "model"
    zero = dp if fsdp else None
    # Role-aware rules: contracting dims are NEVER dp-sharded (that would
    # turn FSDP gathers into activation partial-sum all-reduces — observed
    # 10 GiB all-reduces before this split); output dims carry the dp
    # (ZeRO) component, TP dims carry "model".
    table: Dict[str, AxisVal] = {
        "vocab": tp,
        "mlp": (tp,) + dp if fsdp else tp,       # w_up/w_gate output dim
        "mlp_c": tp,                             # w_down contracting dim
        "expert_mlp": zero,                      # dim0 already uses model
        "expert_mlp_c": None,
        "experts": tp,
        "heads": tp,
        "kv_heads": tp,
        "inner": (tp,) + dp if fsdp else tp,     # mamba/xlstm up outputs
        "inner_c": tp,
        "embed": None,                           # contracting / residual
        "embed_out": zero,                       # w_o/w_down output dim
        "kv_lora": None,
        "head_dim": None,
        "layers": None,
        "batch": dp,
        "seq": None,
        "cache_seq": seq_shard_cache if kind == "decode" else None,
        "enc_seq": None,
    }
    return Rules(table=table, mesh=mesh)


# ---------------------------------------------------------------------------
# Tree-level helpers
# ---------------------------------------------------------------------------

def param_shardings(cfg: ArchConfig, rules: Rules, model):
    """NamedSharding tree matching the abstract param tree."""
    specs = model.specs_tree(cfg)
    flat, treedef = jax.tree_util.tree_flatten(specs,
                                               is_leaf=module.is_spec)
    out = [rules.sharding_for(s.shape, s.axes) for s in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def like_params(param_shardings_tree, state_abstract):
    """Sharding for optimizer state: broadcast each param's sharding onto
    its (possibly nested) moment entries by shape-rank matching."""
    def match(sh, leaf):
        spec = sh.spec
        nd = len(leaf.shape)
        entries = list(spec) + [None] * max(0, nd - len(spec))
        entries = entries[:nd]
        # drop entries that no longer divide (e.g. factored vr/vc, q8 scale)
        fixed = []
        for dim, e in zip(leaf.shape, entries):
            ext = 1
            if e is not None:
                axes = (e,) if isinstance(e, str) else e
                for a in axes:
                    ext *= sh.mesh.shape[a]
            fixed.append(e if dim % ext == 0 and dim >= ext else None)
        return NamedSharding(sh.mesh, P(*fixed))
    return match


def batch_shardings(cfg: ArchConfig, shape: ShapeConfig, rules: Rules,
                    abstract_batch):
    def spec(path_name, leaf):
        nd = len(leaf.shape)
        if path_name == "positions3":           # [3, B, S]
            axes = (None, "batch", None)
        elif nd == 2:                            # tokens/labels [B, S]
            axes = ("batch", "seq")
        elif nd == 3:                            # frames/embeds [B, S, M]
            axes = ("batch", "seq", None)
        else:
            axes = (None,) * nd
        return rules.sharding_for(leaf.shape, axes, path_name)
    return {k: spec(k, v) for k, v in abstract_batch.items()}


def cache_shardings(cfg: ArchConfig, rules: Rules, abstract_cache):
    """Sharding tree for the decode cache (structure-matched)."""
    a = cfg.attn

    def leaf_spec(path: str, leaf):
        nd = len(leaf.shape)
        # stacked caches have leading num_periods dim
        if path.endswith("/len") or path.endswith("pos") or nd == 0:
            return rules.sharding_for(leaf.shape, (None,) * nd, path)
        if "/k" in path or "/v" in path:
            if nd == 5:       # [n, B, T, K, D]
                axes = ("layers", "batch", "cache_seq", "kv_heads", None)
            else:             # cross cache or unstacked
                axes = ("layers", "batch", "enc_seq", "kv_heads", None)[:nd]
            return rules.sharding_for(leaf.shape, axes, path)
        if path.endswith("c_kv") or path.endswith("k_rope"):
            axes = ("layers", "batch", "cache_seq", None)[:nd]
            return rules.sharding_for(leaf.shape, axes, path)
        if path.endswith("conv"):
            axes = ("layers", "batch", None, "inner")[:nd]
            return rules.sharding_for(leaf.shape, axes, path)
        if path.endswith("ssm"):
            axes = ("layers", "batch", "inner", None)[:nd]
            return rules.sharding_for(leaf.shape, axes, path)
        # xlstm states c/n/m/h: replicate heads (small)
        axes = ("layers", "batch") + (None,) * (nd - 2)
        return rules.sharding_for(leaf.shape, axes[:nd], path)

    paths_leaves = jax.tree_util.tree_flatten_with_path(abstract_cache)
    flat, treedef = paths_leaves
    out = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", k)) for k in kp)
        out.append(leaf_spec(path, leaf))
    return jax.tree_util.tree_unflatten(treedef, out)
