"""``repro.distributed`` — mesh, sharding and cross-device helpers.

Module map
----------
``context.py``      :class:`DistContext` (mesh + dp/ep/tp axis
                    assignment, divisibility-aware activation
                    constraints) and :func:`make_serving_context`, the
                    dp x ep mesh builder the serving engine uses.
``sharding.py``     Logical-axis parameter/batch/cache sharding rules
                    with divisibility fallback (:class:`Rules`,
                    :func:`make_rules`, tree-level helpers).
``compression.py``  int8 gradient compression with error feedback for
                    cross-pod reduction.

Rule of the house: mesh and ``shard_map`` construction always goes
through ``repro.compat`` (jax 0.4.x ↔ current shims), never ``jax.*``
directly. See ``docs/distributed.md`` for the serving mesh layout.
"""
from repro.distributed.context import (DistContext, constrain, ep_split,
                                       make_serving_context)

__all__ = ["DistContext", "constrain", "ep_split", "make_serving_context"]
