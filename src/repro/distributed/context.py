"""Distribution context threaded through model apply functions.

:class:`DistContext` bundles the device mesh with the logical-axis
assignment (which mesh axes carry data, expert and tensor parallelism)
and provides divisibility-aware activation sharding constraints. It is
the single object the model stack consumes — layers never look at the
mesh directly.

Serving: :func:`make_serving_context` builds the dp x ep mesh the
continuous-batching engine (``repro.serve``) runs on — expert-parallel
prefill through ``pipelined_moe``'s ``sharded`` layout, replicated
psum-combine decode. Mesh construction goes through ``repro.compat``
(never ``jax.*`` mesh calls directly) so jax 0.4.x and current resolve
identically.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax

__all__ = ["DistContext", "constrain", "ep_split", "make_serving_context"]


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: "jax.sharding.Mesh"
    dp_axes: Tuple[str, ...] = ("data",)
    ep_axis: Optional[str] = "model"      # expert-parallel mesh axis
    tp_axis: Optional[str] = "model"      # tensor-parallel mesh axis
    seq_parallel: bool = False            # residual stream seq-sharded
                                          # over tp (Korthikanti-style)

    @property
    def ep_size(self) -> int:
        if self.ep_axis is None:
            return 1
        return self.mesh.shape[self.ep_axis]

    @property
    def tp_size(self) -> int:
        if self.tp_axis is None:
            return 1
        return self.mesh.shape[self.tp_axis]

    @property
    def dp_size(self) -> int:
        out = 1
        for a in self.dp_axes:
            out *= self.mesh.shape[a]
        return out

    def named_sharding(self, *dims: Optional[str]):
        """:class:`NamedSharding` over the mesh for logical per-axis
        roles — one entry per array dimension, each ``'dp' | 'ep' |
        'tp' | None``. This is the placement-side sibling of
        :meth:`constrain` (which hints activations *inside* a jitted
        program): use it for ``device_put`` of step *inputs* so every
        host array enters jit with one committed layout. The serving
        engine's DP-sharded KV pools place through it
        (``serve.paged_kv``: pools ``(None, 'dp')`` over the page axis,
        page tables ``('dp', None)`` over the slot axis)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        entries = []
        for d in dims:
            if d == "dp":
                ax = self.dp_axes
                entries.append(ax if len(ax) > 1 else ax[0])
            elif d == "ep":
                entries.append(self.ep_axis)
            elif d == "tp":
                entries.append(self.tp_axis)
            else:
                entries.append(None)
        return NamedSharding(self.mesh, P(*entries))

    def constrain(self, x, dims: Tuple[Optional[str], ...]):
        """Activation sharding constraint. dims entries: 'dp' | 'tp' |
        None. Drops an entry when the dim isn't divisible (e.g. batch=1
        at long-context decode). Without constraints, GSPMD propagates
        FSDP weight shardings into activations and replicates the batch —
        constraints force gather-at-use (ZeRO) semantics instead."""
        import jax
        from jax.sharding import PartitionSpec as P
        entries = []
        for size, d in zip(x.shape, dims):
            ax = (self.dp_axes if d == "dp"
                  else (self.tp_axis,) if d == "tp" and self.tp_axis
                  else None)
            if ax:
                ext = 1
                for a in ax:
                    ext *= self.mesh.shape[a]
                if ext == 0 or size % ext != 0:
                    ax = None
            entries.append(ax if ax is None or len(ax) > 1 else ax[0])
        return jax.lax.with_sharding_constraint(x, P(*entries))


def constrain(dist: Optional[DistContext], x, dims):
    """Module-level convenience: no-op when ``dist`` is None."""
    return x if dist is None else dist.constrain(x, dims)


def ep_split(devices: int, num_experts: int) -> Tuple[int, int]:
    """Factor ``devices`` into ``(dp, ep)`` for serving.

    ``ep`` is the largest divisor of ``devices`` that also divides
    ``num_experts`` (every device must own the same number of whole
    experts); the rest of the machine becomes data parallelism. Dense
    models (``num_experts == 0``) get ``ep = 1``.
    """
    assert devices >= 1
    ep = 1
    if num_experts > 0:
        for d in range(min(devices, num_experts), 0, -1):
            if devices % d == 0 and num_experts % d == 0:
                ep = d
                break
    return devices // ep, ep


def make_serving_context(devices: int, *,
                         num_experts: int = 0) -> Optional[DistContext]:
    """Mesh + context for mesh-sharded serving (``repro.serve``).

    Builds a ``(data=dp, model=ep)`` mesh over the first ``devices``
    jax devices via the ``repro.compat`` shims and returns a
    :class:`DistContext` with ``ep_axis="model"`` (expert parallelism
    only — ``tp_axis`` is None so attention stays unsharded and the
    paged-KV pools replicate). Returns None for ``devices <= 1`` — the
    caller's single-device path.
    """
    if devices <= 1:
        return None
    avail = len(jax.devices())
    if avail < devices:
        raise RuntimeError(
            f"serving mesh needs {devices} devices but jax sees {avail}; "
            f"on CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{devices} before the first jax import (the serve CLI and "
            f"benchmarks/serving.py re-exec themselves to do this)")
    from repro.compat import make_mesh
    dp, ep = ep_split(devices, num_experts)
    mesh = make_mesh((dp, ep), ("data", "model"))
    return DistContext(mesh=mesh, dp_axes=("data",),
                       ep_axis="model" if ep > 1 else None,
                       tp_axis=None)
