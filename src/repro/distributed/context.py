"""Distribution context threaded through model apply functions."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: "jax.sharding.Mesh"
    dp_axes: Tuple[str, ...] = ("data",)
    ep_axis: Optional[str] = "model"      # expert-parallel mesh axis
    tp_axis: Optional[str] = "model"      # tensor-parallel mesh axis
    seq_parallel: bool = False            # residual stream seq-sharded
                                          # over tp (Korthikanti-style)

    @property
    def ep_size(self) -> int:
        if self.ep_axis is None:
            return 1
        return self.mesh.shape[self.ep_axis]

    @property
    def tp_size(self) -> int:
        if self.tp_axis is None:
            return 1
        return self.mesh.shape[self.tp_axis]

    @property
    def dp_size(self) -> int:
        out = 1
        for a in self.dp_axes:
            out *= self.mesh.shape[a]
        return out

    def constrain(self, x, dims: Tuple[Optional[str], ...]):
        """Activation sharding constraint. dims entries: 'dp' | 'tp' |
        None. Drops an entry when the dim isn't divisible (e.g. batch=1
        at long-context decode). Without constraints, GSPMD propagates
        FSDP weight shardings into activations and replicates the batch —
        constraints force gather-at-use (ZeRO) semantics instead."""
        import jax
        from jax.sharding import PartitionSpec as P
        entries = []
        for size, d in zip(x.shape, dims):
            ax = (self.dp_axes if d == "dp"
                  else (self.tp_axis,) if d == "tp" and self.tp_axis
                  else None)
            if ax:
                ext = 1
                for a in ax:
                    ext *= self.mesh.shape[a]
                if ext == 0 or size % ext != 0:
                    ax = None
            entries.append(ax if ax is None or len(ax) > 1 else ax[0])
        return jax.lax.with_sharding_constraint(x, P(*entries))


def constrain(dist: Optional[DistContext], x, dims):
    return x if dist is None else dist.constrain(x, dims)
