"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        --shape train_4k --steps 100 [--mesh-data 16 --mesh-model 16]

On this CPU container it runs reduced configs on a small host mesh; on a
real TPU pod the same entry point uses the production mesh (the step
function, shardings and checkpointing are identical — only the mesh and
config scale change). Fault tolerance: checkpoint/restart + seekable
data + heartbeats (DESIGN §8).
"""
from __future__ import annotations

import argparse
import dataclasses
import logging

import jax
import jax.numpy as jnp

from repro.ckpt import Checkpointer
from repro.compat import set_mesh
from repro.configs import SHAPES, get_config
from repro.core import TPU_V5E, resolve
from repro.data import SyntheticTokens
from repro.distributed.context import DistContext
from repro.launch.mesh import dp_axes, make_host_mesh
from repro.runtime import AdaptiveOptions, TrainOptions, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="moe-gpt3-s")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--use-kernel", action="store_true",
                    help="run expert FFNs through the fused Pallas "
                         "grouped-FFN kernel (pure-jax fallback off-TPU)")
    ap.add_argument("--adaptive", action="store_true",
                    help="online (n, strategy) controller instead of a "
                         "one-shot offline resolve")
    ap.add_argument("--retune-every", type=int, default=0,
                    help="with --adaptive: also re-resolve every K steps "
                         "(0 = only on batch-shape change)")
    ap.add_argument("--metrics-port", type=int, default=-1,
                    help="serve live Prometheus /metrics (controller "
                         "retunes + training heartbeat gauges) on this "
                         "port (0 = free port; -1 = disabled)")
    ap.add_argument("--trace-out", default="",
                    help="write resolver retune spans as a "
                         "Perfetto-loadable trace here at exit "
                         "('' = off)")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = SHAPES[args.shape]

    mesh = None
    dist = None
    if args.mesh_data * args.mesh_model > 1:
        mesh = make_host_mesh(args.mesh_data, args.mesh_model)
        dist = DistContext(mesh=mesh, dp_axes=dp_axes(mesh),
                           ep_axis="model", tp_axis="model")
    # one telemetry surface for training: resolver retune spans/counters
    # land in the same repro.obs registry the serving engine uses
    from repro.obs import MetricsServer, Recorder, Tracer
    obs = Recorder(tracer=Tracer()) if args.trace_out else Recorder()
    server = None
    if args.metrics_port >= 0:
        server = MetricsServer(obs.registry,
                               port=args.metrics_port).start()
        print(f"metrics: {server.url}/metrics")

    adaptive = False
    if cfg.moe is not None:
        if args.adaptive:
            # leave the adaptive placeholders in place: train() grows an
            # AdaptiveController that resolves (n, strategy) online
            cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
                cfg.moe, num_partitions=0,
                memory_reuse_strategy="adaptive"))
            adaptive = AdaptiveOptions(retune_every=args.retune_every,
                                       ep_size=max(1, args.mesh_model),
                                       dp=max(1, args.mesh_data),
                                       hw=TPU_V5E, obs=obs)
            print("MPipeMoE: online adaptive (n, strategy) "
                  f"(retune_every={args.retune_every})")
        else:
            cfg = resolve(cfg, local_tokens=args.batch * args.seq,
                          ep_size=args.mesh_model, hw=TPU_V5E)
            print(f"MPipeMoE: n={cfg.moe.num_partitions} "
                  f"strategy={cfg.moe.memory_reuse_strategy}")

    ds = SyntheticTokens(cfg, batch=args.batch, seq=args.seq, seed=0)
    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    opts = TrainOptions(lr=args.lr, warmup=min(20, args.steps // 5),
                        total_steps=args.steps,
                        compress_grads=args.compress_grads,
                        use_kernel=args.use_kernel)

    g_step = obs.registry.gauge("repro_train_step", "last training step")
    g_loss = obs.registry.gauge("repro_train_loss", "last training loss")
    h_step = obs.registry.histogram("repro_train_step_seconds",
                                    "training step wall time")

    def heartbeat(step, metrics):
        g_step.set(step)
        g_loss.set(float(metrics["loss"]))
        h_step.observe(float(metrics["step_time_s"]))
        if step % 10 == 0:
            extra = (f" n={metrics['n']} strat={metrics['strategy']}"
                     if "n" in metrics else "")
            print(f"step {step:5d} loss={metrics['loss']:.4f} "
                  f"t={metrics['step_time_s']*1e3:.0f}ms{extra}",
                  flush=True)

    ctx = set_mesh(mesh) if mesh is not None else _null()
    try:
        with ctx:
            state, hist = train(cfg, steps=args.steps, batch_source=ds,
                                opts=opts, dist=dist, checkpointer=ck,
                                ckpt_every=args.ckpt_every,
                                heartbeat=heartbeat, adaptive=adaptive)
    finally:
        if server is not None:
            server.stop()
        if args.trace_out:
            obs.tracer.write(args.trace_out)
            print(f"trace: {args.trace_out}")
    print(f"final loss {hist[-1]['loss']:.4f} at step {hist[-1]['step']}")
    if "n" in hist[-1]:                   # controller engaged (MoE arch)
        print(f"adaptive: n={hist[-1]['n']} "
              f"strategy={hist[-1]['strategy']}")


class _null:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
