"""Abstract input specs (ShapeDtypeStruct) per (arch x shape) cell.

The dry-run lowers against these — weak-type-correct, shardable, zero
allocation. Conventions:
* [vlm]  : seq_len splits 1/4 stub patch-embeds + 3/4 text tokens,
  labels cover the full seq (-1 over the image span), M-RoPE position ids
  provided as [3, B, S].
* [audio]: encoder frames [B, 1500, d_enc] stub + decoder tokens [B, S].
* decode : one new token against a cache of seq_len (ring-buffer caches
  allocate window slots only).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.api import get_model


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def vlm_split(seq_len: int) -> Tuple[int, int]:
    s_img = seq_len // 4
    return s_img, seq_len - s_img


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig,
                      local_batch: int = 0) -> Dict:
    b = local_batch or shape.global_batch
    s = shape.seq_len
    out: Dict = {}
    if cfg.frontend == "vision_stub":
        s_img, s_txt = vlm_split(s)
        out["tokens"] = _sds((b, s_txt), jnp.int32)
        out["embeds"] = _sds((b, s_img, cfg.d_model), jnp.float32)
        out["labels"] = _sds((b, s), jnp.int32)
        if cfg.attn.mrope:
            out["positions3"] = _sds((3, b, s), jnp.int32)
    elif cfg.frontend == "audio_stub":
        e = cfg.encoder
        out["frames"] = _sds((b, e.context_len, e.d_model), jnp.float32)
        out["tokens"] = _sds((b, s), jnp.int32)
        out["labels"] = _sds((b, s), jnp.int32)
    else:
        out["tokens"] = _sds((b, s), jnp.int32)
        out["labels"] = _sds((b, s), jnp.int32)
    return out


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict:
    out = train_batch_specs(cfg, shape)
    out.pop("labels", None)
    return out


def decode_inputs(cfg: ArchConfig, shape: ShapeConfig,
                  cache_dtype=jnp.bfloat16):
    """(tokens, cache) abstract inputs for serve_step."""
    model = get_model(cfg)
    b = shape.global_batch
    tokens = _sds((b, 1), jnp.int32)
    cache = model.init_cache(cfg, b, shape.seq_len, cache_dtype,
                             abstract=True)
    return tokens, cache


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict:
    """Unified entry: everything the lowered step consumes (minus state)."""
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape)}
    tokens, cache = decode_inputs(cfg, shape)
    return {"tokens": tokens, "cache": cache}
