"""Static profiler over compiled HLO text — the dry-run's "profile".

``compiled.cost_analysis()`` counts every computation ONCE: a scan-over-
layers model under-reports by the trip count, and collective bytes are
missing entirely. This module parses the optimized HLO:

* builds the computation call graph (while bodies x ``known_trip_count``,
  fusions / to_apply x call sites) and propagates execution multipliers;
* counts dot FLOPs from result shape x contracted dims (symbol table per
  computation resolves operand shapes);
* sums collective bytes per kind (all-reduce counted 2x: ring = RS+AG);
* approximates HBM traffic as operand+result bytes of top-level
  (non-fusion-internal) instructions in scheduled computations.

Everything is per-device (the module is already SPMD-partitioned).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPTOKEN_RE = re.compile(r"(?:^|[\s)])([a-z][a-z0-9\-]*)\(")


def _parse_instr(line: str):
    """'%name = <shape> op(...)' -> (name, shape, op) or None.

    Robust to tuple shapes with parens and /*index=N*/ comments (which
    contain '='): split on the first ' = ', then take the first
    lowercase-token-followed-by-'(' as the opcode.
    """
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%") and not s[:1].isalpha():
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[:eq].strip().lstrip("%")
    rhs = s[eq + 3:]
    m = _OPTOKEN_RE.search(rhs)
    if not m:
        return None
    op = m.group(1)
    if op in _DTYPE_BYTES:
        return None
    shape = rhs[:m.start()].strip()
    return name, shape, op
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLED_RE = re.compile(r"(?:body|condition|calls|to_apply|"
                        r"true_computation|false_computation)=%?([\w\.\-]+)")


def _shape_elems(shape_str: str) -> List[Tuple[str, int]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dtype, n))
    return out


def _shape_bytes(shape_str: str) -> int:
    return sum(n * _DTYPE_BYTES[d] for d, n in _shape_elems(shape_str))


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr]
    # (callee, multiplier-per-execution, via_op)
    calls: List[Tuple[str, float, str]]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        header = _COMP_RE.match(line)
        if header and ("{" in line):
            cur = Computation(header.group(1),
                              line.lstrip().startswith("ENTRY"),
                              [], [])
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        parsed = _parse_instr(line)
        if parsed:
            name, shape, op = parsed
            cur.instrs.append(Instr(name, shape, op, line))
            if op == "while":
                tm = _TRIP_RE.search(line)
                trip = float(tm.group(1)) if tm else 1.0
                for cm in _CALLED_RE.finditer(line):
                    kind = cm.group(0).split("=")[0]
                    mult = trip if kind == "body" else trip + 1
                    cur.calls.append((cm.group(1), mult, op))
            else:
                for cm in _CALLED_RE.finditer(line):
                    cur.calls.append((cm.group(1), 1.0, op))
    return comps


def execution_multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Total executions of each computation from the entry: DFS over the
    caller graph with memoization (HLO call graphs are DAGs)."""
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:   # fall back: treat first computation as entry
        entry = next(iter(comps.values()))
    callers: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    for c in comps.values():
        for callee, k, _op in c.calls:
            callers[callee].append((c.name, k))

    memo: Dict[str, float] = {}

    def total(name: str, depth=0) -> float:
        if name == entry.name:
            return 1.0
        if name in memo:
            return memo[name]
        if depth > 200:
            return 1.0
        s = 0.0
        for parent, k in callers.get(name, []):
            if parent == name:
                continue
            s += total(parent, depth + 1) * k
        memo[name] = s if s else 0.0
        return memo[name]

    return {name: total(name) for name in comps}


# ---------------------------------------------------------------------------
# Counters
# ---------------------------------------------------------------------------

_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _symbol_table(comp: Computation) -> Dict[str, str]:
    return {i.name: i.shape for i in comp.instrs}


def dot_flops(comps: Dict[str, Computation],
              mult: Dict[str, float]) -> float:
    total = 0.0
    for c in comps.values():
        m = mult.get(c.name, 0.0)
        if not m:
            continue
        sym = _symbol_table(c)
        for ins in c.instrs:
            if ins.op not in ("dot", "convolution"):
                continue
            out_elems = sum(n for _, n in _shape_elems(ins.shape))
            cdims = _CONTRACT_RE.search(ins.line)
            contract = 1
            if cdims:
                args = ins.line.split(ins.op + "(", 1)[1]
                # first operand: either "f32[32,64]{1,0} %name" (inline
                # shape, older HLO text) or a bare "%name"
                inline = re.match(
                    r"\s*(\w+\[[\d,]*\])(?:\{[\d,]*\})?\s+%?[\w\.\-]+",
                    args)
                if inline:
                    lhs_shape = inline.group(1)
                else:
                    lhs_name = args.split(",")[0].strip().lstrip("%")
                    lhs_shape = sym.get(lhs_name, "")
                dims = []
                for _, dstr in _SHAPE_RE.findall(lhs_shape):
                    dims = [int(x) for x in dstr.split(",") if x]
                    break
                for di in cdims.group(1).split(","):
                    if di and dims and int(di) < len(dims):
                        contract *= dims[int(di)]
            total += m * 2.0 * out_elems * contract
    return total


def collective_bytes(text_or_comps, mult: Optional[Dict[str, float]] = None
                     ) -> Dict[str, float]:
    if isinstance(text_or_comps, str):
        comps = parse_hlo(text_or_comps)
        mult = execution_multipliers(comps)
    else:
        comps = text_or_comps
        assert mult is not None
    out: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    out["count"] = 0.0
    for c in comps.values():
        m = mult.get(c.name, 0.0)
        if not m:
            continue
        for ins in c.instrs:
            base = ins.op.replace("-start", "")
            if base not in COLLECTIVES:
                continue
            nbytes = _shape_bytes(ins.shape)
            if base == "all-reduce":
                nbytes *= 2
            out[base] += m * nbytes
            out["count"] += m
    return out


def hbm_bytes(comps: Dict[str, Computation], mult: Dict[str, float]) -> float:
    """Approximate HBM traffic: operand+result bytes of instructions in
    scheduled (non-fusion-internal) computations."""
    fusion_bodies = set()
    for c in comps.values():
        for callee, _k, op in c.calls:
            if op in ("fusion", "reduce", "custom-call", "map", "sort",
                      "scatter", "select-and-scatter", "reduce-window",
                      "all-reduce", "reduce-scatter"):
                fusion_bodies.add(callee)
    skip_ops = {"parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "after-all", "iota", "while", "conditional",
                "call"}
    # slicing ops read/write only the slice, not the (possibly stacked-
    # over-layers) operand; counting operands would overstate by the
    # scan depth
    sliced_ops = {"dynamic-slice", "gather", "dynamic-update-slice",
                  "scatter", "pad", "slice", "broadcast"}
    # per fused computation: parameter indices that are consumed ONLY by
    # slicing ops (their HBM read is the slice, not the full buffer —
    # e.g. stacked-over-layers weights dynamic-sliced inside a scan body)
    sliced_params: Dict[str, Dict[int, int]] = {}
    for c in comps.values():
        pidx: Dict[str, int] = {}
        for ins in c.instrs:
            if ins.op == "parameter":
                mm = re.search(r"parameter\((\d+)\)", ins.line)
                if mm:
                    pidx[ins.name] = int(mm.group(1))
        res: Dict[int, int] = {}
        for pname, i in pidx.items():
            uses = [ins for ins in c.instrs
                    if re.search(r"[(,]\s*%?" + re.escape(pname) + r"\b",
                                 ins.line) and ins.op != "parameter"]
            if uses and all(u.op in ("dynamic-slice", "gather") and
                            u.line.split(u.op + "(", 1)[1].split(",")[0]
                            .strip().lstrip("%") == pname for u in uses):
                res[i] = sum(_shape_bytes(u.shape) for u in uses)
        if res:
            sliced_params[c.name] = res

    # computations whose root is dynamic-update-slice into a carried
    # buffer: in-place update — traffic is the slice, not the buffer
    dus_comps: Dict[str, int] = {}
    for c in comps.values():
        root = next((i for i in c.instrs if i.line.lstrip().startswith(
            "ROOT")), None)
        if root is not None and root.op == "dynamic-update-slice":
            args = root.line.split("dynamic-update-slice(", 1)
            if len(args) == 2:
                ops = [a.strip().lstrip("%")
                       for a in args[1].split(")")[0].split(",")]
                sym_c = _symbol_table(c)
                if len(ops) >= 2 and ops[1] in sym_c:
                    dus_comps[c.name] = _shape_bytes(sym_c[ops[1]])

    total = 0.0
    for c in comps.values():
        if c.name in fusion_bodies:
            continue
        m = mult.get(c.name, 0.0)
        if not m:
            continue
        sym = _symbol_table(c)
        for ins in c.instrs:
            if ins.op in skip_ops:
                continue
            nbytes = _shape_bytes(ins.shape)
            if ins.op == "dynamic-update-slice":
                args = ins.line.split(ins.op + "(", 1)
                ops_ = [a.strip().lstrip("%")
                        for a in args[1].split(")")[0].split(",")]
                nbytes = 2 * _shape_bytes(sym.get(ops_[1], "")) \
                    if len(ops_) >= 2 else nbytes
            elif ins.op in sliced_ops:
                nbytes *= 2                       # read slice + write
            else:
                args = ins.line.split(ins.op + "(", 1)
                callee = None
                if ins.op == "fusion":
                    cm = re.search(r"calls=%?([\w\.\-]+)", ins.line)
                    if cm:
                        callee = cm.group(1)
                if callee in dus_comps:
                    total += m * 2 * dus_comps[callee]
                    continue
                # stash-update pattern: fusion(buffer[L,...], slice[...])
                # -> buffer[L,...]: in-place DUS; traffic = the slice
                if ins.op == "fusion" and len(args) == 2:
                    op_shapes = [sym.get(a.strip().lstrip("%").split(
                        "*/")[-1].strip().lstrip("%"), "")
                        for a in args[1].split(")")[0].split(",")]
                    rb = _shape_bytes(ins.shape)
                    if rb > 2 ** 28 and any(
                            _shape_bytes(s) == rb for s in op_shapes):
                        dims = _SHAPE_RE.findall(ins.shape)
                        if len(dims) == 1:
                            inner = dims[0][1].split(",", 1)
                            inner_shape = inner[1] if len(inner) > 1 else ""
                            slice_ops = [s for s in op_shapes
                                         if inner_shape and
                                         f"[{inner_shape}]" in s]
                            if slice_ops:
                                total += m * 2 * _shape_bytes(slice_ops[0])
                                continue
                if len(args) == 2:
                    arglist = args[1].split(")")[0]
                    for ai, a in enumerate(arglist.split(",")):
                        a = a.strip().lstrip("%")
                        if a not in sym:
                            continue
                        sl = sliced_params.get(callee, {}) if callee else {}
                        if ai in sl:
                            nbytes += sl[ai]      # slice-only read
                        else:
                            nbytes += _shape_bytes(sym[a])
            total += m * nbytes
    return total


# ---------------------------------------------------------------------------
# Top-level analysis
# ---------------------------------------------------------------------------

def analyze(hlo_text: str) -> Dict[str, float]:
    comps = parse_hlo(hlo_text)
    mult = execution_multipliers(comps)
    coll = collective_bytes(comps, mult)
    return {
        "flops": dot_flops(comps, mult),
        "hbm_bytes": hbm_bytes(comps, mult),
        "collectives": coll,
    }


def roofline_terms(flops: float, hbm: float, coll: Dict[str, float], *,
                   chips: int, peak_flops: float = 197e12,
                   hbm_bw: float = 819e9, link_bw: float = 50e9,
                   ici_links: int = 4) -> Dict[str, float]:
    total_coll = sum(v for k, v in coll.items() if k in COLLECTIVES)
    return {
        "compute_s": flops / peak_flops,
        "memory_s": hbm / hbm_bw,
        "collective_s": total_coll / (link_bw * ici_links),
        "collective_bytes": total_coll,
        "flops": flops,
        "hbm_bytes": hbm,
    }


def dominant_term(terms: Dict[str, float]) -> str:
    three = {k: terms[k] for k in ("compute_s", "memory_s", "collective_s")}
    return max(three, key=three.get)


def per_collective_report(hlo_text: str, top: int = 15) -> List[str]:
    """Largest collective ops with execution multipliers — the main
    hillclimbing lens for the collective term."""
    comps = parse_hlo(hlo_text)
    mult = execution_multipliers(comps)
    rows = []
    for c in comps.values():
        m = mult.get(c.name, 0.0)
        if not m:
            continue
        for ins in c.instrs:
            base = ins.op.replace("-start", "")
            if base in COLLECTIVES:
                b = _shape_bytes(ins.shape) * (2 if base == "all-reduce"
                                               else 1)
                meta = ""
                mm = re.search(r'op_name="([^"]*)"', ins.line)
                if mm:
                    meta = mm.group(1)[-70:]
                rows.append((m * b, f"{base:18s} x{m:5.0f} "
                             f"{b/2**20:9.2f}MiB  {meta}"))
    rows.sort(reverse=True)
    return [r[1] for r in rows[:top]]
