"""Serving launcher.

Two paths share one CLI:

* ``--engine``: the continuous-batching engine (``repro.serve``) replays
  a Poisson arrival trace of mixed-length requests with the state cache
  the architecture needs (paged KV for attention — full K/V or the MLA
  latent —, slot-indexed constant state for recurrent mamba/xLSTM
  mixers, a composite of both for jamba; decided by
  ``models/api.serving_support`` and printed at startup), per-bucket
  adaptive (n, strategy) prefill, preemptive scheduling under capacity
  pressure (``--preempt``, ``--num-pages``) and temperature /
  top-k / top-p sampling (``--temperature`` …) —

      PYTHONPATH=src python -m repro.launch.serve --engine --requests 16

  Unservable configs (encoder-decoder, vision/audio frontends, m-rope)
  exit with the stable reason string from ``serving_support``.

  ``--devices N`` serves over an N-device dp x ep mesh (EP-sharded
  prefill, replicated psum decode — see docs/distributed.md); on CPU
  the launcher re-execs itself with virtual host devices when fewer
  than N are attached. ``--attn-kernel`` selects the paged-decode
  attention path (fused Pallas page walk vs the gather baseline —
  bit-identical tokens, see docs/serving.md). ``--prefix-cache on``
  shares published KV pages across requests with the same prompt
  prefix (refcounted trie + copy-on-write; also bit-identical — see
  docs/serving.md).

* default: the legacy fixed-batch loop (kept as the golden reference the
  engine is tested against), now with per-request ``max_new_tokens`` and
  EOS early exit — stopping is masked host-side so jitted shapes stay
  static.

``--hw`` names the :class:`HardwareSpec` the MPipeMoE resolver plans
for; ``auto`` detects it from the attached jax backend.

Telemetry (engine path only; see docs/observability.md):
``--metrics-port`` serves live Prometheus ``/metrics`` + ``/healthz``
for the duration of the run, and ``--trace-out PATH`` records
engine/request/resolver spans and writes a Perfetto-loadable Chrome
trace-event JSON at shutdown.

``--http-port`` (engine path only) swaps the Poisson replay for the
asyncio HTTP/SSE ingress tier (``repro.serve.ingress``): real clients
``POST /generate`` and stream tokens back per decode step; client
disconnects cancel their request; ``--shed-policy`` /
``--admission-queue`` configure overload shedding. Serves until
interrupted —

    PYTHONPATH=src python -m repro.launch.serve --engine \\
        --http-port 8080 --shed-policy degrade --admission-queue 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import HW_SPECS, resolve, resolve_hw
from repro.models.api import get_model


def legacy_loop(args, cfg, hw):
    """Fixed-batch request loop over a dense [batch, max_len] cache."""
    if cfg.moe is not None:
        # concrete (n, strategy) for the prefill token count (decode
        # itself always runs n=1 — see pipeline_moe._resolve_partitions)
        cfg = resolve(cfg, local_tokens=args.batch * args.prompt_len,
                      ep_size=1, hw=hw)
        print(f"MPipeMoE prefill: n={cfg.moe.num_partitions} "
              f"strategy={cfg.moe.memory_reuse_strategy}")
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(cfg, key)
    max_len = args.prompt_len + args.gen
    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t, cfg))

    rng = np.random.Generator(np.random.Philox(key=123))
    for req in range(args.requests):
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(req), (args.batch, args.prompt_len), 0,
            cfg.vocab_size)}
        if cfg.frontend == "audio_stub":
            e = cfg.encoder
            batch["frames"] = 0.02 * jax.random.normal(
                key, (args.batch, e.context_len, e.d_model))
        # per-sequence generation budget (<= --gen); EOS stops earlier
        max_new = rng.integers(max(1, args.gen // 2), args.gen + 1,
                               size=args.batch)
        t0 = time.perf_counter()
        logits, cache = model.prefill(params, batch, cfg, max_len=max_len,
                                      dtype=jnp.float32)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        done = np.zeros(args.batch, bool)
        n_gen = np.ones(args.batch, np.int64)
        if args.eos >= 0:
            done |= np.asarray(tok[:, 0]) == args.eos
        done |= n_gen >= max_new
        steps = 1
        while not done.all() and steps < args.gen:
            logits, cache = step(params, cache, tok)
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            # masked stop: finished sequences keep re-feeding their last
            # token, so the jitted step shape never changes
            tok = jnp.where(jnp.asarray(done)[:, None], tok, nxt)
            n_gen += ~done
            if args.eos >= 0:
                done |= np.asarray(tok[:, 0]) == args.eos
            done |= n_gen >= max_new
            steps += 1
        dt = time.perf_counter() - t0
        total = int(n_gen.sum())
        print(f"request-batch {req}: {args.batch} seqs x "
              f"({args.prompt_len} prompt + <= {args.gen} gen) "
              f"{total} tokens in {dt*1e3:.0f}ms -> {total/dt:.1f} tok/s "
              f"(stopped early: {int(done.sum())})")


def _engine_setup(args, cfg, hw):
    """Shared by the replay and ingress engine paths: servability
    check, recorder, EngineOptions from the CLI."""
    from repro.models.api import serving_support
    from repro.obs import Recorder, Tracer
    from repro.serve import EngineOptions

    kind, why = serving_support(cfg)
    if kind is None:
        raise SystemExit(f"{cfg.name} is not servable: {why}")
    print(f"state cache: {kind}")
    obs = Recorder(tracer=Tracer()) if args.trace_out else Recorder()
    opts = EngineOptions(page_size=args.page_size, max_slots=args.batch,
                         max_seq_len=args.prompt_len + args.gen,
                         chunk=args.chunk, hw=hw, preempt=args.preempt,
                         num_pages=args.num_pages, measure=args.measure,
                         devices=args.devices,
                         kv_sharding=args.kv_sharding,
                         attn_kernel=args.attn_kernel,
                         prefix_cache=args.prefix_cache, obs=obs)
    return obs, opts


def ingress_loop(args, cfg, hw):
    """Serve real HTTP/SSE clients until interrupted (no trace replay)."""
    from repro.obs import MetricsServer
    from repro.serve import Engine, IngressOptions, IngressServer

    obs, opts = _engine_setup(args, cfg, hw)
    engine = Engine(cfg, None, options=opts)
    engine.warmup()
    server = None
    if args.metrics_port >= 0:
        server = MetricsServer(obs.registry, port=args.metrics_port,
                               refresh=engine._refresh_gauges).start()
        print(f"metrics: {server.url}/metrics")
    ingress = IngressServer(engine, options=IngressOptions(
        port=args.http_port, shed_policy=args.shed_policy,
        admission_queue=args.admission_queue)).start()
    print(f"ingress: {ingress.url} — POST /generate streams SSE "
          f"(shed={args.shed_policy}, "
          f"admission_queue={args.admission_queue}); ^C to stop")
    try:
        while True:
            time.sleep(1.0)
    except KeyboardInterrupt:
        print("draining...")
    finally:
        ingress.stop()
        if server is not None:
            server.stop()
    if args.trace_out:
        obs.tracer.write(args.trace_out)
        print(f"trace: {args.trace_out} "
              f"(load in https://ui.perfetto.dev)")
    s = engine.stats()
    print(f"served {s['requests_done']} requests "
          f"({s['requests_cancelled']} cancelled: "
          f"{s['cancelled_by_stage']}), "
          f"{s['tokens_generated']} tokens in {s['engine_steps']} steps")


def engine_loop(args, cfg, hw):
    from repro.obs import MetricsServer
    from repro.serve import SamplingParams, run_poisson

    obs, opts = _engine_setup(args, cfg, hw)
    sampling = None
    if args.temperature > 0:
        sampling = SamplingParams(temperature=args.temperature,
                                  top_k=args.top_k, top_p=args.top_p,
                                  seed=args.sample_seed)

    server = None

    def on_engine(engine):
        # live /metrics: scrape-time refresh through the engine's gauge
        # updater so mid-run curls match what stats() would report
        nonlocal server
        if args.metrics_port >= 0:
            server = MetricsServer(obs.registry, port=args.metrics_port,
                                   refresh=engine._refresh_gauges).start()
            print(f"metrics: {server.url}/metrics")

    try:
        engine, dt = run_poisson(
            cfg, opts, requests=args.requests, rate=args.rate,
            prompt_max=args.prompt_len, gen_max=args.gen, seed=args.seed,
            eos_id=args.eos if args.eos >= 0 else None,
            time_scale=args.time_scale, sampling=sampling,
            on_engine=on_engine)
    finally:
        if server is not None:
            server.stop()
    if args.trace_out:
        obs.tracer.write(args.trace_out)
        print(f"trace: {args.trace_out} "
              f"(load in https://ui.perfetto.dev)")
    s = engine.stats()
    if s["devices"] > 1:
        kvs = (f"DP-sharded KV x{s['kv_shards']}"
               if s["kv_shards"] > 1 else "replicated KV")
        print(f"mesh: {s['devices']} devices = dp {s['dp_size']} x "
              f"ep {s['ep_size']} (EP-sharded prefill, {kvs}; "
              f"{s['per_device_cache_bytes']/2**20:.2f}MiB KV pool "
              f"per device)")
    print(f"engine: {s['requests_done']} requests, "
          f"{s['tokens_generated']} tokens in {dt:.2f}s "
          f"({s['requests_done']/dt:.2f} req/s, "
          f"{s['tokens_generated']/dt:.1f} tok/s)")
    print(f"latency p50={s['p50_latency_s']*1e3:.0f}ms "
          f"p99={s['p99_latency_s']*1e3:.0f}ms | "
          f"TTFT p50={s['p50_ttft_s']*1e3:.0f}ms | "
          f"ITL p50={s['p50_itl_s']*1e3:.1f}ms "
          f"p99={s['p99_itl_s']*1e3:.1f}ms")
    print(f"KV pool {s['cache_bytes']/2**20:.2f}MiB, "
          f"peak used {s['peak_kv_used_bytes']/2**20:.2f}MiB | "
          f"{s['engine_steps']} steps, "
          f"{s['prefill_compiles']} prefill compiles")
    if s["preempt_recompute"] or s["preempt_offload"]:
        print(f"preemptions: {s['preempt_recompute']} recompute, "
              f"{s['preempt_offload']} offload, {s['resumes']} resumes, "
              f"swap {s['swap_out_bytes']/2**20:.2f}MiB out / "
              f"{s['swap_in_bytes']/2**20:.2f}MiB in")
    if s.get("prefix_cache") == "on":
        print(f"prefix cache: {s['prefix_hits']} hits / "
              f"{s['prefix_misses']} misses "
              f"({100 * s['prefix_hit_rate']:.0f}%), "
              f"{s['prefix_hit_tokens']} prompt tokens skipped, "
              f"{s['prefix_cow_copies']} CoW copies, "
              f"{s['prefix_evicted_pages']} pages evicted")
    for bucket, (n, strat) in sorted(engine.adaptive.resolutions.items()):
        print(f"  bucket {bucket:4d} -> n={n} strategy={strat}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--batch", type=int, default=4,
                    help="legacy: batch size; engine: decode slots")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--hw", default="auto",
                    choices=["auto"] + sorted(HW_SPECS),
                    help="hardware spec for the MPipeMoE resolver "
                         "(auto = detect from the jax backend)")
    ap.add_argument("--eos", type=int, default=-1,
                    help="EOS token id for early exit (-1 = disabled)")
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine over a Poisson trace")
    ap.add_argument("--chunk", type=int, default=32,
                    help="engine: prefill chunk size (tokens)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="engine: KV page size (tokens)")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="engine: Poisson arrival rate (req/s)")
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="engine: arrival time multiplier (0 = all at once)")
    ap.add_argument("--preempt", default="auto",
                    choices=["auto", "recompute", "offload", "never"],
                    help="engine: overload policy — on-demand pages with "
                         "preemption (auto picks offload vs recompute per "
                         "victim by cost), or 'never' for the conservative "
                         "full-budget admission-blocking baseline")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="engine: KV pool size in pages (0 = worst case; "
                         "smaller values exercise preemption)")
    ap.add_argument("--measure", default="auto",
                    choices=["auto", "wallclock", "simulate"],
                    help="engine: bucket (n, strategy) resolution measure "
                         "(auto = wallclock on non-CPU backends)")
    ap.add_argument("--devices", type=int, default=0,
                    help="engine: serve over an N-device dp x ep mesh "
                         "(0 = single device); CPU re-execs with virtual "
                         "host devices when fewer are attached")
    ap.add_argument("--kv-sharding", default="replicated",
                    choices=["replicated", "dp"],
                    help="engine: paged-KV pool layout over the mesh — "
                         "'replicated' (every device holds the whole "
                         "pool) or 'dp' (pages sharded over the data "
                         "axis: per-device KV drops dp-fold, per-shard "
                         "free lists, sticky least-loaded placement); "
                         "'dp' needs --devices > 1")
    ap.add_argument("--attn-kernel", default="auto",
                    choices=["auto", "pallas", "gather"],
                    help="engine: paged-decode attention path — 'pallas' "
                         "walks the page table inside a fused kernel "
                         "(shard-local page reads under --kv-sharding "
                         "dp), 'gather' materializes pages first (the "
                         "exactness baseline; both emit bit-identical "
                         "tokens), 'auto' picks pallas on TPU")
    ap.add_argument("--prefix-cache", default="off",
                    choices=["on", "off"],
                    help="engine: cross-request prefix caching — 'on' "
                         "publishes full KV pages of finished prefixes "
                         "into a per-shard refcounted trie so later "
                         "requests sharing a prompt prefix skip its "
                         "prefill (copy-on-write on divergence, LRU "
                         "eviction under pressure; bit-identical "
                         "tokens, see docs/serving.md); non-paged "
                         "caches degrade to 'off'")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="engine: sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="engine: top-k filter (0 = disabled)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="engine: nucleus (top-p) filter (1 = disabled)")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="engine: per-request sampling seed")
    ap.add_argument("--metrics-port", type=int, default=-1,
                    help="engine: serve live Prometheus /metrics (and "
                         "/healthz) on this port for the duration of "
                         "the run (0 = pick a free port, printed at "
                         "startup; -1 = disabled)")
    ap.add_argument("--trace-out", default="",
                    help="engine: record spans and write a "
                         "Perfetto-loadable Chrome trace-event JSON "
                         "here at shutdown ('' = tracing off)")
    ap.add_argument("--http-port", type=int, default=-1,
                    help="engine: serve the HTTP/SSE ingress tier on "
                         "this port instead of replaying a Poisson "
                         "trace — POST /generate streams one SSE event "
                         "per generated token, client disconnects "
                         "cancel their request (0 = pick a free port, "
                         "printed at startup; -1 = disabled)")
    ap.add_argument("--shed-policy", default="reject",
                    choices=["reject", "degrade"],
                    help="ingress: behaviour past --admission-queue — "
                         "'reject' answers 429 with Retry-After, "
                         "'degrade' admits with max_new_tokens clamped")
    ap.add_argument("--admission-queue", type=int, default=8,
                    help="ingress: bound on requests accepted but not "
                         "yet finished before load shedding kicks in")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.devices > 1:
        if not args.engine:
            ap.error("--devices requires --engine (the legacy loop is "
                     "single-device)")
        from repro.compat import ensure_host_device_count
        ensure_host_device_count(args.devices)
    elif args.kv_sharding == "dp":
        ap.error("--kv-sharding dp shards the KV pools over the mesh "
                 "data axis; it requires --devices > 1")
    if (args.metrics_port >= 0 or args.trace_out) and not args.engine:
        ap.error("--metrics-port / --trace-out instrument the "
                 "continuous-batching engine; add --engine")
    if args.attn_kernel != "auto" and not args.engine:
        ap.error("--attn-kernel selects the engine's paged-decode "
                 "attention path; add --engine")
    if args.prefix_cache != "off" and not args.engine:
        ap.error("--prefix-cache enables the engine's cross-request "
                 "prefix cache; add --engine")
    if args.http_port >= 0 and not args.engine:
        ap.error("--http-port serves the continuous-batching engine "
                 "over HTTP/SSE; add --engine")
    if args.http_port < 0 and (args.shed_policy != "reject"
                               or args.admission_queue != 8):
        ap.error("--shed-policy / --admission-queue configure the "
                 "HTTP ingress tier; add --http-port")
    hw = resolve_hw(args.hw)
    print(f"hw spec: {hw.name}")
    cfg = get_config(args.arch).reduced()
    if args.engine and args.http_port >= 0:
        ingress_loop(args, cfg, hw)
    elif args.engine:
        engine_loop(args, cfg, hw)
    else:
        legacy_loop(args, cfg, hw)


if __name__ == "__main__":
    main()
