"""Serving launcher: continuous batched greedy decode over a request
stream (reduced configs on CPU; production mesh on TPU).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b \
        --batch 4 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import TPU_V5E, resolve
from repro.models.api import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--requests", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.moe is not None:
        # concrete (n, strategy) for the prefill token count (decode
        # itself always runs n=1 — see pipeline_moe._resolve_partitions)
        cfg = resolve(cfg, local_tokens=args.batch * args.prompt_len,
                      ep_size=1, hw=TPU_V5E)
        print(f"MPipeMoE prefill: n={cfg.moe.num_partitions} "
              f"strategy={cfg.moe.memory_reuse_strategy}")
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(cfg, key)
    max_len = args.prompt_len + args.gen
    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t, cfg))

    for req in range(args.requests):
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(req), (args.batch, args.prompt_len), 0,
            cfg.vocab_size)}
        if cfg.frontend == "audio_stub":
            e = cfg.encoder
            batch["frames"] = 0.02 * jax.random.normal(
                key, (args.batch, e.context_len, e.d_model))
        t0 = time.perf_counter()
        logits, cache = model.prefill(params, batch, cfg, max_len=max_len,
                                      dtype=jnp.float32)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        n = 1
        while n < args.gen:
            logits, cache = step(params, cache, tok)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            n += 1
        dt = time.perf_counter() - t0
        print(f"request-batch {req}: {args.batch} seqs x "
              f"({args.prompt_len} prompt + {args.gen} gen) in "
              f"{dt*1e3:.0f}ms -> {args.batch*args.gen/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
