import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS_EXTRA", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh; record memory analysis, FLOPs/bytes and collective
traffic for the roofline table.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.compat import set_mesh
from repro.configs import ARCHS, ASSIGNED, SHAPES, applicable, get_config
from repro.core import TPU_V5E, resolve
from repro.distributed.context import DistContext
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis, specs as specs_lib
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models.api import get_model
from repro.runtime.train_loop import TrainOptions, abstract_state, \
    make_train_step


def _dist_for(mesh) -> DistContext:
    return DistContext(mesh=mesh, dp_axes=dp_axes(mesh), ep_axis="model",
                       tp_axis="model")


def _arch_cfg_for_cell(name: str, shape_name: str, mesh) -> "ArchConfig":
    cfg = get_config(name)
    shape = SHAPES[shape_name]
    # layer-level remat for training (activation fit at 4k x 256 batch)
    if shape.kind == "train":
        cfg = dataclasses.replace(cfg, remat_policy="full")
    if cfg.moe is not None:
        dp = 1
        for a in dp_axes(mesh):
            dp *= mesh.shape[a]
        ep = mesh.shape["model"]
        if shape.kind == "train":
            local_tokens = max(1, shape.global_batch // dp) \
                * max(1, shape.seq_len // ep)
        else:
            local_tokens = max(1, shape.global_batch // dp) * shape.seq_len
        cfg = resolve(cfg, local_tokens=local_tokens, ep_size=ep,
                      hw=TPU_V5E, allow_offload=False, dp=dp)
    return cfg


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               mesh=None, compile_: bool = True, cfg_override=None,
               seq_parallel: bool = False) -> dict:
    """Lower (and compile) one cell; return the record dict.

    ``cfg_override(cfg) -> cfg`` lets the perf-iteration harness tweak a
    single knob (pipeline mode, n, remat policy, ...) against the same
    lowering path; ``seq_parallel`` flips the residual-stream layout.
    """
    shape = SHAPES[shape_name]
    base = get_config(arch)
    ok, why = applicable(base, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    cfg = _arch_cfg_for_cell(arch, shape_name, mesh)
    if cfg_override is not None:
        cfg = cfg_override(cfg)
    model = get_model(cfg)
    dist = dataclasses.replace(_dist_for(mesh), seq_parallel=seq_parallel)
    rules = shd.make_rules(
        mesh, shape.kind,
        fsdp=(shape.kind == "train") or cfg.param_count() > 3e10,
        seq_shard_cache=("data", "model") if shape.global_batch == 1
        else "model")
    record = {"arch": arch, "shape": shape_name,
              "mesh": dict(zip(mesh.axis_names,
                               [int(s) for s in mesh.devices.shape])),
              "params": cfg.param_count(),
              "params_active": cfg.active_param_count()}
    if cfg.moe:
        record["moe"] = {"n_partitions": cfg.moe.num_partitions,
                         "strategy": cfg.moe.memory_reuse_strategy}

    t0 = time.perf_counter()
    with set_mesh(mesh):
        pshard = shd.param_shardings(cfg, rules, model)
        if shape.kind == "train":
            opts = TrainOptions()
            astate = abstract_state(cfg, opts)
            from repro.optim import get_optimizer, state_shardings
            opt_mod, ocfg = get_optimizer(cfg.optimizer, opts.lr)
            sshard = {
                "params": pshard,
                "opt": state_shardings(opt_mod, ocfg, astate["params"],
                                       pshard, mesh),
                "step": jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()),
            }
            abatch = specs_lib.train_batch_specs(cfg, shape)
            bshard = shd.batch_shardings(cfg, shape, rules, abatch)
            step = make_train_step(cfg, opts, dist)
            jitted = jax.jit(step, in_shardings=(sshard, bshard),
                             donate_argnums=(0,))
            lowered = jitted.lower(astate, abatch)
        elif shape.kind == "prefill":
            abatch = specs_lib.prefill_batch_specs(cfg, shape)
            bshard = shd.batch_shardings(cfg, shape, rules, abatch)

            def prefill_step(params, batch):
                logits, cache = model.prefill(params, batch, cfg,
                                              max_len=shape.seq_len,
                                              dist=dist)
                return logits, cache
            jitted = jax.jit(prefill_step, in_shardings=(pshard, bshard))
            lowered = jitted.lower(model.abstract_params(cfg), abatch)
        else:  # decode
            tokens, acache = specs_lib.decode_inputs(cfg, shape)
            cshard = shd.cache_shardings(cfg, rules, acache)
            tshard = rules.sharding_for(tokens.shape, ("batch", None),
                                        "tokens")

            def serve_step(params, cache, toks):
                return model.decode_step(params, cache, toks, cfg,
                                         dist=dist)
            jitted = jax.jit(serve_step,
                             in_shardings=(pshard, cshard, tshard),
                             donate_argnums=(1,))
            lowered = jitted.lower(model.abstract_params(cfg), acache,
                                   tokens)
        record["lower_s"] = round(time.perf_counter() - t0, 2)
        record["fallbacks"] = rules.fallbacks[:20]

        if not compile_:
            return record
        t1 = time.perf_counter()
        compiled = lowered.compile()
        record["compile_s"] = round(time.perf_counter() - t1, 2)

    mem = compiled.memory_analysis()
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                record[k] = int(v)
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    if cost:  # raw XLA numbers (counts loop bodies ONCE — see hlo_analysis)
        record["xla_flops_once"] = float(cost.get("flops", 0.0))
        record["xla_bytes_once"] = float(cost.get("bytes accessed", 0.0))
    txt = compiled.as_text()
    ana = hlo_analysis.analyze(txt)
    record["flops"] = ana["flops"]
    record["hbm_bytes"] = ana["hbm_bytes"]
    record["collectives"] = {k: float(v)
                             for k, v in ana["collectives"].items()}
    chips = 1
    for s in mesh.devices.shape:
        chips *= int(s)
    record["roofline"] = hlo_analysis.roofline_terms(
        ana["flops"], ana["hbm_bytes"], ana["collectives"], chips=chips)
    record["roofline"]["dominant"] = hlo_analysis.dominant_term(
        record["roofline"])
    # MODEL_FLOPS per device: 6*N*D train (fwd+bwd), 2*N*D prefill (fwd
    # over B*S tokens), 2*N*D decode (one new token per sequence); N =
    # active params for MoE
    if shape.kind == "decode":
        tokens_global = shape.global_batch
    else:
        tokens_global = shape.global_batch * shape.seq_len
    mult = 6.0 if shape.kind == "train" else 2.0
    model_flops = (mult * record["params_active"] * tokens_global) / chips
    record["model_flops"] = model_flops
    record["useful_ratio"] = (model_flops / ana["flops"]
                              if ana["flops"] else 0.0)
    record["top_collectives"] = hlo_analysis.per_collective_report(txt, 8)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ASSIGNED:
            for s in SHAPES:
                cells.append((a, s))
    else:
        archs = [args.arch] if args.arch else list(ASSIGNED)
        shapes = [args.shape] if args.shape else list(SHAPES)
        for a in archs:
            for s in shapes:
                cells.append((a, s))

    os.makedirs(args.out, exist_ok=True)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    tag = "multipod" if args.multi_pod else "singlepod"
    failures = 0
    for arch, shape in cells:
        out_path = os.path.join(args.out,
                                f"{tag}__{arch}__{shape}.json")
        try:
            rec = lower_cell(arch, shape, mesh=mesh,
                             compile_=not args.no_compile)
        except Exception as e:                      # record, keep going
            failures += 1
            rec = {"arch": arch, "shape": shape, "error": repr(e),
                   "traceback": traceback.format_exc()[-4000:]}
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        status = ("SKIP " + rec["skipped"] if "skipped" in rec else
                  "ERROR" if "error" in rec else
                  f"ok lower={rec.get('lower_s')}s "
                  f"compile={rec.get('compile_s')}s "
                  f"dom={rec.get('roofline', {}).get('dominant', '?')}")
        print(f"[{tag}] {arch:24s} {shape:12s} {status}", flush=True)
    print(f"done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
