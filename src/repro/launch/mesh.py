"""Production meshes (task brief): single-pod 16x16, multi-pod 2x16x16.

``make_production_mesh`` is a function (never touches jax device state at
import time). The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to build these meshes on CPU.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) devices exist."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"mesh {data}x{model} > {n} devices")
    return make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
