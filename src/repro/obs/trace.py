"""Span tracer: monotonic-clock spans and instant events exported as
Chrome trace-event JSON (the format Perfetto / chrome://tracing load).

Taxonomy (pinned by tests/test_obs.py's golden-schema test):

* pid 1 "engine"   / tid 1 "steps"    — one ``engine.step`` X span per
  host step, with ``prefill``/``decode`` child X spans nested inside,
  plus ``jit.trace`` instants whenever XLA re-traces a jitted body.
* pid 2 "requests" / tid = request id — the request lifecycle:
  ``ADMIT``/``RESUME``/``PREEMPT``/``RETIRE`` instants,
  ``PREFILL`` chunk X spans (args: chunk/bucket/pos) and a ``DECODE``
  B/E pair that opens when the request enters decode and closes at
  preemption or retirement.
* pid 3 "resolver" / tid 1 "retune"   — ``resolver.resolve`` X spans
  (args: tokens/n/strategy) with ``candidate`` instants for each
  measured (n, strategy) timing inside the granularity search.

Two recorders share the interface: :class:`Tracer` buffers events in
memory and ``export()``s ``{"traceEvents": [...]}``;
:class:`NullTracer` is the disabled path — every method is a no-op and
``span()`` returns a shared inert context manager, so instrumented
call sites cost one truthiness check plus a no-op call. Nothing here
touches jax: events emitted inside jitted Python bodies run at trace
time only, so telemetry on/off cannot change compiled HLO (pinned by
the conformance compile-count matrix).
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional

__all__ = ["NullTracer", "Tracer", "PID_ENGINE", "PID_INGRESS",
           "PID_REQUESTS", "PID_RESOLVER"]

PID_ENGINE = 1
PID_REQUESTS = 2
PID_RESOLVER = 3
PID_INGRESS = 4

_PROCESS_NAMES = {PID_ENGINE: "engine", PID_REQUESTS: "requests",
                  PID_RESOLVER: "resolver", PID_INGRESS: "ingress"}


def _now_us() -> float:
    return time.perf_counter() * 1e6


class _Span:
    """Context manager for an X (complete) event. Mutable mapping-ish:
    ``span["key"] = value`` attaches args discovered mid-span (the
    resolver's chosen (n, strategy) is only known at exit)."""

    __slots__ = ("_tracer", "name", "pid", "tid", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, pid: int, tid: int,
                 args: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self.name = name
        self.pid = pid
        self.tid = tid
        self.args = dict(args) if args else {}
        self._t0 = 0.0

    def __setitem__(self, key: str, value: Any) -> None:
        self.args[key] = value

    def __enter__(self) -> "_Span":
        self._t0 = _now_us()
        return self

    def __exit__(self, *exc) -> None:
        t1 = _now_us()
        self._tracer._emit({
            "ph": "X", "name": self.name, "pid": self.pid,
            "tid": self.tid, "ts": self._t0,
            "dur": max(0.0, t1 - self._t0),
            **({"args": self.args} if self.args else {})})


class _NullSpan:
    """Inert span: accepts item assignment, does nothing."""

    __slots__ = ()

    def __setitem__(self, key: str, value: Any) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


class NullTracer:
    """Disabled tracer — the default. Every method no-ops; ``enabled``
    is False so call sites can skip arg construction entirely."""

    enabled = False
    _SPAN = _NullSpan()

    def span(self, name: str, *, pid: int = PID_ENGINE, tid: int = 1,
             args: Optional[Dict[str, Any]] = None) -> _NullSpan:
        return self._SPAN

    def instant(self, name: str, *, pid: int = PID_ENGINE, tid: int = 1,
                args: Optional[Dict[str, Any]] = None) -> None:
        pass

    def begin(self, name: str, *, pid: int = PID_ENGINE, tid: int = 1,
              args: Optional[Dict[str, Any]] = None) -> None:
        pass

    def end(self, name: str, *, pid: int = PID_ENGINE,
            tid: int = 1) -> None:
        pass

    def thread_name(self, pid: int, tid: int, name: str) -> None:
        pass

    def export(self) -> Dict[str, Any]:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        pass


class Tracer(NullTracer):
    """In-memory Chrome trace-event recorder.

    Events carry microsecond ``ts`` from ``time.perf_counter()`` (one
    monotonic clock for the whole process, so spans from different
    pids interleave correctly on the Perfetto timeline).
    """

    enabled = True

    def __init__(self):
        self._events: List[Dict[str, Any]] = []
        self._thread_names: Dict[tuple, str] = {}

    # -- emission --------------------------------------------------------
    def _emit(self, ev: Dict[str, Any]) -> None:
        self._events.append(ev)

    def span(self, name, *, pid=PID_ENGINE, tid=1, args=None) -> _Span:
        return _Span(self, name, pid, tid, args)

    def instant(self, name, *, pid=PID_ENGINE, tid=1, args=None) -> None:
        self._emit({"ph": "i", "name": name, "pid": pid, "tid": tid,
                    "ts": _now_us(), "s": "t",
                    **({"args": dict(args)} if args else {})})

    def begin(self, name, *, pid=PID_ENGINE, tid=1, args=None) -> None:
        self._emit({"ph": "B", "name": name, "pid": pid, "tid": tid,
                    "ts": _now_us(),
                    **({"args": dict(args)} if args else {})})

    def end(self, name, *, pid=PID_ENGINE, tid=1) -> None:
        self._emit({"ph": "E", "name": name, "pid": pid, "tid": tid,
                    "ts": _now_us()})

    def thread_name(self, pid: int, tid: int, name: str) -> None:
        self._thread_names[(pid, tid)] = name

    # -- export ----------------------------------------------------------
    def export(self) -> Dict[str, Any]:
        """Chrome trace-event JSON object. Real events sorted by ts
        (B before E at equal ts so zero-duration pairs stay nested);
        process/thread metadata (ph=M) prepended."""
        meta: List[Dict[str, Any]] = []
        pids = sorted({e["pid"] for e in self._events}
                      | set(_PROCESS_NAMES)
                      | {p for p, _ in self._thread_names})
        for pid in pids:
            meta.append({"ph": "M", "name": "process_name", "pid": pid,
                         "tid": 0, "ts": 0,
                         "args": {"name": _PROCESS_NAMES.get(
                             pid, f"pid{pid}")}})
        for (pid, tid), name in sorted(self._thread_names.items()):
            meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                         "tid": tid, "ts": 0, "args": {"name": name}})
        order = {"B": 0, "X": 0, "i": 1, "E": 2}
        events = sorted(self._events,
                        key=lambda e: (e["ts"], order.get(e["ph"], 1)))
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.export(), f)
