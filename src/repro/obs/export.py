"""Live metrics exporter: a background ``http.server`` thread serving
``GET /metrics`` (Prometheus text exposition of a
:class:`repro.obs.metrics.Registry`) and ``GET /healthz`` — stdlib
only, enabled by ``--metrics-port`` on ``repro.launch.serve``.

The server never touches engine internals directly: an optional
``refresh`` callback (``Engine._refresh_gauges`` in practice) runs on
the serving thread before each render, pulling point-in-time gauges
(queue depths, running slots, per-shard free pages) into the registry
so a scrape mid-run sees the same values ``Engine.stats()`` would
report. Registry reads are GIL-atomic enough for monitoring; the
engine host loop is never blocked by a scrape.
"""
from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Optional

from .metrics import Registry

__all__ = ["MetricsServer"]


class MetricsServer:
    """Daemon-thread HTTP server exposing one registry.

    ``port=0`` binds an ephemeral port (tests); the bound port is
    available as ``.port`` after ``start()``.
    """

    def __init__(self, registry: Registry, *, port: int = 0,
                 host: str = "127.0.0.1",
                 refresh: Optional[Callable[[], None]] = None):
        self.registry = registry
        self.refresh = refresh
        self._httpd = ThreadingHTTPServer((host, port), self._handler())
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="obs-metrics",
            daemon=True)
        self._stopped = False
        self.host = host
        self.port = int(self._httpd.server_address[1])

    def _handler(self):
        server = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):
                if self.path.split("?", 1)[0] == "/metrics":
                    if server.refresh is not None:
                        try:
                            server.refresh()
                        except Exception:
                            pass    # stale gauges beat a dead scrape
                    body = server.registry.render().encode()
                    ctype = "text/plain; version=0.0.4; charset=utf-8"
                elif self.path.split("?", 1)[0] == "/healthz":
                    body, ctype = b"ok\n", "text/plain; charset=utf-8"
                else:
                    self.send_error(404)
                    return
                # a scraper may hang up mid-response (timeout, ^C):
                # that is its business, not a handler-thread traceback
                try:
                    self.send_response(200)
                    self.send_header("Content-Type", ctype)
                    self.send_header("Content-Length", str(len(body)))
                    self.end_headers()
                    self.wfile.write(body)
                except (BrokenPipeError, ConnectionResetError):
                    pass

            def log_message(self, fmt, *args):
                pass    # scrapes must not spam the serving console

        return Handler

    def start(self) -> "MetricsServer":
        self._thread.start()
        return self

    def stop(self) -> None:
        """Idempotent: every shutdown path (CLI finally-blocks, tests,
        signal handlers) may call it without coordinating."""
        if self._stopped:
            return
        self._stopped = True
        # shutdown() blocks on an event only serve_forever() sets — on
        # a server that was never start()ed it would wait forever
        if self._thread.is_alive():
            self._httpd.shutdown()
            self._thread.join(timeout=5.0)
        self._httpd.server_close()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"
