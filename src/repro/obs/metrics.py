"""Metrics registry: named counters, gauges and ring-buffer histograms
with Prometheus text exposition — stdlib only.

One :class:`Registry` is the single metrics surface of a process:
the serving engine, the scheduler, the state caches and the train-side
adaptive controller all register into the same instance (metric
creation is idempotent — asking for an existing name returns the same
object, so independent subsystems can share families without
coordination). ``render()`` emits the Prometheus text exposition format
served by :class:`repro.obs.export.MetricsServer`; ``snapshot()``
returns the same data as a plain JSON-serializable dict for benchmark
artifacts (``BENCH_*`` JSONs embed it verbatim).

Quantiles are **nearest-rank**: :func:`quantile` is the one shared
implementation (``Engine.stats()`` and the histograms both use it) —
the index is ``ceil(p/100 * n) - 1`` into the sorted sample, which the
previous hand-rolled ``int(p/100 * n)`` overshot by up to one rank
(p50 of a 2-element list returned the max instead of the lower value).

Thread safety is GIL-level: single attribute writes and deque appends
are atomic, which is all the exporter thread needs to read a consistent
enough view — the registry is a monitoring surface, not a ledger.
"""
from __future__ import annotations

import math
import re
from collections import deque
from typing import Any, Deque, Dict, Iterable, List, Optional, Sequence, \
    Tuple

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "quantile"]

_NAME = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def quantile(xs: Sequence[float], p: float) -> float:
    """Nearest-rank p-th percentile (``p`` in [0, 100]) of ``xs``.

    The nearest rank of percentile p over n samples is
    ``ceil(p/100 * n)`` (1-based), i.e. index ``ceil(p/100 * n) - 1``
    into the ascending sort — p0 is the min, p100 the max, and p50 of
    two samples is the *lower* one. Empty input returns 0.0.
    """
    if not xs:
        return 0.0
    assert 0.0 <= p <= 100.0, p
    s = sorted(xs)
    rank = max(1, math.ceil(p / 100.0 * len(s)))
    return float(s[min(rank, len(s)) - 1])


def _fmt(v: float) -> str:
    """Prometheus sample value: integral floats render without the
    trailing .0 noise (page/slot counts read as integers)."""
    f = float(v)
    if f == int(f) and abs(f) < 2 ** 53:
        return str(int(f))
    return repr(f)


def _label_str(labels: Tuple[Tuple[str, str], ...],
               extra: Tuple[Tuple[str, str], ...] = ()) -> str:
    items = labels + extra
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in items) + "}"


class _Metric:
    """One child time series (a concrete label binding of a family)."""

    def __init__(self, labels: Tuple[Tuple[str, str], ...] = ()):
        self.labels = labels


class Counter(_Metric):
    """Monotonically increasing count."""

    def __init__(self, labels=()):
        super().__init__(labels)
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        assert n >= 0, f"counter decrement ({n})"
        self.value += n


class Gauge(_Metric):
    """Point-in-time value (set/inc/dec)."""

    def __init__(self, labels=()):
        super().__init__(labels)
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n


class Histogram(_Metric):
    """Ring-buffer histogram: quantiles over the last ``window``
    observations, total count/sum over the full lifetime. Rendered as a
    Prometheus *summary* (quantile samples + ``_sum``/``_count``)."""

    QUANTILES = (50.0, 90.0, 99.0)

    def __init__(self, labels=(), *, window: int = 8192):
        super().__init__(labels)
        assert window > 0
        self.window = window
        self._ring: Deque[float] = deque(maxlen=window)
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self._ring.append(v)
        self.count += 1
        self.sum += v

    def values(self) -> List[float]:
        return list(self._ring)

    def quantile(self, p: float) -> float:
        return quantile(self.values(), p)


class _Family:
    """A named metric plus its labelled children. A scalar metric is a
    family with one unlabelled child; ``labels()`` materializes keyed
    children on demand (e.g. ``free_pages{shard="0"}``)."""

    def __init__(self, cls, name: str, help: str, label_names: Tuple[str,
                 ...], **kwargs):
        self.cls = cls
        self.name = name
        self.help = help
        self.label_names = label_names
        self.kwargs = kwargs
        self._children: Dict[Tuple[Tuple[str, str], ...], _Metric] = {}
        if not label_names:                   # scalar: one default child
            self._default = self._child(())
        else:
            self._default = None

    def _child(self, key: Tuple[Tuple[str, str], ...]) -> _Metric:
        c = self._children.get(key)
        if c is None:
            c = self.cls(key, **self.kwargs)
            self._children[key] = c
        return c

    def labels(self, **kw) -> Any:
        assert tuple(sorted(kw)) == tuple(sorted(self.label_names)), \
            f"{self.name}: labels {sorted(kw)} != {sorted(self.label_names)}"
        key = tuple((k, str(kw[k])) for k in self.label_names)
        return self._child(key)

    def children(self) -> Iterable[_Metric]:
        return self._children.values()

    # scalar conveniences: a label-less family IS its one child --------
    def __getattr__(self, item):
        if self._default is not None:
            return getattr(self._default, item)
        raise AttributeError(
            f"{self.name} has labels {self.label_names}; "
            f"use .labels(...) before .{item}")


class Registry:
    """Named metric families, rendered to Prometheus text or a plain
    dict. Registration is idempotent: re-declaring a name returns the
    existing family (kind and label names must match)."""

    def __init__(self):
        self._families: Dict[str, _Family] = {}

    def _register(self, cls, name: str, help: str,
                  labels: Sequence[str] = (), **kwargs) -> _Family:
        assert _NAME.match(name), f"bad metric name {name!r}"
        labels = tuple(labels)
        for ln in labels:
            assert _LABEL.match(ln), f"bad label name {ln!r}"
        fam = self._families.get(name)
        if fam is not None:
            assert fam.cls is cls, \
                f"{name} re-registered as {cls.__name__}, was " \
                f"{fam.cls.__name__}"
            assert fam.label_names == labels, \
                f"{name} re-registered with labels {labels}, was " \
                f"{fam.label_names}"
            return fam
        fam = _Family(cls, name, help, labels, **kwargs)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> _Family:
        return self._register(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> _Family:
        return self._register(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (), *,
                  window: int = 8192) -> _Family:
        return self._register(Histogram, name, help, labels,
                              window=window)

    def get(self, name: str) -> Optional[_Family]:
        return self._families.get(name)

    # -- exposition ------------------------------------------------------
    def render(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        out: List[str] = []
        for fam in self._families.values():
            kind = {"Counter": "counter", "Gauge": "gauge",
                    "Histogram": "summary"}[fam.cls.__name__]
            if fam.help:
                out.append(f"# HELP {fam.name} {fam.help}")
            out.append(f"# TYPE {fam.name} {kind}")
            for child in fam.children():
                ls = child.labels
                if isinstance(child, Histogram):
                    vals = sorted(child.values())
                    for q in Histogram.QUANTILES:
                        out.append(
                            f"{fam.name}"
                            f"{_label_str(ls, (('quantile', str(q / 100.0)),))}"
                            f" {_fmt(quantile(vals, q))}")
                    out.append(f"{fam.name}_sum{_label_str(ls)} "
                               f"{_fmt(child.sum)}")
                    out.append(f"{fam.name}_count{_label_str(ls)} "
                               f"{child.count}")
                else:
                    out.append(f"{fam.name}{_label_str(ls)} "
                               f"{_fmt(child.value)}")
        return "\n".join(out) + "\n"

    def snapshot(self) -> Dict[str, Any]:
        """Plain JSON-serializable view: scalar metrics map to their
        value, labelled families to a ``{label-string: value}`` dict,
        histograms to count/sum/quantile summaries."""
        out: Dict[str, Any] = {}
        for fam in self._families.values():
            def one(child):
                if isinstance(child, Histogram):
                    vals = child.values()
                    return {"count": child.count, "sum": child.sum,
                            **{f"p{int(q)}": quantile(vals, q)
                               for q in Histogram.QUANTILES}}
                return child.value

            if not fam.label_names:
                out[fam.name] = one(fam._default)
            else:
                out[fam.name] = {
                    ",".join(f'{k}="{v}"' for k, v in child.labels):
                        one(child)
                    for child in fam.children()}
        return out
