"""repro.obs — engine-wide telemetry: metrics registry, span tracer,
Perfetto export, live /metrics exporter. Stdlib only; nothing in this
package imports jax.

Module map:

- ``metrics``  — ``Registry`` of counters/gauges/ring-buffer
  histograms; shared nearest-rank ``quantile``; Prometheus ``render()``
  and JSON ``snapshot()``.
- ``trace``    — ``Tracer`` (Chrome trace-event spans/instants,
  Perfetto-loadable export) and the no-op ``NullTracer``.
- ``export``   — ``MetricsServer``: background HTTP thread serving
  ``/metrics`` + ``/healthz``.

The unit the rest of the codebase passes around is :class:`Recorder`:
a registry (always real, so ``Engine.stats()`` and ``/metrics`` read
one source of truth) plus a tracer (``NullTracer`` unless span
recording was requested). "Telemetry disabled" — the default — means
the null tracer and no exporter thread; the registry itself is plain
counter arithmetic on the host and is never consulted inside jitted
code, so the disabled path adds no jit traces and no measurable
per-token cost (pinned by the conformance compile-count matrix and
``benchmarks/trajectory/pr7_obs_overhead.json``).
"""
from __future__ import annotations

from typing import Optional

from .export import MetricsServer
from .metrics import Counter, Gauge, Histogram, Registry, quantile
from .trace import (NullTracer, PID_ENGINE, PID_INGRESS, PID_REQUESTS,
                    PID_RESOLVER, Tracer)

__all__ = ["Counter", "Gauge", "Histogram", "MetricsServer",
           "NullTracer", "PID_ENGINE", "PID_INGRESS", "PID_REQUESTS",
           "PID_RESOLVER", "Recorder", "Registry", "Tracer", "quantile"]


class Recorder:
    """Registry + tracer bundle threaded through engine, scheduler,
    caches, resolver and train controller. Construct with
    ``Recorder(tracer=Tracer())`` to record spans; the default is
    metrics-only with the no-op tracer."""

    def __init__(self, registry: Optional[Registry] = None,
                 tracer: Optional[NullTracer] = None):
        self.registry = registry if registry is not None else Registry()
        self.tracer = tracer if tracer is not None else NullTracer()

    @property
    def tracing(self) -> bool:
        return self.tracer.enabled
