from repro.data.synthetic import SyntheticTokens, VaryingSyntheticTokens

__all__ = ["SyntheticTokens", "VaryingSyntheticTokens"]
