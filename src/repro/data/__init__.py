from repro.data.synthetic import SyntheticTokens

__all__ = ["SyntheticTokens"]
