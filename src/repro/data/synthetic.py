"""Deterministic, seekable synthetic token pipeline.

Every batch is a pure function of (seed, step, host slice): a restarted or
replacement host resumes at the exact batch — the property the
fault-tolerance layer relies on (DESIGN §8). A background thread keeps a
double-buffered prefetch queue so host->device transfer overlaps step
compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional, Sequence

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


class SyntheticTokens:
    def __init__(self, cfg: ArchConfig, batch: int, seq: int,
                 seed: int = 0, num_hosts: int = 1, host_index: int = 0):
        assert batch % num_hosts == 0, (batch, num_hosts)
        self.cfg = cfg
        self.global_batch = batch
        self.local_batch = batch // num_hosts
        self.seq = seq
        self.seed = seed
        self.host_index = host_index

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for a global step (host-local slice)."""
        rng = np.random.Generator(np.random.Philox(
            key=self.seed, counter=[0, 0, self.host_index, step]))
        cfg = self.cfg
        b, s = self.local_batch, self.seq
        # "documents": markov-ish stream so the LM loss is learnable
        base = rng.integers(0, cfg.vocab_size, size=(b, s + 1),
                            dtype=np.int32)
        repeat = rng.random((b, s + 1)) < 0.5
        base[:, 1:] = np.where(repeat[:, 1:],
                               (base[:, :-1] + 1) % cfg.vocab_size,
                               base[:, 1:])
        out = {"tokens": base[:, :-1], "labels": base[:, 1:].copy()}
        if cfg.frontend == "audio_stub":
            e = cfg.encoder
            out["frames"] = rng.standard_normal(
                (b, e.context_len, e.d_model)).astype(np.float32) * 0.02
        elif cfg.frontend == "vision_stub":
            s_img = max(16, s // 4)
            out["embeds"] = rng.standard_normal(
                (b, s_img, cfg.d_model)).astype(np.float32) * 0.02
            if cfg.attn.mrope:
                t = np.arange(s + s_img, dtype=np.int32)
                out["positions3"] = np.stack(
                    [np.broadcast_to(t, (b, t.size))] * 3)
            out["labels"] = np.concatenate(
                [np.full((b, s_img), -1, np.int32), out["labels"]], axis=1)
        return out

    def iter(self, start_step: int = 0,
             prefetch: int = 2) -> Iterator[Dict[str, np.ndarray]]:
        q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            step = start_step
            while not stop.is_set():
                q.put(self.batch_at(step))
                step += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


class VaryingSyntheticTokens:
    """Seekable source whose per-step batch size follows ``trace``
    (cycled). Models production serving/training traffic where the token
    count drifts — the workload the online adaptive controller retunes
    for (each distinct size is a new shape, and possibly a new optimal
    pipeline granularity).
    """

    def __init__(self, cfg: ArchConfig, trace: Sequence[int], seq: int,
                 seed: int = 0, num_hosts: int = 1, host_index: int = 0):
        assert trace, "need at least one batch size"
        self.trace = tuple(int(b) for b in trace)
        self._sources = {
            b: SyntheticTokens(cfg, batch=b, seq=seq, seed=seed,
                               num_hosts=num_hosts, host_index=host_index)
            for b in set(self.trace)}

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        return self._sources[self.trace[step % len(self.trace)]] \
            .batch_at(step)
