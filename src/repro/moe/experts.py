"""Expert FFN compute: grouped 2-GEMM (optionally gated), with the
paper's ``T_M`` tagged for remat/offload policies. The Pallas fast path
(``repro.kernels.grouped_ffn``) fuses the two GEMMs so T_M stays in VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig
from repro.models.module import Spec

_ACTS = {"silu": jax.nn.silu,
         "gelu": lambda x: jax.nn.gelu(x, approximate=True),
         "relu": jax.nn.relu}


def specs(cfg: ArchConfig):
    m = cfg.moe
    d = cfg.d_model
    s = {
        "w_up": Spec((m.num_experts, d, m.d_expert),
                     ("experts", "embed", "expert_mlp")),
        "w_down": Spec((m.num_experts, m.d_expert, d),
                       ("experts", "expert_mlp_c", "embed_out")),
    }
    if cfg.gated_ffn:
        s["w_gate"] = Spec((m.num_experts, d, m.d_expert),
                           ("experts", "embed", "expert_mlp"))
    return s


def shared_specs(cfg: ArchConfig):
    m = cfg.moe
    d = cfg.d_model
    dh = m.d_shared * m.num_shared_experts
    s = {"w_up": Spec((d, dh), ("embed", "mlp")),
         "w_down": Spec((dh, d), ("mlp_c", "embed_out"))}
    if cfg.gated_ffn:
        s["w_gate"] = Spec((d, dh), ("embed", "mlp"))
    return s


def apply_grouped(params, x, cfg: ArchConfig, use_kernel: bool = False):
    """x: [E_local, C, M] -> [E_local, C, M]."""
    act = _ACTS[cfg.ffn_act]
    dt = x.dtype
    if use_kernel:
        from repro.kernels.grouped_ffn import ops as gops
        return gops.grouped_ffn(
            x, params["w_up"].astype(dt),
            params["w_gate"].astype(dt) if cfg.gated_ffn else None,
            params["w_down"].astype(dt), cfg.ffn_act)
    h = jnp.einsum("ecm,emh->ech", x, params["w_up"].astype(dt))
    if cfg.gated_ffn:
        g = jnp.einsum("ecm,emh->ech", x, params["w_gate"].astype(dt))
        h = act(g) * h
    else:
        h = act(h)
    h = checkpoint_name(h, "t_m")
    return jnp.einsum("ech,ehm->ecm", h, params["w_down"].astype(dt))


def apply_shared(params, x, cfg: ArchConfig):
    """Dense always-on shared experts. x: [T, M] -> [T, M]."""
    act = _ACTS[cfg.ffn_act]
    dt = x.dtype
    h = jnp.einsum("tm,mh->th", x, params["w_up"].astype(dt))
    if cfg.gated_ffn:
        g = jnp.einsum("tm,mh->th", x, params["w_gate"].astype(dt))
        h = act(g) * h
    else:
        h = act(h)
    return jnp.einsum("th,hm->tm", h, params["w_down"].astype(dt))
