"""Top-k gating network with capacity-aware auxiliary losses.

Routing is deterministic (no jitter noise) so steps are bit-reproducible
across restarts — a fault-tolerance property (DESIGN §8). Aux losses follow
Switch/GShard: load-balance loss + router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.module import Spec


def specs(cfg: ArchConfig):
    m = cfg.moe
    s = {"w_gate": Spec((cfg.d_model, m.num_experts), ("embed", "experts"),
                        "normal", 0.02)}
    if m.gate_bias:
        s["b_gate"] = Spec((m.num_experts,), ("experts",), "zeros")
    return s


def route(params, tokens, cfg: ArchConfig):
    """tokens: [T, M] -> (probs [T,k], expert_idx [T,k] int32, aux dict)."""
    m = cfg.moe
    logits = jnp.einsum("tm,me->te", tokens.astype(jnp.float32),
                        params["w_gate"].astype(jnp.float32))
    if m.gate_bias:
        logits = logits + params["b_gate"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)
    if m.top_k > 1:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Switch-style load balance: E * sum_e f_e * p_e
    assign = jax.nn.one_hot(top_i[:, 0], m.num_experts)      # primary route
    f_e = assign.mean(axis=0)
    p_e = probs.mean(axis=0)
    aux_loss = m.num_experts * jnp.sum(f_e * p_e) * m.aux_loss_weight
    z = jax.scipy.special.logsumexp(logits, axis=-1)
    z_loss = jnp.mean(z ** 2) * m.z_loss_weight
    return top_p, top_i.astype(jnp.int32), {
        "aux_loss": aux_loss, "z_loss": z_loss}
