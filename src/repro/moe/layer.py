"""MoE layer: ties router + dispatch + MPipeMoE engine, and owns the
shard_map entry point for expert parallelism.

Layout contract (DESIGN §4): under a mesh, tokens enter sharded over
(dp-axes on batch, EP axis on sequence) — sequence-parallel MoE — so each
device contributes distinct tokens to the All-to-All. At decode (S=1)
tokens are replicated over EP and the combine is a psum instead.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ArchConfig
from repro.core.pipeline_moe import pipelined_moe
from repro.models.module import axes_of
from repro.moe import experts as E
from repro.moe import router as R


def specs(cfg: ArchConfig):
    s = {"router": R.specs(cfg), "experts": E.specs(cfg)}
    if cfg.moe.num_shared_experts:
        s["shared"] = E.shared_specs(cfg)
    return s


def _param_specs(cfg: ArchConfig, ep_axis: Optional[str],
                 dp_axes: Tuple[str, ...] = ()):
    """PartitionSpecs for the shard_map boundary: experts sharded over the
    EP axis on dim 0 AND kept dp-sharded (ZeRO-3) on their output dim —
    the body gathers them explicitly (see ``gather_expert_weights``), so
    the weight-grad reduction is one reduce-scatter. Router/shared stay
    replicated (tiny)."""
    def to_spec(axes, zero3: bool):
        entries = []
        for i, a in enumerate(axes):
            if a == "experts" and i == 0:
                entries.append(ep_axis)
            elif zero3 and dp_axes and i == len(axes) - 1:
                entries.append(dp_axes if len(dp_axes) > 1 else dp_axes[0])
            else:
                entries.append(None)
        return P(*entries)
    tree = axes_of(specs(cfg))
    out = {}
    for key, sub in tree.items():
        zero3 = key == "experts"
        out[key] = jax.tree_util.tree_map(
            lambda ax, z=zero3: to_spec(ax, z), sub,
            is_leaf=lambda x: isinstance(x, tuple))
    return out


def apply(params, x, *, cfg: ArchConfig, dist=None, mode: str = "train",
          use_kernel: bool = False) -> Tuple[jax.Array, dict]:
    """x: [B, S, M] -> ([B, S, M], aux)."""
    b, s, d = x.shape

    if dist is None or dist.ep_axis is None or dist.ep_size == 1:
        out, aux = pipelined_moe(params, x.reshape(b * s, d), cfg=cfg,
                                 ep_size=1, mode=mode,
                                 use_kernel=use_kernel)
        return out.reshape(b, s, d), aux

    mesh = dist.mesh
    ep_axis = dist.ep_axis
    ep_size = dist.ep_size
    dp = dist.dp_axes if b % max(1, dist.dp_size) == 0 else ()
    seq_shardable = mode != "decode" and s % ep_size == 0

    # ZeRO-3 expert weights: only when every expert tensor's last dim
    # divides the dp extent (divisibility fallback: replicate)
    dp_ext = 1
    for a_ in dist.dp_axes:
        dp_ext *= mesh.shape[a_]
    zero3_ok = (mode == "train" and dp_ext > 1
                and cfg.moe.d_expert % dp_ext == 0
                and d % dp_ext == 0)
    zero3_axes = dist.dp_axes if zero3_ok else ()

    x_spec = P(dp if dp else None, ep_axis if seq_shardable else None,
               None)
    p_specs = _param_specs(cfg, ep_axis, zero3_axes)

    # Pin the shard_map boundary: without this, GSPMD propagates the
    # seq-sharded in_spec *backward* into the surrounding layers, and a
    # sequence axis sharded over the EP axis miscompiles the recurrent
    # mixers on jax 0.4.x (the causal conv / chunked SSM scan partition
    # without the needed halo exchange — wrong *values*, not just a bad
    # layout; see tests/test_serving_conformance.py's jamba arch leg).
    # The explicit replicated constraint keeps the residual stream's
    # layout at the boundary and reshards only inside it.
    if seq_shardable:
        from jax.sharding import NamedSharding
        repl = NamedSharding(mesh, P(None, None, None))
        x = jax.lax.with_sharding_constraint(x, repl)

    # decode uses the replicated-token path: aux is invarying over the EP
    # axis there, so only reduce over the axes the value varies on
    reduce_axes = dp + ((ep_axis,) if seq_shardable else ())

    def body(p, xl):
        bl, sl, _ = xl.shape
        out, aux = pipelined_moe(
            p, xl.reshape(bl * sl, d), cfg=cfg, ep_axis=ep_axis,
            ep_size=ep_size, mode=mode, use_kernel=use_kernel,
            dp_axes=zero3_axes)
        if reduce_axes:
            aux = jax.tree_util.tree_map(
                lambda v: jax.lax.pmean(v, reduce_axes), aux)
        return out.reshape(bl, sl, d), aux

    out, aux = shard_map(
        body, mesh=mesh, in_specs=(p_specs, x_spec),
        out_specs=(x_spec, P()))(params, x)
    if seq_shardable:
        out = jax.lax.with_sharding_constraint(out, repl)
    return out, aux
