"""Token dispatch/combine for expert parallelism.

Two implementations:

* ``sort``  — production path: stable-argsort tokens by destination
  expert, compute each token's rank within its expert via searchsorted,
  scatter into the [E, C, M] buffer. O(T log T + T·M) — no [T,E,C] one-hot
  einsum (which would rival the expert FLOPs themselves at large T).
* ``einsum`` — the GShard-style dense dispatch; kept as the differentiable
  oracle for property tests.

Both drop overflow tokens beyond capacity (standard capacity-factor
semantics); combine scales by the gate probability and sums the k routes.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def dispatch_plan(expert_idx, num_experts: int, capacity: int):
    """expert_idx: [T, k] -> (slot_dest [T*k], valid [T*k]).

    ``slot_dest[t*k+j]`` is the flat position in the [E*C] buffer that
    route j of token t writes to; invalid (overflow) slots get dest E*C
    (scattered into a scratch row that is later dropped).
    """
    t, k = expert_idx.shape
    flat_e = expert_idx.reshape(-1)                      # [T*k]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank within expert group = i - first index of this expert value
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(t * k) - first
    valid_sorted = rank < capacity
    dest_sorted = jnp.where(valid_sorted,
                            sorted_e * capacity + rank,
                            num_experts * capacity)
    # un-sort back to slot order
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(t * k))
    dest = dest_sorted[inv]
    valid = valid_sorted[inv]
    return dest.astype(jnp.int32), valid


def dispatch(tokens, dest, num_experts: int, capacity: int):
    """tokens: [T, M]; dest: [T*k] -> buffer [E, C, M]."""
    t, m = tokens.shape
    k = dest.shape[0] // t
    src = jnp.repeat(tokens, k, axis=0) if k > 1 else tokens
    buf = jnp.zeros((num_experts * capacity + 1, m), tokens.dtype)
    buf = buf.at[dest].add(src)       # scatter-add: unique dests except scratch
    return buf[:-1].reshape(num_experts, capacity, m)


def combine(buffer, dest, probs, t: int):
    """buffer: [E, C, M]; dest/probs: [T*k] / [T,k] -> [T, M]."""
    e, c, m = buffer.shape
    flat = jnp.concatenate(
        [buffer.reshape(e * c, m), jnp.zeros((1, m), buffer.dtype)], axis=0)
    gathered = flat[dest]                                # [T*k, M]
    k = dest.shape[0] // t
    gathered = gathered.reshape(t, k, m)
    return jnp.einsum("tkm,tk->tm", gathered, probs.astype(buffer.dtype))


# ---------------------------------------------------------------------------
# einsum (GShard) oracle
# ---------------------------------------------------------------------------

def einsum_dispatch_mask(expert_idx, probs, num_experts: int, capacity: int):
    """-> (dispatch_mask [T,E,C] bool, combine_w [T,E,C] float)."""
    t, k = expert_idx.shape
    onehot = jax.nn.one_hot(expert_idx, num_experts, dtype=jnp.int32)
    # position of route j of token t within expert e (counting all earlier
    # routes in slot-major order)
    flat = onehot.reshape(t * k, num_experts)
    pos = jnp.cumsum(flat, axis=0) - flat                # [T*k, E]
    pos = pos.reshape(t, k, num_experts)
    in_cap = pos < capacity
    pos_oh = jax.nn.one_hot(jnp.where(in_cap, pos, capacity), capacity + 1,
                            dtype=jnp.float32)[..., :capacity]
    mask = (onehot[..., None] * pos_oh *
            in_cap[..., None].astype(jnp.float32))       # [T,k,E,C]
    combine_w = jnp.einsum("tkec,tk->tec", mask, probs.astype(jnp.float32))
    return mask.sum(axis=1) > 0, combine_w
