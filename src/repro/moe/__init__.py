"""MoE substrate: routing, dispatch/combine, experts, the MoE layer."""
