"""Sharded checkpointing: per-host npz shards + JSON manifest.

Properties required at 1000+ node scale (DESIGN §8):
* atomic    — write to ``<dir>.tmp`` then ``os.rename`` (a crash never
  leaves a half-written checkpoint as "latest");
* async     — a background thread serializes device arrays already copied
  to host, so the train loop is blocked only for the device->host copy;
* keep-k    — bounded disk footprint;
* elastic   — ``restore`` takes target shardings: a checkpoint saved on an
  N-host mesh restores onto an M-host mesh (state is saved as full logical
  arrays per leaf here single-host; multi-host would save per-shard slices
  keyed by global offset — the manifest format already carries them).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(state) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    out = {}
    for kp, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_into(treedef_source, arrays: Dict[str, np.ndarray]):
    flat = jax.tree_util.tree_flatten_with_path(treedef_source)
    leaves = []
    for kp, leaf in flat[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        arr = arrays[key]
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat[1], leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3,
                 host_index: int = 0, num_hosts: int = 1):
        self.dir = directory
        self.keep = keep
        self.host_index = host_index
        self.num_hosts = num_hosts
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ---------------- save ----------------
    def save(self, step: int, state, block: bool = False) -> None:
        self.wait()
        host_state = jax.tree_util.tree_map(np.asarray, state)  # D2H copy

        def _write():
            path = os.path.join(self.dir, f"step_{step:010d}")
            tmp = path + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            arrays = _flatten(host_state)
            np.savez(os.path.join(tmp, f"shard_{self.host_index}.npz"),
                     **arrays)
            manifest = {
                "step": step,
                "num_hosts": self.num_hosts,
                "leaves": {k: {"shape": list(v.shape),
                               "dtype": str(v.dtype)}
                           for k, v in arrays.items()},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(path):
                shutil.rmtree(path)
            os.rename(tmp, path)                  # atomic publish
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.list_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---------------- restore ----------------
    def list_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def restore(self, step: int, like=None, shardings=None):
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        arrays: Dict[str, np.ndarray] = {}
        for h in range(manifest["num_hosts"]):
            fn = os.path.join(path, f"shard_{h}.npz")
            if os.path.exists(fn):
                with np.load(fn) as z:
                    arrays.update({k: z[k] for k in z.files})
        state = (_unflatten_into(like, arrays) if like is not None
                 else arrays)
        if shardings is not None:
            # elastic restore: place each leaf per the target mesh
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state

    def restore_latest(self, abstract=None, like=None, shardings=None):
        steps = self.list_steps()
        if not steps:
            return None
        step = steps[-1]
        return {"step": step,
                "state": self.restore(step, like=like, shardings=shardings)}
