"""Shims for jax APIs that moved between 0.4.x and current releases.

The repo targets current jax (``jax.make_mesh(..., axis_types=...)``,
``jax.set_mesh``, ``jax.shard_map``); older CPU containers pin 0.4.x
where those live elsewhere or don't exist. Every call site goes through
these helpers so both resolve identically.

Also hosts :func:`ensure_host_device_count`, the CPU virtual-device
shim the multi-device CLIs (``launch/serve --devices``,
``benchmarks/serving.py --devices``) use to re-exec themselves with
``--xla_force_host_platform_device_count`` when asked for more devices
than are attached.
"""
from __future__ import annotations

from typing import Sequence

import jax

__all__ = ["ensure_host_device_count", "make_mesh", "set_mesh",
           "shard_map"]


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """jax.make_mesh with explicit Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def set_mesh(mesh):
    """Context manager activating ``mesh`` (jax.set_mesh on current jax;
    the Mesh object is itself a context manager on 0.4.x)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def ensure_host_device_count(n: int) -> None:
    """Re-exec the current CLI with ``n`` virtual CPU devices.

    XLA only honours ``--xla_force_host_platform_device_count`` before
    the first jax import, which has already happened by the time a CLI
    parses ``--devices N``. When fewer than ``n`` devices are attached
    (and the backend is CPU), re-exec the same argv with the flag added
    to ``XLA_FLAGS`` and exit with the child's status. No-op when
    enough devices already exist; raises on non-CPU backends (real
    accelerators cannot be conjured) or if a re-exec already happened.
    """
    import os
    import subprocess
    import sys

    if n <= 1 or jax.device_count() >= n:
        return
    if jax.default_backend() != "cpu":
        raise RuntimeError(
            f"need {n} devices but only {jax.device_count()} "
            f"{jax.default_backend()} device(s) are attached")
    if os.environ.get("_REPRO_HOST_DEVICE_REEXEC"):
        raise RuntimeError(
            f"still only {jax.device_count()} devices after re-exec "
            f"with --xla_force_host_platform_device_count={n}")
    env = dict(os.environ, _REPRO_HOST_DEVICE_REEXEC="1")
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n}").strip()
    raise SystemExit(
        subprocess.call([sys.executable] + sys.argv, env=env))


def shard_map(f, *, mesh, in_specs, out_specs, check_rep=True):
    if hasattr(jax, "shard_map"):
        if not check_rep:
            # pallas_call has no replication rule, so callers closing
            # over kernels must disable the check; the kwarg was renamed
            # check_vma and then dropped across releases — try each
            for kw in ("check_rep", "check_vma"):
                try:
                    return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                         out_specs=out_specs, **{kw: False})
                except TypeError:
                    continue
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map
    # 0.4.x shard_map has no replication rule for checkpoint_name (used
    # for the paper's t_di/t_m residual tags) — disable the rep check
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)
