"""Shims for jax APIs that moved between 0.4.x and current releases.

The repo targets current jax (``jax.make_mesh(..., axis_types=...)``,
``jax.set_mesh``, ``jax.shard_map``); older CPU containers pin 0.4.x
where those live elsewhere or don't exist. Every call site goes through
these helpers so both resolve identically.
"""
from __future__ import annotations

from typing import Sequence

import jax


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """jax.make_mesh with explicit Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def set_mesh(mesh):
    """Context manager activating ``mesh`` (jax.set_mesh on current jax;
    the Mesh object is itself a context manager on 0.4.x)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map
    # 0.4.x shard_map has no replication rule for checkpoint_name (used
    # for the paper's t_di/t_m residual tags) — disable the rep check
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)
