"""Paged KV cache: host-side page allocator over the device page pools —
the paged implementation of the :class:`~repro.serve.state_cache.StateCache`
protocol (and, with an MLA config, the **paged latent cache**: the same
allocator over per-token compressed-latent pools ``c_kv``/``k_rope``
instead of full K/V — see ``models/kv_cache.paged_layer_pool``).

Device side (``models/kv_cache.init_paged_pools``): per attention layer a
global pool ``[num_pages, page_size, kv_heads, head_dim]`` (or
``[num_pages, page_size, kv_lora_rank]`` + ``[num_pages, page_size,
rope_head_dim]`` for MLA) shared by every in-flight sequence. Host side
(this module): free lists of physical pages, a ``[max_slots,
max_pages_per_seq]`` page table and per-slot lengths, mirrored to device
as plain int32 arrays each step — plus a host-side offload pool holding
the page contents of preempted-by-offload requests until they resume.

Invariants (stated per shard — one shard unsharded, ``dp`` shards under
``kv_sharding="dp"``):
* each shard's local page 0 is reserved — never allocated — as the
  write sink for that shard's masked (padding / inactive-slot)
  scatters; globally those are pages ``{s * pages_per_shard}``;
* pages are allocated either **up front** for a slot's whole budget
  (``alloc_slot`` with the full prompt + max_new token count — the
  conservative admission-blocking baseline) or **on demand** one page at
  a time (``grow_slot`` — the preemptive scheduler's path, where a
  shard running dry triggers a preemption on that shard instead of a
  deadlock);
* a slot only ever binds pages of its own shard (slot ``i`` lives on
  shard ``i // slots_per_shard``), so decode stays data-parallel: no
  slot's reads or writes cross a shard boundary;
* freed slots have their page-table row reset to their shard's sink, so
  a stale slot's decode writes land in the sink page, never in pages
  that were handed to another sequence;
* an offloaded request holds **zero** device pages: ``offload_slot``
  copies its pages to host and returns them to its shard's free list,
  and ``restore_slot`` later re-allocates **on the same shard**
  (placement is sticky for a request's lifetime; different physical
  pages are fine — the page table re-maps them) and copies the contents
  back.

Mesh-sharded serving (``dist`` given), two layouts:

* ``kv_sharding="replicated"`` (the PR 4 baseline): pools, page table
  and lens are replicated across every device — each device needs the
  whole pool, so adding devices buys compute but zero KV capacity.
* ``kv_sharding="dp"``: the pool's **page axis is sharded over the mesh
  ``data`` axis** (each of the ``dp`` device groups physically holds
  ``num_pages / dp`` pages — per-device resident KV bytes drop ``dp``×)
  and the page table / lens / decode batch shard over the slot axis, so
  decode runs data-parallel: each dp group attends only its own slots
  against only its own pages. Chunked prefill keeps the EP-sharded
  ``pipelined_moe`` layout; its KV scatter lands in the owning shard's
  pages directly (GSPMD routes the writes — the prefill→decode handoff
  needs no copy) and the step output is pinned back to the page-sharded
  layout (``StateCache.pin_pools``). Each shard keeps its **own
  host-side free list**; admission places a request on a shard
  (least-loaded, sticky) and pool-dry is a per-shard event.

``cache_bytes``/``used_bytes`` report *logical* pool bytes;
``per_device_cache_bytes`` / ``per_device_peak_used_bytes`` report the
per-device residency (divided by ``n_shards`` under ``dp``, with
``replicas`` physical copies each). Host-offload round-trips are
unchanged per shard: pages are extracted from (and re-inserted into) the
pools with the pool layout preserved (``insert_pages(out_sharding=)``).

Cross-request prefix cache (``prefix_cache=True``): every page carries a
refcount (= binding slots + 1 if the page is published in the prefix
trie), and each shard keeps a trie over **full-page token keys** — node
at depth i maps the exact ``page_size`` token ids of logical page i to
the physical page holding their K/V. Admission
(:meth:`alloc_slot_prefix`) walks the trie with the request's prompt,
binds the matched pages instead of recomputing them (refcount +1 each,
``lens`` starts at the hit length — prefill runs only the tail), capped
at ``len(prompt) - 1`` so at least one token always prefilles to
produce the first-sample logits. That cap can land mid-page, so the
tail's first write may target a shared page: :meth:`ensure_private`
copy-on-writes it (device-side :func:`models.kv_cache.copy_pages` into
a fresh page; when the pool is dry and the trie is the only other
referent, the entry is *stolen* — detached — instead, which is what
keeps a sole request from live-locking against its own cache entries).
Retiring or finishing prefill publishes the slot's written full pages
(:meth:`cache_slot_prefix`). A page is freed only at refcount zero:
preemption (both modes) merely drops the victim's references, so a page
another request — or the trie — still holds is never recycled.
Eviction is LRU over trie entries no slot references
(:meth:`_evict_one`), triggered on demand when an allocation finds the
free list empty; ``free_pages_of`` therefore counts free + evictable.
Under ``kv_sharding="dp"`` the tries are per shard and
:meth:`match_prefix` is the scheduler's cache-aware placement hint, so
hits are shard-local by construction. With ``prefix_cache=False``
(default) refcounts are uniformly 1 and every code path reduces to the
pre-prefix behaviour.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import kv_cache
from repro.serve.state_cache import KV_SHARDINGS, StateCache, _round_up

__all__ = ["KV_SHARDINGS", "PagedKVCache"]


class _TrieNode:
    """One published full page of a shard's prefix trie: ``key`` is the
    exact ``page_size`` token ids at this depth (as bytes — content is
    the hash), ``page`` the physical page holding their K/V, ``tick``
    the last match/publish time (LRU eviction order). Each shard's root
    is a keyless sentinel with ``page == -1``."""
    __slots__ = ("key", "page", "parent", "children", "tick")

    def __init__(self, key: bytes, page: int,
                 parent: Optional["_TrieNode"]):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: Dict[bytes, "_TrieNode"] = {}
        self.tick = 0


class PagedKVCache(StateCache):
    kind = "paged"

    def __init__(self, cfg: ArchConfig, *, num_pages: int, page_size: int,
                 max_slots: int, max_pages_per_seq: int,
                 dtype=jnp.bfloat16, dist=None,
                 kv_sharding: str = "replicated", shards: int = 0,
                 prefix_cache: bool = False):
        """``num_pages=0`` auto-sizes the pool to the worst case (every
        slot's full ``max_pages_per_seq`` budget, plus one sink page per
        shard) — the sizing lives here, next to the rounding rules it
        depends on, so callers cannot drift out of sync with them.
        ``prefix_cache=True`` turns on cross-request prefix reuse (see
        module docstring)."""
        super().__init__(cfg, max_slots=max_slots, dist=dist,
                         kv_sharding=kv_sharding, shards=shards)
        self.page_size = int(page_size)
        self.max_pages_per_seq = int(max_pages_per_seq)
        # each shard needs its sink + >= 1 real page
        if num_pages == 0:      # auto: every slot's worst-case budget
            num_pages = self.max_slots * max_pages_per_seq + self.n_shards
        self.num_pages = max(_round_up(num_pages, self.n_shards),
                             2 * self.n_shards)
        self.pages_per_shard = self.num_pages // self.n_shards

        self.pools: Any = kv_cache.init_paged_pools(cfg, self.num_pages,
                                                    page_size, dtype)
        if self.pool_sharding is not None:
            self.pools = jax.device_put(self.pools, self.pool_sharding)

        # -- host allocator state --------------------------------------
        # per-shard free lists; local page 0 of each shard reserved as
        # that shard's masked-write sink
        self._free_by_shard: List[List[int]] = [
            list(range((s + 1) * self.pages_per_shard - 1,
                       s * self.pages_per_shard, -1))
            for s in range(self.n_shards)]
        self.page_table = np.zeros((self.max_slots, max_pages_per_seq),
                                   np.int32)
        for slot in range(self.max_slots):
            self.page_table[slot, :] = self.sink_page(
                self.shard_of_slot(slot))
        self._slot_pages: List[List[int]] = [[] for _ in
                                             range(self.max_slots)]
        # rid -> (host page-content tree, page count, owning shard):
        # preempted-by-offload requests parked until resume
        self._offloaded: Dict[int, Tuple[Any, int, int]] = {}
        self.peak_used_pages = 0
        self._peak_used_by_shard = [0] * self.n_shards

        # -- cross-request prefix cache --------------------------------
        # refcount per physical page: #slots binding it, +1 while it is
        # published in the trie; free pages are exactly refs == 0. With
        # prefix_cache off the tries stay empty and refs stay <= 1, so
        # the allocator reduces to the refcount-free behaviour.
        self.prefix_enabled = bool(prefix_cache)
        self._refs = np.zeros(self.num_pages, np.int32)
        self._trie_roots: List[_TrieNode] = [
            _TrieNode(b"", -1, None) for _ in range(self.n_shards)]
        self._node_of_page: Dict[int, _TrieNode] = {}
        self._tick = 0
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.prefix_hit_tokens = 0
        self.prefix_evicted_pages = 0
        self.prefix_cow_copies = 0
        self.prefix_cow_bytes = 0

    # -- shard topology --------------------------------------------------
    def shard_of_page(self, page: int) -> int:
        return page // self.pages_per_shard

    def sink_page(self, shard: int) -> int:
        """The shard's reserved masked-write sink (its local page 0)."""
        return shard * self.pages_per_shard

    @property
    def shard_capacity_pages(self) -> int:
        """Allocatable pages per shard (the sink is reserved)."""
        return self.pages_per_shard - 1

    # -- budget ----------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        return -(-int(tokens) // self.page_size)

    def free_pages_of(self, shard: int) -> int:
        """*Allocatable* pages on ``shard``: the free list plus trie-only
        pages (no slot reference) that eviction can reclaim on demand.
        Identical to the free-list length with the prefix cache off."""
        return len(self._free_by_shard[shard]) + self._reclaimable_of(shard)

    @property
    def _free(self) -> List[int]:
        """All free pages across shards (flat, read-only view)."""
        return [p for fl in self._free_by_shard for p in fl]

    @property
    def free_pages(self) -> int:
        return sum(self.free_pages_of(s) for s in range(self.n_shards))

    @property
    def free_units(self) -> int:
        return self.free_pages

    def free_units_of(self, shard: int) -> int:
        return self.free_pages_of(shard)

    def record_metrics(self, registry) -> None:
        super().record_metrics(registry)
        self.record_shard_metrics(registry)

    def record_shard_metrics(self, registry) -> None:
        """Paged-only per-shard gauges (also exported by a composite
        cache on behalf of its paged side)."""
        free = registry.gauge("repro_kv_free_pages",
                              "free KV pages per shard", ["shard"])
        held = registry.gauge("repro_kv_held_bytes",
                              "resident KV bytes per shard", ["shard"])
        for s in range(self.n_shards):
            free.labels(shard=s).set(self.free_pages_of(s))
            held.labels(shard=s).set(
                self.used_pages_of(s) * self.page_bytes)
        if not self.prefix_enabled:
            return
        g = registry.gauge
        cached = g("repro_prefix_cached_pages",
                   "pages published in the prefix trie", ["shard"])
        shared = g("repro_prefix_shared_pages",
                   "pages with more than one referent", ["shard"])
        for s in range(self.n_shards):
            cached.labels(shard=s).set(self.prefix_cached_pages_of(s))
            shared.labels(shard=s).set(self.prefix_shared_pages_of(s))
        g("repro_prefix_hits",
          "prefix-cache admission hits").set(self.prefix_hits)
        g("repro_prefix_misses",
          "prefix-cache admission misses").set(self.prefix_misses)
        g("repro_prefix_hit_tokens",
          "prompt tokens served from the prefix cache"
          ).set(self.prefix_hit_tokens)
        g("repro_prefix_evicted_pages",
          "trie references dropped by eviction/steal"
          ).set(self.prefix_evicted_pages)
        g("repro_prefix_cow_copies",
          "copy-on-write page duplications").set(self.prefix_cow_copies)
        g("repro_prefix_cow_bytes",
          "bytes duplicated by copy-on-write").set(self.prefix_cow_bytes)

    @property
    def used_pages(self) -> int:
        """*Physical* occupancy: pages not on a free list. A page shared
        by several slots and/or the prefix trie counts exactly once —
        this (not per-slot sums) is what peaks and the held-bytes gauges
        report."""
        return (self.num_pages - self.n_shards) - sum(
            len(fl) for fl in self._free_by_shard)

    def used_pages_of(self, shard: int) -> int:
        return self.shard_capacity_pages - len(self._free_by_shard[shard])

    @property
    def max_slot_tokens(self) -> int:
        """Per-request token ceiling: the per-sequence page budget, or a
        whole shard's allocatable pages, whichever binds first."""
        return self.page_size * min(self.max_pages_per_seq,
                                    self.shard_capacity_pages)

    def can_admit(self, total_tokens: int,
                  shard: Optional[int] = None) -> bool:
        """Can ``total_tokens`` be reserved — on ``shard``, or on the
        least-loaded shard when None?"""
        need = self.pages_for(total_tokens)
        free = (max(self.free_pages_of(s) for s in range(self.n_shards))
                if shard is None else self.free_pages_of(shard))
        return (need <= free
                and need <= self.max_pages_per_seq
                and total_tokens <= self.max_pages_per_seq * self.page_size)

    def best_shard(self, total_tokens: int,
                   candidates: Optional[Sequence[int]] = None
                   ) -> Optional[int]:
        """Least-loaded placement: among ``candidates`` (default: all
        shards), the one with the most free pages that can still admit
        ``total_tokens``; ties break to the lowest shard id. None when
        no shard fits."""
        cands = range(self.n_shards) if candidates is None else candidates
        best = None
        for s in cands:
            if not self.can_admit(total_tokens, s):
                continue
            if best is None or self.free_pages_of(s) > \
                    self.free_pages_of(best):
                best = s
        return best

    # -- slot lifecycle --------------------------------------------------
    def _note_peak(self, shard: int) -> None:
        self.peak_used_pages = max(self.peak_used_pages, self.used_pages)
        self._peak_used_by_shard[shard] = max(
            self._peak_used_by_shard[shard], self.used_pages_of(shard))

    def _take_page(self, shard: int) -> Optional[int]:
        """Pop a free page of ``shard``, evicting least-recently-matched
        trie-only entries when the free list runs dry. None when the
        shard is truly dry (caller preempts or reports infeasible)."""
        fl = self._free_by_shard[shard]
        while not fl:
            if not self._evict_one(shard):
                return None
        page = fl.pop()
        assert self._refs[page] == 0, f"free page {page} has references"
        self._refs[page] = 1
        return page

    def _release_page(self, page: int) -> None:
        """Drop one reference; a page frees only at refcount zero, so a
        page another slot — or the trie — still holds is never recycled."""
        refs = int(self._refs[page]) - 1
        assert refs >= 0, f"double free of page {page}"
        self._refs[page] = refs
        if refs == 0:
            self._free_by_shard[self.shard_of_page(page)].append(page)

    def _bind(self, slot: int, pages: List[int], tokens: int) -> None:
        shard = self.shard_of_slot(slot)
        self._slot_pages[slot] = pages
        self.page_table[slot, :] = self.sink_page(shard)
        self.page_table[slot, :len(pages)] = pages
        self.lens[slot] = tokens
        self._note_peak(shard)

    def alloc_slot(self, slot: int, tokens: int) -> None:
        """Reserve ``pages_for(tokens)`` pages of the slot's shard — the
        full budget (blocking admission) or just an initial watermark
        (the on-demand path, which then grows via :meth:`grow_slot`)."""
        assert not self._slot_pages[slot], f"slot {slot} already allocated"
        shard = self.shard_of_slot(slot)
        need = self.pages_for(tokens)
        assert self.can_admit(tokens, shard), \
            f"alloc_slot without can_admit (shard {shard})"
        pages = [self._take_page(shard) for _ in range(need)]
        assert None not in pages, f"shard {shard} ran dry mid-alloc"
        self._bind(slot, pages, 0)

    def slot_page_count(self, slot: int) -> int:
        return len(self._slot_pages[slot])

    def slot_capacity(self, slot: int) -> int:
        """Tokens the slot can hold with its currently-bound pages."""
        return len(self._slot_pages[slot]) * self.page_size

    def held_bytes(self, slot: int) -> int:
        """Bytes this slot holds *exclusively*. With the prefix cache on
        a shared page is attributed to no slot (pool-level accounting —
        ``used_pages_of`` counts it exactly once); a page the slot shares
        only with the trie still counts as the slot's."""
        if not self.prefix_enabled:
            return self.slot_page_count(slot) * self.page_bytes
        mine = sum(
            1 for p in self._slot_pages[slot]
            if int(self._refs[p]) - (p in self._node_of_page) == 1)
        return mine * self.page_bytes

    def grow_slot(self, slot: int) -> bool:
        """Bind one more page of the slot's shard. False when that shard
        is dry (the caller preempts a victim *on that shard* and
        retries)."""
        held = self._slot_pages[slot]
        assert len(held) < self.max_pages_per_seq, \
            f"slot {slot} grew past its per-sequence page budget"
        shard = self.shard_of_slot(slot)
        page = self._take_page(shard)
        if page is None:
            return False
        self.page_table[slot, len(held)] = page
        held.append(page)
        self._note_peak(shard)
        return True

    def free_slot(self, slot: int) -> None:
        shard = self.shard_of_slot(slot)
        for page in reversed(self._slot_pages[slot]):
            self._release_page(page)
        self._slot_pages[slot] = []
        self.page_table[slot, :] = self.sink_page(shard)
        self.lens[slot] = 0

    # -- preempt-by-offload ----------------------------------------------
    def offload_slot(self, slot: int, rid: int) -> int:
        """Swap the slot's pages out to the host pool (keyed by request
        id) and free them to the slot's shard. Only the pages covering
        ``lens[slot]`` are copied — growth can run ahead of a chunk that
        was then preempted away, and those tail pages hold nothing worth
        saving. Returns bytes copied."""
        shard = self.shard_of_slot(slot)
        pages = self._slot_pages[slot]
        need = self.pages_for(int(self.lens[slot]))
        assert pages and need >= 1, f"offload of empty slot {slot}"
        assert rid not in self._offloaded, f"rid {rid} already offloaded"
        assert need <= len(pages), \
            f"slot {slot} holds {len(pages)} pages < lens needs {need}"
        for page in reversed(pages[need:]):  # trim unwritten growth
            self._release_page(page)
        pages = self._slot_pages[slot] = pages[:need]
        host = kv_cache.extract_pages(self.pools, pages)
        nbytes = kv_cache.tree_bytes(host)
        self._offloaded[rid] = (host, len(pages), shard)
        self.swap_out_bytes += nbytes
        self.free_slot(slot)
        return nbytes

    def offloaded_pages(self, rid: int) -> int:
        return self._offloaded[rid][1]

    def offloaded_shard(self, rid: int) -> int:
        """The shard an offloaded request must restore onto (sticky)."""
        return self._offloaded[rid][2]

    def can_restore(self, rid: int) -> bool:
        _, need, shard = self._offloaded[rid]
        return need <= self.free_pages_of(shard)

    def drop_offload(self, rid: int) -> None:
        """Discard a parked request's host pages (cancellation). The
        device pages were freed back to the shard at offload time, so
        nothing page-table-side changes — the snapshot just dies."""
        del self._offloaded[rid]

    def restore_slot(self, rid: int, slot: int, tokens: int) -> int:
        """Swap a preempted request's pages back in: allocate fresh
        physical pages on the owning shard (the table re-maps), copy the
        host contents into the pools, and rebind the slot at length
        ``tokens``. Returns bytes copied."""
        host, need, shard = self._offloaded[rid]
        # validate before popping: a refused restore must not lose the
        # parked pages
        assert not self._slot_pages[slot], f"slot {slot} already allocated"
        assert self.shard_of_slot(slot) == shard, \
            f"restore of rid {rid} onto slot {slot} (shard " \
            f"{self.shard_of_slot(slot)}) but its pages live on shard " \
            f"{shard} — placement is sticky"
        assert need <= self.free_pages_of(shard), \
            "restore_slot without can_restore"
        assert self.pages_for(tokens) == need, \
            f"restore of {tokens} tokens into {need} pages"
        del self._offloaded[rid]
        pages = [self._take_page(shard) for _ in range(need)]
        assert None not in pages, f"shard {shard} ran dry mid-restore"
        self.pools = kv_cache.insert_pages(
            self.pools, pages, host, sharding=self._replicated,
            out_sharding=self._pool_spec)
        self._bind(slot, pages, tokens)
        nbytes = kv_cache.tree_bytes(host)
        self.swap_in_bytes += nbytes
        return nbytes

    @property
    def offloaded_count(self) -> int:
        return len(self._offloaded)

    @property
    def host_bytes(self) -> int:
        """Bytes currently parked in the host offload pool."""
        return sum(kv_cache.tree_bytes(host)
                   for host, _, _ in self._offloaded.values())

    # -- cross-request prefix cache --------------------------------------
    def _page_keys(self, token_ids) -> List[bytes]:
        """Full-page content keys: the exact ``page_size`` token ids of
        each fully-covered page, as bytes."""
        ids = np.ascontiguousarray(np.asarray(token_ids, np.int32))
        ps = self.page_size
        return [ids[i * ps:(i + 1) * ps].tobytes()
                for i in range(len(ids) // ps)]

    def _walk(self, shard: int, keys: Sequence[bytes]) -> List[_TrieNode]:
        """Longest-prefix match: the published trie nodes for the
        leading full pages of ``keys`` on ``shard``."""
        node, path = self._trie_roots[shard], []
        for key in keys:
            child = node.children.get(key)
            if child is None:
                break
            path.append(child)
            node = child
        return path

    def _reclaimable_of(self, shard: int) -> int:
        """Trie-held pages of ``shard`` no slot references — evictable
        on demand, hence allocatable."""
        if not self._node_of_page:
            return 0
        return sum(1 for p in self._node_of_page
                   if self._refs[p] == 1 and self.shard_of_page(p) == shard)

    def _detach(self, node: _TrieNode) -> int:
        """Unpublish ``node``'s whole subtree (children key on the full
        path, so they cannot outlive it). Slot-bound descendants lose
        only the trie's reference and live on; unreferenced ones free.
        Returns the number of pages whose trie reference was dropped."""
        del node.parent.children[node.key]
        node.parent = None
        stack, dropped = [node], 0
        while stack:
            cur = stack.pop()
            stack.extend(cur.children.values())
            cur.children = {}
            del self._node_of_page[cur.page]
            self._release_page(cur.page)
            dropped += 1
        return dropped

    def _evict_one(self, shard: int) -> bool:
        """Evict the least-recently-matched trie entry of ``shard`` that
        no slot references (refcount 1 — trie only); frees >= 1 page.
        False when nothing on the shard is evictable."""
        victim = None
        stack = list(self._trie_roots[shard].children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if self._refs[node.page] == 1 and \
                    (victim is None or node.tick < victim.tick):
                victim = node
        if victim is None:
            return False
        self.prefix_evicted_pages += self._detach(victim)
        return True

    def match_prefix(self, token_ids, total_tokens: int,
                     candidates: Optional[Sequence[int]] = None
                     ) -> Tuple[Optional[int], int]:
        """Cache-aware placement probe: among ``candidates`` (default:
        all shards), the shard holding the longest published prefix of
        ``token_ids`` that can also still fit the rest of the
        ``total_tokens`` reservation. Returns ``(shard, cached_tokens)``
        — ``(None, 0)`` on a miss, and the caller falls back to
        :meth:`best_shard`. Read-only: :meth:`alloc_slot_prefix` binds."""
        if not self.prefix_enabled or len(token_ids) < 2:
            return None, 0
        need = self.pages_for(total_tokens)
        if need > self.max_pages_per_seq or \
                total_tokens > self.max_pages_per_seq * self.page_size:
            return None, 0
        keys = self._page_keys(token_ids)
        best, best_cached = None, 0
        cands = range(self.n_shards) if candidates is None else candidates
        for s in cands:
            path = self._walk(s, keys)
            cached = min(len(path) * self.page_size, len(token_ids) - 1)
            if cached <= best_cached:
                continue
            bound = self.pages_for(cached)
            # fresh pages still needed (+1 when the hit ends mid-page:
            # that shared page gets copy-on-written before the tail
            # prefill writes into it)
            fresh = need - bound + (1 if cached % self.page_size else 0)
            avail = (len(self._free_by_shard[s]) + self._reclaimable_of(s)
                     - sum(1 for n in path[:bound]
                           if self._refs[n.page] == 1))
            if fresh > avail:
                continue
            best, best_cached = s, cached
        return best, best_cached

    def alloc_slot_prefix(self, slot: int, tokens: int, token_ids,
                          *, page_aligned: bool = False) -> int:
        """Admission with prefix reuse: bind the longest published
        prefix of ``token_ids`` on the slot's shard (refcount +1 per hit
        page — their K/V is *not* recomputed), then take fresh pages for
        the rest of the ``tokens`` reservation. Returns the cached token
        count; the caller starts prefill there. The hit is capped at
        ``len(token_ids) - 1`` so the tail is never empty (the final
        prefill chunk must produce the first-sample logits).

        ``page_aligned=True`` (the full-reserve scheduler) floors the
        hit to a page boundary: the tail then never writes a shared
        page, so a fully-reserved slot never needs a copy-on-write
        target page beyond its reservation."""
        if not self.prefix_enabled:
            self.alloc_slot(slot, tokens)
            return 0
        assert not self._slot_pages[slot], f"slot {slot} already allocated"
        shard = self.shard_of_slot(slot)
        path = self._walk(shard, self._page_keys(token_ids))
        cached = min(len(path) * self.page_size, len(token_ids) - 1)
        if page_aligned:
            cached -= cached % self.page_size
        if cached <= 0:
            self.prefix_misses += 1
            self.alloc_slot(slot, tokens)
            return 0
        bound = self.pages_for(cached)
        path = path[:bound]
        # bind the hits *first*: refcount >= 2 shields them (and their
        # ancestors) from the evictions the fresh takes below may run
        self._tick += 1
        for node in path:
            self._refs[node.page] += 1
            node.tick = self._tick
        pages = [node.page for node in path]
        for _ in range(self.pages_for(tokens) - bound):
            page = self._take_page(shard)
            assert page is not None, \
                f"alloc_slot_prefix without match_prefix feasibility " \
                f"(shard {shard})"
            pages.append(page)
        self._bind(slot, pages, cached)
        self.prefix_hits += 1
        self.prefix_hit_tokens += cached
        return cached

    def cache_slot_prefix(self, slot: int, token_ids) -> None:
        """Publish the slot's written full pages into its shard's trie
        (one trie reference each). Idempotent: pages already published
        under the same token path are just tick-refreshed. Only pages
        fully covered by both ``lens[slot]`` and ``token_ids`` qualify —
        a partial page is still being written to."""
        if not self.prefix_enabled or not self._slot_pages[slot]:
            return
        shard = self.shard_of_slot(slot)
        n_tok = min(len(token_ids), int(self.lens[slot]))
        keys = self._page_keys(np.asarray(token_ids, np.int32)[:n_tok])
        self._tick += 1
        node = self._trie_roots[shard]
        for i, key in enumerate(keys):
            child = node.children.get(key)
            if child is None:
                page = self._slot_pages[slot][i]
                if page in self._node_of_page:
                    # already published elsewhere (a CoW copy of a still
                    # cached page): never double-index a physical page
                    break
                child = _TrieNode(key, page, node)
                node.children[key] = child
                self._node_of_page[page] = child
                self._refs[page] += 1
            child.tick = self._tick
            node = child

    def ensure_private(self, slot: int, tokens: int) -> bool:
        """Copy-on-write: make every page the next writes (through token
        position ``tokens``) land in exclusive to this slot. A shared
        page is copied device-side into a fresh page (the trie and other
        slots keep the original); when the shard cannot supply a copy
        target and the trie is the only other referent, the cache entry
        is *stolen* (detached) instead — zero-copy, and the reason a
        sole request can always make progress. False only when another
        slot shares the page and no page can be freed: the engine then
        preempts a victim on this shard and retries."""
        if not self.prefix_enabled:
            return True
        pages = self._slot_pages[slot]
        lo = int(self.lens[slot]) // self.page_size
        hi = min(self.pages_for(tokens), len(pages))
        idx = [i for i in range(lo, hi) if self._refs[pages[i]] > 1]
        if not idx:
            return True
        shard = self.shard_of_slot(slot)
        copies: List[Tuple[int, int, int]] = []   # (pos, shared, fresh)
        for i in idx:
            page = pages[i]
            node = self._node_of_page.get(page)
            fresh = self._take_page(shard)
            if fresh is None:
                if node is not None and int(self._refs[page]) == 2:
                    # dry, but only the trie shares it: steal the entry
                    self.prefix_evicted_pages += self._detach(node)
                    continue
                for _, _, taken in copies:  # roll back this call's takes
                    self._release_page(taken)
                return False
            copies.append((i, page, fresh))
        if copies:
            self.pools = kv_cache.copy_pages(
                self.pools, [c[1] for c in copies], [c[2] for c in copies],
                out_sharding=self._pool_spec)
            for i, shared, fresh in copies:
                pages[i] = fresh
                self.page_table[slot, i] = fresh
                self._release_page(shared)
            self.prefix_cow_copies += len(copies)
            self.prefix_cow_bytes += len(copies) * self.page_bytes
            self._note_peak(shard)
        return True

    def prefix_cached_pages_of(self, shard: int) -> int:
        """Pages currently published in ``shard``'s trie."""
        return sum(1 for p in self._node_of_page
                   if self.shard_of_page(p) == shard)

    def prefix_shared_pages_of(self, shard: int) -> int:
        """Pages of ``shard`` with more than one referent."""
        lo = shard * self.pages_per_shard
        return int(np.count_nonzero(
            self._refs[lo:lo + self.pages_per_shard] >= 2))

    def check_integrity(self) -> None:
        """Refcount-conservation audit (test hook): every page is free
        (refcount 0, on its shard's free list exactly once), a reserved
        sink, or referenced with a refcount equal to its referent count
        (binding slots + trie); trie entries are shard-local and
        consistent with ``_node_of_page``. Raises AssertionError on any
        leak, double-free or double-booking."""
        refs = np.zeros(self.num_pages, np.int64)
        seen_free: set = set()
        for s, fl in enumerate(self._free_by_shard):
            assert len(set(fl)) == len(fl), f"shard {s} free-list dupes"
            for p in fl:
                assert self.shard_of_page(p) == s, \
                    f"page {p} on shard {s}'s free list"
                assert p != self.sink_page(s), "sink page freed"
            seen_free.update(fl)
        for slot, pages in enumerate(self._slot_pages):
            for p in pages:
                assert self.shard_of_page(p) == self.shard_of_slot(slot), \
                    f"slot {slot} bound page {p} across shards"
                refs[p] += 1
        n_nodes = 0
        for s, root in enumerate(self._trie_roots):
            stack = list(root.children.values())
            while stack:
                node = stack.pop()
                stack.extend(node.children.values())
                n_nodes += 1
                assert self.shard_of_page(node.page) == s, \
                    "trie entry crossed a shard boundary"
                assert self._node_of_page.get(node.page) is node, \
                    f"trie index out of sync for page {node.page}"
                refs[node.page] += 1
        assert n_nodes == len(self._node_of_page), \
            "orphaned trie index entries"
        sinks = {self.sink_page(s) for s in range(self.n_shards)}
        for p in range(self.num_pages):
            assert int(self._refs[p]) == int(refs[p]), \
                f"page {p}: refcount {int(self._refs[p])} != " \
                f"{int(refs[p])} referents"
            if p in sinks:
                assert refs[p] == 0 and p not in seen_free, \
                    f"sink page {p} misused"
            elif refs[p] == 0:
                assert p in seen_free, f"page {p} leaked"
            else:
                assert p not in seen_free, f"page {p} double-booked"

    # -- device views ----------------------------------------------------
    # NOTE: always .copy() — jnp.asarray of a host numpy array can be
    # zero-copy on CPU, and the engine mutates page_table/lens in place
    # while the dispatched step is still running asynchronously. Under a
    # mesh the copies are device_put with one consistent committed
    # sharding per role (replicated, or slot-sharded over "data" for the
    # DP layout), so the jit caches never churn.
    @property
    def page_table_width(self) -> int:
        return self.max_pages_per_seq

    def device_page_table(self, slot: Optional[int] = None):
        if slot is None:
            return self.to_device_slots(self.page_table.copy())
        return self.to_device(self.page_table[slot:slot + 1].copy())

    def device_sinks(self):
        """Per-slot sink page ids ``[max_slots]`` for the decode step's
        masked-write redirect (constant for the engine's lifetime)."""
        sinks = np.asarray([self.sink_page(self.shard_of_slot(s))
                            for s in range(self.max_slots)], np.int32)
        return self.to_device_slots(sinks)

    def sink_row(self, slot: int) -> np.ndarray:
        """``[1]`` sink page id for one slot's prefill chunk."""
        return np.asarray([self.sink_page(self.shard_of_slot(slot))],
                          np.int32)

    # -- accounting ------------------------------------------------------
    @property
    def cache_bytes(self) -> int:
        """Total logical bytes of the allocated pools (constant)."""
        return kv_cache.cache_bytes(self.pools)

    @property
    def per_device_cache_bytes(self) -> int:
        """Pool bytes resident on one device (the DP layout divides the
        page axis over the shards; replication does not)."""
        return self.cache_bytes // self.n_shards

    @property
    def page_bytes(self) -> int:
        """Bytes of one page across all layers."""
        return self.cache_bytes // self.num_pages

    @property
    def used_bytes(self) -> int:
        """Bytes of pages currently bound to live sequences."""
        return self.used_pages * self.page_bytes

    @property
    def peak_used_bytes(self) -> int:
        return self.peak_used_pages * self.page_bytes

    @property
    def per_device_peak_used_bytes(self) -> int:
        """Peak KV bytes resident on one device: the busiest shard's
        peak under "dp" (each device holds only its shard's pages); the
        global peak when every device replicates the whole pool."""
        if self.n_shards == 1:
            return self.peak_used_bytes
        return max(self._peak_used_by_shard) * self.page_bytes
