"""Paged KV cache: host-side page allocator over the device page pools —
the paged implementation of the :class:`~repro.serve.state_cache.StateCache`
protocol (and, with an MLA config, the **paged latent cache**: the same
allocator over per-token compressed-latent pools ``c_kv``/``k_rope``
instead of full K/V — see ``models/kv_cache.paged_layer_pool``).

Device side (``models/kv_cache.init_paged_pools``): per attention layer a
global pool ``[num_pages, page_size, kv_heads, head_dim]`` (or
``[num_pages, page_size, kv_lora_rank]`` + ``[num_pages, page_size,
rope_head_dim]`` for MLA) shared by every in-flight sequence. Host side
(this module): free lists of physical pages, a ``[max_slots,
max_pages_per_seq]`` page table and per-slot lengths, mirrored to device
as plain int32 arrays each step — plus a host-side offload pool holding
the page contents of preempted-by-offload requests until they resume.

Invariants (stated per shard — one shard unsharded, ``dp`` shards under
``kv_sharding="dp"``):
* each shard's local page 0 is reserved — never allocated — as the
  write sink for that shard's masked (padding / inactive-slot)
  scatters; globally those are pages ``{s * pages_per_shard}``;
* pages are allocated either **up front** for a slot's whole budget
  (``alloc_slot`` with the full prompt + max_new token count — the
  conservative admission-blocking baseline) or **on demand** one page at
  a time (``grow_slot`` — the preemptive scheduler's path, where a
  shard running dry triggers a preemption on that shard instead of a
  deadlock);
* a slot only ever binds pages of its own shard (slot ``i`` lives on
  shard ``i // slots_per_shard``), so decode stays data-parallel: no
  slot's reads or writes cross a shard boundary;
* freed slots have their page-table row reset to their shard's sink, so
  a stale slot's decode writes land in the sink page, never in pages
  that were handed to another sequence;
* an offloaded request holds **zero** device pages: ``offload_slot``
  copies its pages to host and returns them to its shard's free list,
  and ``restore_slot`` later re-allocates **on the same shard**
  (placement is sticky for a request's lifetime; different physical
  pages are fine — the page table re-maps them) and copies the contents
  back.

Mesh-sharded serving (``dist`` given), two layouts:

* ``kv_sharding="replicated"`` (the PR 4 baseline): pools, page table
  and lens are replicated across every device — each device needs the
  whole pool, so adding devices buys compute but zero KV capacity.
* ``kv_sharding="dp"``: the pool's **page axis is sharded over the mesh
  ``data`` axis** (each of the ``dp`` device groups physically holds
  ``num_pages / dp`` pages — per-device resident KV bytes drop ``dp``×)
  and the page table / lens / decode batch shard over the slot axis, so
  decode runs data-parallel: each dp group attends only its own slots
  against only its own pages. Chunked prefill keeps the EP-sharded
  ``pipelined_moe`` layout; its KV scatter lands in the owning shard's
  pages directly (GSPMD routes the writes — the prefill→decode handoff
  needs no copy) and the step output is pinned back to the page-sharded
  layout (``StateCache.pin_pools``). Each shard keeps its **own
  host-side free list**; admission places a request on a shard
  (least-loaded, sticky) and pool-dry is a per-shard event.

``cache_bytes``/``used_bytes`` report *logical* pool bytes;
``per_device_cache_bytes`` / ``per_device_peak_used_bytes`` report the
per-device residency (divided by ``n_shards`` under ``dp``, with
``replicas`` physical copies each). Host-offload round-trips are
unchanged per shard: pages are extracted from (and re-inserted into) the
pools with the pool layout preserved (``insert_pages(out_sharding=)``).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import kv_cache
from repro.serve.state_cache import KV_SHARDINGS, StateCache, _round_up

__all__ = ["KV_SHARDINGS", "PagedKVCache"]


class PagedKVCache(StateCache):
    kind = "paged"

    def __init__(self, cfg: ArchConfig, *, num_pages: int, page_size: int,
                 max_slots: int, max_pages_per_seq: int,
                 dtype=jnp.bfloat16, dist=None,
                 kv_sharding: str = "replicated", shards: int = 0):
        """``num_pages=0`` auto-sizes the pool to the worst case (every
        slot's full ``max_pages_per_seq`` budget, plus one sink page per
        shard) — the sizing lives here, next to the rounding rules it
        depends on, so callers cannot drift out of sync with them."""
        super().__init__(cfg, max_slots=max_slots, dist=dist,
                         kv_sharding=kv_sharding, shards=shards)
        self.page_size = int(page_size)
        self.max_pages_per_seq = int(max_pages_per_seq)
        # each shard needs its sink + >= 1 real page
        if num_pages == 0:      # auto: every slot's worst-case budget
            num_pages = self.max_slots * max_pages_per_seq + self.n_shards
        self.num_pages = max(_round_up(num_pages, self.n_shards),
                             2 * self.n_shards)
        self.pages_per_shard = self.num_pages // self.n_shards

        self.pools: Any = kv_cache.init_paged_pools(cfg, self.num_pages,
                                                    page_size, dtype)
        if self.pool_sharding is not None:
            self.pools = jax.device_put(self.pools, self.pool_sharding)

        # -- host allocator state --------------------------------------
        # per-shard free lists; local page 0 of each shard reserved as
        # that shard's masked-write sink
        self._free_by_shard: List[List[int]] = [
            list(range((s + 1) * self.pages_per_shard - 1,
                       s * self.pages_per_shard, -1))
            for s in range(self.n_shards)]
        self.page_table = np.zeros((self.max_slots, max_pages_per_seq),
                                   np.int32)
        for slot in range(self.max_slots):
            self.page_table[slot, :] = self.sink_page(
                self.shard_of_slot(slot))
        self._slot_pages: List[List[int]] = [[] for _ in
                                             range(self.max_slots)]
        # rid -> (host page-content tree, page count, owning shard):
        # preempted-by-offload requests parked until resume
        self._offloaded: Dict[int, Tuple[Any, int, int]] = {}
        self.peak_used_pages = 0
        self._peak_used_by_shard = [0] * self.n_shards

    # -- shard topology --------------------------------------------------
    def shard_of_page(self, page: int) -> int:
        return page // self.pages_per_shard

    def sink_page(self, shard: int) -> int:
        """The shard's reserved masked-write sink (its local page 0)."""
        return shard * self.pages_per_shard

    @property
    def shard_capacity_pages(self) -> int:
        """Allocatable pages per shard (the sink is reserved)."""
        return self.pages_per_shard - 1

    # -- budget ----------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        return -(-int(tokens) // self.page_size)

    def free_pages_of(self, shard: int) -> int:
        return len(self._free_by_shard[shard])

    @property
    def _free(self) -> List[int]:
        """All free pages across shards (flat, read-only view)."""
        return [p for fl in self._free_by_shard for p in fl]

    @property
    def free_pages(self) -> int:
        return sum(len(fl) for fl in self._free_by_shard)

    @property
    def free_units(self) -> int:
        return self.free_pages

    def free_units_of(self, shard: int) -> int:
        return self.free_pages_of(shard)

    def record_metrics(self, registry) -> None:
        super().record_metrics(registry)
        self.record_shard_metrics(registry)

    def record_shard_metrics(self, registry) -> None:
        """Paged-only per-shard gauges (also exported by a composite
        cache on behalf of its paged side)."""
        free = registry.gauge("repro_kv_free_pages",
                              "free KV pages per shard", ["shard"])
        held = registry.gauge("repro_kv_held_bytes",
                              "resident KV bytes per shard", ["shard"])
        for s in range(self.n_shards):
            free.labels(shard=s).set(self.free_pages_of(s))
            held.labels(shard=s).set(
                self.used_pages_of(s) * self.page_bytes)

    @property
    def used_pages(self) -> int:
        return (self.num_pages - self.n_shards) - self.free_pages

    def used_pages_of(self, shard: int) -> int:
        return self.shard_capacity_pages - self.free_pages_of(shard)

    @property
    def max_slot_tokens(self) -> int:
        """Per-request token ceiling: the per-sequence page budget, or a
        whole shard's allocatable pages, whichever binds first."""
        return self.page_size * min(self.max_pages_per_seq,
                                    self.shard_capacity_pages)

    def can_admit(self, total_tokens: int,
                  shard: Optional[int] = None) -> bool:
        """Can ``total_tokens`` be reserved — on ``shard``, or on the
        least-loaded shard when None?"""
        need = self.pages_for(total_tokens)
        free = (max(map(len, self._free_by_shard)) if shard is None
                else self.free_pages_of(shard))
        return (need <= free
                and need <= self.max_pages_per_seq
                and total_tokens <= self.max_pages_per_seq * self.page_size)

    def best_shard(self, total_tokens: int,
                   candidates: Optional[Sequence[int]] = None
                   ) -> Optional[int]:
        """Least-loaded placement: among ``candidates`` (default: all
        shards), the one with the most free pages that can still admit
        ``total_tokens``; ties break to the lowest shard id. None when
        no shard fits."""
        cands = range(self.n_shards) if candidates is None else candidates
        best = None
        for s in cands:
            if not self.can_admit(total_tokens, s):
                continue
            if best is None or self.free_pages_of(s) > \
                    self.free_pages_of(best):
                best = s
        return best

    # -- slot lifecycle --------------------------------------------------
    def _note_peak(self, shard: int) -> None:
        self.peak_used_pages = max(self.peak_used_pages, self.used_pages)
        self._peak_used_by_shard[shard] = max(
            self._peak_used_by_shard[shard], self.used_pages_of(shard))

    def alloc_slot(self, slot: int, tokens: int) -> None:
        """Reserve ``pages_for(tokens)`` pages of the slot's shard — the
        full budget (blocking admission) or just an initial watermark
        (the on-demand path, which then grows via :meth:`grow_slot`)."""
        assert not self._slot_pages[slot], f"slot {slot} already allocated"
        shard = self.shard_of_slot(slot)
        need = self.pages_for(tokens)
        assert self.can_admit(tokens, shard), \
            f"alloc_slot without can_admit (shard {shard})"
        fl = self._free_by_shard[shard]
        pages = [fl.pop() for _ in range(need)]
        self._slot_pages[slot] = pages
        self.page_table[slot, :] = self.sink_page(shard)
        self.page_table[slot, :need] = pages
        self.lens[slot] = 0
        self._note_peak(shard)

    def slot_page_count(self, slot: int) -> int:
        return len(self._slot_pages[slot])

    def slot_capacity(self, slot: int) -> int:
        """Tokens the slot can hold with its currently-bound pages."""
        return len(self._slot_pages[slot]) * self.page_size

    def held_bytes(self, slot: int) -> int:
        return self.slot_page_count(slot) * self.page_bytes

    def grow_slot(self, slot: int) -> bool:
        """Bind one more page of the slot's shard. False when that shard
        is dry (the caller preempts a victim *on that shard* and
        retries)."""
        held = self._slot_pages[slot]
        assert len(held) < self.max_pages_per_seq, \
            f"slot {slot} grew past its per-sequence page budget"
        shard = self.shard_of_slot(slot)
        fl = self._free_by_shard[shard]
        if not fl:
            return False
        page = fl.pop()
        self.page_table[slot, len(held)] = page
        held.append(page)
        self._note_peak(shard)
        return True

    def free_slot(self, slot: int) -> None:
        shard = self.shard_of_slot(slot)
        self._free_by_shard[shard].extend(reversed(self._slot_pages[slot]))
        self._slot_pages[slot] = []
        self.page_table[slot, :] = self.sink_page(shard)
        self.lens[slot] = 0

    # -- preempt-by-offload ----------------------------------------------
    def offload_slot(self, slot: int, rid: int) -> int:
        """Swap the slot's pages out to the host pool (keyed by request
        id) and free them to the slot's shard. Only the pages covering
        ``lens[slot]`` are copied — growth can run ahead of a chunk that
        was then preempted away, and those tail pages hold nothing worth
        saving. Returns bytes copied."""
        shard = self.shard_of_slot(slot)
        pages = self._slot_pages[slot]
        need = self.pages_for(int(self.lens[slot]))
        assert pages and need >= 1, f"offload of empty slot {slot}"
        assert rid not in self._offloaded, f"rid {rid} already offloaded"
        assert need <= len(pages), \
            f"slot {slot} holds {len(pages)} pages < lens needs {need}"
        self._free_by_shard[shard].extend(reversed(pages[need:]))  # trim
        pages = self._slot_pages[slot] = pages[:need]
        host = kv_cache.extract_pages(self.pools, pages)
        nbytes = kv_cache.tree_bytes(host)
        self._offloaded[rid] = (host, len(pages), shard)
        self.swap_out_bytes += nbytes
        self.free_slot(slot)
        return nbytes

    def offloaded_pages(self, rid: int) -> int:
        return self._offloaded[rid][1]

    def offloaded_shard(self, rid: int) -> int:
        """The shard an offloaded request must restore onto (sticky)."""
        return self._offloaded[rid][2]

    def can_restore(self, rid: int) -> bool:
        _, need, shard = self._offloaded[rid]
        return need <= self.free_pages_of(shard)

    def restore_slot(self, rid: int, slot: int, tokens: int) -> int:
        """Swap a preempted request's pages back in: allocate fresh
        physical pages on the owning shard (the table re-maps), copy the
        host contents into the pools, and rebind the slot at length
        ``tokens``. Returns bytes copied."""
        host, need, shard = self._offloaded[rid]
        # validate before popping: a refused restore must not lose the
        # parked pages
        assert not self._slot_pages[slot], f"slot {slot} already allocated"
        assert self.shard_of_slot(slot) == shard, \
            f"restore of rid {rid} onto slot {slot} (shard " \
            f"{self.shard_of_slot(slot)}) but its pages live on shard " \
            f"{shard} — placement is sticky"
        fl = self._free_by_shard[shard]
        assert need <= len(fl), "restore_slot without can_restore"
        assert self.pages_for(tokens) == need, \
            f"restore of {tokens} tokens into {need} pages"
        del self._offloaded[rid]
        pages = [fl.pop() for _ in range(need)]
        self.pools = kv_cache.insert_pages(
            self.pools, pages, host, sharding=self._replicated,
            out_sharding=self._pool_spec)
        self._slot_pages[slot] = pages
        self.page_table[slot, :] = self.sink_page(shard)
        self.page_table[slot, :need] = pages
        self.lens[slot] = tokens
        nbytes = kv_cache.tree_bytes(host)
        self.swap_in_bytes += nbytes
        self._note_peak(shard)
        return nbytes

    @property
    def offloaded_count(self) -> int:
        return len(self._offloaded)

    @property
    def host_bytes(self) -> int:
        """Bytes currently parked in the host offload pool."""
        return sum(kv_cache.tree_bytes(host)
                   for host, _, _ in self._offloaded.values())

    # -- device views ----------------------------------------------------
    # NOTE: always .copy() — jnp.asarray of a host numpy array can be
    # zero-copy on CPU, and the engine mutates page_table/lens in place
    # while the dispatched step is still running asynchronously. Under a
    # mesh the copies are device_put with one consistent committed
    # sharding per role (replicated, or slot-sharded over "data" for the
    # DP layout), so the jit caches never churn.
    @property
    def page_table_width(self) -> int:
        return self.max_pages_per_seq

    def device_page_table(self, slot: Optional[int] = None):
        if slot is None:
            return self.to_device_slots(self.page_table.copy())
        return self.to_device(self.page_table[slot:slot + 1].copy())

    def device_sinks(self):
        """Per-slot sink page ids ``[max_slots]`` for the decode step's
        masked-write redirect (constant for the engine's lifetime)."""
        sinks = np.asarray([self.sink_page(self.shard_of_slot(s))
                            for s in range(self.max_slots)], np.int32)
        return self.to_device_slots(sinks)

    def sink_row(self, slot: int) -> np.ndarray:
        """``[1]`` sink page id for one slot's prefill chunk."""
        return np.asarray([self.sink_page(self.shard_of_slot(slot))],
                          np.int32)

    # -- accounting ------------------------------------------------------
    @property
    def cache_bytes(self) -> int:
        """Total logical bytes of the allocated pools (constant)."""
        return kv_cache.cache_bytes(self.pools)

    @property
    def per_device_cache_bytes(self) -> int:
        """Pool bytes resident on one device (the DP layout divides the
        page axis over the shards; replication does not)."""
        return self.cache_bytes // self.n_shards

    @property
    def page_bytes(self) -> int:
        """Bytes of one page across all layers."""
        return self.cache_bytes // self.num_pages

    @property
    def used_bytes(self) -> int:
        """Bytes of pages currently bound to live sequences."""
        return self.used_pages * self.page_bytes

    @property
    def peak_used_bytes(self) -> int:
        return self.peak_used_pages * self.page_bytes

    @property
    def per_device_peak_used_bytes(self) -> int:
        """Peak KV bytes resident on one device: the busiest shard's
        peak under "dp" (each device holds only its shard's pages); the
        global peak when every device replicates the whole pool."""
        if self.n_shards == 1:
            return self.peak_used_bytes
        return max(self._peak_used_by_shard) * self.page_bytes
