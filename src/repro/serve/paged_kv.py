"""Paged KV cache: host-side page allocator over the device page pools.

Device side (``models/kv_cache.init_paged_pools``): per attention layer a
global pool ``[num_pages, page_size, kv_heads, head_dim]`` shared by every
in-flight sequence. Host side (this module): a free list of physical
pages, a ``[max_slots, max_pages_per_seq]`` page table and per-slot
lengths, mirrored to device as plain int32 arrays each step — plus a
host-side offload pool holding the page contents of preempted-by-offload
requests until they resume.

Invariants:
* page 0 is reserved — never allocated — as the write sink for masked
  (padding / inactive-slot) scatters;
* pages are allocated either **up front** for a slot's whole budget
  (``alloc_slot`` with the full prompt + max_new token count — the
  conservative admission-blocking baseline) or **on demand** one page at
  a time (``grow_slot`` — the preemptive scheduler's path, where running
  dry triggers a preemption instead of a deadlock);
* freed slots have their page-table row zeroed and length reset, so a
  stale slot's decode writes land in the sink page, never in pages that
  were handed to another sequence;
* an offloaded request holds **zero** device pages: ``offload_slot``
  copies its pages to host and returns them to the free list, and
  ``restore_slot`` later re-allocates (different physical pages are fine
  — the page table re-maps them) and copies the contents back.

Mesh-sharded serving (``dist`` given): the pools, page table and lens
are **replicated** across every device of the mesh — decode runs the
replicated psum-combine MoE layout where every device attends all
slots, so each device needs the whole pool. The allocator stays a
single host-side free list (one logical pool, N physical replicas);
``cache_bytes``/``used_bytes`` report *per-replica* bytes, with
``replicas`` as the multiplier. Host-offload round-trips are unchanged:
pages are extracted from (and re-inserted replicated into) the pools
exactly as on one device.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import kv_cache

__all__ = ["PagedKVCache"]


class PagedKVCache:
    def __init__(self, cfg: ArchConfig, *, num_pages: int, page_size: int,
                 max_slots: int, max_pages_per_seq: int,
                 dtype=jnp.bfloat16, dist=None):
        assert num_pages >= 2, "need at least the sink page + one real page"
        self.cfg = cfg
        self.page_size = int(page_size)
        self.num_pages = int(num_pages)
        self.max_slots = int(max_slots)
        self.max_pages_per_seq = int(max_pages_per_seq)
        self.dist = dist
        self._replicated = None
        if dist is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            self._replicated = NamedSharding(dist.mesh, PartitionSpec())
        self.pools: Any = kv_cache.init_paged_pools(cfg, num_pages,
                                                    page_size, dtype)
        if self._replicated is not None:
            self.pools = jax.device_put(self.pools, self._replicated)
        # page 0 reserved as the masked-write sink
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self.page_table = np.zeros((max_slots, max_pages_per_seq), np.int32)
        self.lens = np.zeros((max_slots,), np.int32)
        self._slot_pages: List[List[int]] = [[] for _ in range(max_slots)]
        # rid -> (host page-content tree, page count): preempted-by-
        # offload requests parked until resume
        self._offloaded: Dict[int, Tuple[Any, int]] = {}
        self.peak_used_pages = 0
        self.swap_out_bytes = 0
        self.swap_in_bytes = 0

    # -- budget ----------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        return -(-int(tokens) // self.page_size)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def can_admit(self, total_tokens: int) -> bool:
        need = self.pages_for(total_tokens)
        return (need <= len(self._free)
                and need <= self.max_pages_per_seq
                and total_tokens <= self.max_pages_per_seq * self.page_size)

    # -- slot lifecycle --------------------------------------------------
    def alloc_slot(self, slot: int, tokens: int) -> None:
        """Reserve ``pages_for(tokens)`` pages for the slot — the full
        budget (blocking admission) or just an initial watermark (the
        on-demand path, which then grows via :meth:`grow_slot`)."""
        assert not self._slot_pages[slot], f"slot {slot} already allocated"
        need = self.pages_for(tokens)
        assert self.can_admit(tokens), "alloc_slot without can_admit"
        pages = [self._free.pop() for _ in range(need)]
        self._slot_pages[slot] = pages
        self.page_table[slot, :] = 0
        self.page_table[slot, :need] = pages
        self.lens[slot] = 0
        self.peak_used_pages = max(self.peak_used_pages, self.used_pages)

    def slot_page_count(self, slot: int) -> int:
        return len(self._slot_pages[slot])

    def slot_capacity(self, slot: int) -> int:
        """Tokens the slot can hold with its currently-bound pages."""
        return len(self._slot_pages[slot]) * self.page_size

    def grow_slot(self, slot: int) -> bool:
        """Bind one more free page to the slot. False when the pool is
        dry (the caller preempts a victim and retries)."""
        held = self._slot_pages[slot]
        assert len(held) < self.max_pages_per_seq, \
            f"slot {slot} grew past its per-sequence page budget"
        if not self._free:
            return False
        page = self._free.pop()
        self.page_table[slot, len(held)] = page
        held.append(page)
        self.peak_used_pages = max(self.peak_used_pages, self.used_pages)
        return True

    def free_slot(self, slot: int) -> None:
        self._free.extend(reversed(self._slot_pages[slot]))
        self._slot_pages[slot] = []
        self.page_table[slot, :] = 0
        self.lens[slot] = 0

    # -- preempt-by-offload ----------------------------------------------
    def offload_slot(self, slot: int, rid: int) -> int:
        """Swap the slot's pages out to the host pool (keyed by request
        id) and free them. Only the pages covering ``lens[slot]`` are
        copied — growth can run ahead of a chunk that was then preempted
        away, and those tail pages hold nothing worth saving. Returns
        bytes copied."""
        pages = self._slot_pages[slot]
        need = self.pages_for(int(self.lens[slot]))
        assert pages and need >= 1, f"offload of empty slot {slot}"
        assert rid not in self._offloaded, f"rid {rid} already offloaded"
        assert need <= len(pages), \
            f"slot {slot} holds {len(pages)} pages < lens needs {need}"
        self._free.extend(reversed(pages[need:]))   # trim unused tail
        pages = self._slot_pages[slot] = pages[:need]
        host = kv_cache.extract_pages(self.pools, pages)
        nbytes = kv_cache.tree_bytes(host)
        self._offloaded[rid] = (host, len(pages))
        self.swap_out_bytes += nbytes
        self.free_slot(slot)
        return nbytes

    def offloaded_pages(self, rid: int) -> int:
        return self._offloaded[rid][1]

    def can_restore(self, rid: int) -> bool:
        return self._offloaded[rid][1] <= len(self._free)

    def restore_slot(self, rid: int, slot: int, tokens: int) -> int:
        """Swap a preempted request's pages back in: allocate fresh
        physical pages (the table re-maps), copy the host contents into
        the pools, and rebind the slot at length ``tokens``. Returns
        bytes copied."""
        host, need = self._offloaded.pop(rid)
        assert not self._slot_pages[slot], f"slot {slot} already allocated"
        assert need <= len(self._free), "restore_slot without can_restore"
        assert self.pages_for(tokens) == need, \
            f"restore of {tokens} tokens into {need} pages"
        pages = [self._free.pop() for _ in range(need)]
        self.pools = kv_cache.insert_pages(self.pools, pages, host,
                                           sharding=self._replicated)
        self._slot_pages[slot] = pages
        self.page_table[slot, :] = 0
        self.page_table[slot, :need] = pages
        self.lens[slot] = tokens
        nbytes = kv_cache.tree_bytes(host)
        self.swap_in_bytes += nbytes
        self.peak_used_pages = max(self.peak_used_pages, self.used_pages)
        return nbytes

    @property
    def offloaded_count(self) -> int:
        return len(self._offloaded)

    @property
    def host_bytes(self) -> int:
        """Bytes currently parked in the host offload pool."""
        return sum(kv_cache.tree_bytes(host)
                   for host, _ in self._offloaded.values())

    # -- device views ----------------------------------------------------
    # NOTE: always .copy() — jnp.asarray of a host numpy array can be
    # zero-copy on CPU, and the engine mutates page_table/lens in place
    # while the dispatched step is still running asynchronously. Under a
    # mesh the copies are device_put replicated, so every step input
    # carries one consistent committed sharding (no jit cache churn).
    def to_device(self, x):
        """Host array -> device array (replicated under a mesh)."""
        if self._replicated is not None:
            return jax.device_put(x, self._replicated)
        return jnp.asarray(x)

    def device_page_table(self, slot: Optional[int] = None):
        pt = (self.page_table if slot is None
              else self.page_table[slot:slot + 1])
        return self.to_device(pt.copy())

    def device_lens(self, slot: Optional[int] = None):
        ln = self.lens if slot is None else self.lens[slot:slot + 1]
        return self.to_device(ln.copy())

    @property
    def replicas(self) -> int:
        """Physical copies of the pool (mesh devices; 1 unsharded)."""
        return 1 if self.dist is None else self.dist.mesh.size

    # -- accounting ------------------------------------------------------
    @property
    def cache_bytes(self) -> int:
        """Total bytes of the allocated device pools (constant)."""
        return kv_cache.cache_bytes(self.pools)

    @property
    def page_bytes(self) -> int:
        """Bytes of one page across all layers."""
        return self.cache_bytes // self.num_pages

    @property
    def used_bytes(self) -> int:
        """Bytes of pages currently bound to live sequences."""
        return self.used_pages * self.page_bytes

    @property
    def peak_used_bytes(self) -> int:
        return self.peak_used_pages * self.page_bytes
