"""Paged KV cache: host-side page allocator over the device page pools.

Device side (``models/kv_cache.init_paged_pools``): per attention layer a
global pool ``[num_pages, page_size, kv_heads, head_dim]`` shared by every
in-flight sequence. Host side (this module): a free list of physical
pages, a ``[max_slots, max_pages_per_seq]`` page table and per-slot
lengths, mirrored to device as plain int32 arrays each step.

Invariants:
* page 0 is reserved — never allocated — as the write sink for masked
  (padding / inactive-slot) scatters;
* a slot's pages are reserved **up front** for its whole budget
  (prompt + max_new_tokens) at admission, so a running request can never
  deadlock on allocation (conservative vLLM-style admission, preemption
  is future work);
* freed slots have their page-table row zeroed and length reset, so a
  stale slot's decode writes land in the sink page, never in pages that
  were handed to another sequence.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import kv_cache


class PagedKVCache:
    def __init__(self, cfg: ArchConfig, *, num_pages: int, page_size: int,
                 max_slots: int, max_pages_per_seq: int,
                 dtype=jnp.bfloat16):
        assert num_pages >= 2, "need at least the sink page + one real page"
        self.cfg = cfg
        self.page_size = int(page_size)
        self.num_pages = int(num_pages)
        self.max_slots = int(max_slots)
        self.max_pages_per_seq = int(max_pages_per_seq)
        self.pools: Any = kv_cache.init_paged_pools(cfg, num_pages,
                                                    page_size, dtype)
        # page 0 reserved as the masked-write sink
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self.page_table = np.zeros((max_slots, max_pages_per_seq), np.int32)
        self.lens = np.zeros((max_slots,), np.int32)
        self._slot_pages: List[List[int]] = [[] for _ in range(max_slots)]
        self.peak_used_pages = 0

    # -- budget ----------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        return -(-int(tokens) // self.page_size)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def can_admit(self, total_tokens: int) -> bool:
        need = self.pages_for(total_tokens)
        return (need <= len(self._free)
                and need <= self.max_pages_per_seq
                and total_tokens <= self.max_pages_per_seq * self.page_size)

    # -- slot lifecycle --------------------------------------------------
    def alloc_slot(self, slot: int, total_tokens: int) -> None:
        """Reserve every page of the slot's budget up front."""
        assert not self._slot_pages[slot], f"slot {slot} already allocated"
        need = self.pages_for(total_tokens)
        assert self.can_admit(total_tokens), "alloc_slot without can_admit"
        pages = [self._free.pop() for _ in range(need)]
        self._slot_pages[slot] = pages
        self.page_table[slot, :] = 0
        self.page_table[slot, :need] = pages
        self.lens[slot] = 0
        self.peak_used_pages = max(self.peak_used_pages, self.used_pages)

    def free_slot(self, slot: int) -> None:
        self._free.extend(reversed(self._slot_pages[slot]))
        self._slot_pages[slot] = []
        self.page_table[slot, :] = 0
        self.lens[slot] = 0

    # -- device views ----------------------------------------------------
    # NOTE: always .copy() — jnp.asarray of a host numpy array can be
    # zero-copy on CPU, and the engine mutates page_table/lens in place
    # while the dispatched step is still running asynchronously.
    def device_page_table(self, slot: Optional[int] = None):
        pt = (self.page_table if slot is None
              else self.page_table[slot:slot + 1])
        return jnp.asarray(pt.copy())

    def device_lens(self, slot: Optional[int] = None):
        ln = self.lens if slot is None else self.lens[slot:slot + 1]
        return jnp.asarray(ln.copy())

    # -- accounting ------------------------------------------------------
    @property
    def cache_bytes(self) -> int:
        """Total bytes of the allocated device pools (constant)."""
        return kv_cache.cache_bytes(self.pools)

    @property
    def page_bytes(self) -> int:
        """Bytes of one page across all layers."""
        return self.cache_bytes // self.num_pages

    @property
    def used_bytes(self) -> int:
        """Bytes of pages currently bound to live sequences."""
        return self.used_pages * self.page_bytes

    @property
    def peak_used_bytes(self) -> int:
        return self.peak_used_pages * self.page_bytes
