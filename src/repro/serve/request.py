"""Request/response API of the serving engine.

A request moves QUEUED -> PREFILL -> DECODE -> DONE, possibly bouncing
through PREEMPTED (back to the scheduler's resume queue) any number of
times when the paged KV pool runs dry. ``Engine.cancel`` can end a
request from any non-terminal stage (client disconnect): it lands in
CANCELLED with ``finish_reason="cancelled"`` and whatever tokens it had
already produced. Tokens stream to the caller
through ``on_token`` as they are produced; ``on_done`` fires once with
the finished request. Stopping: per-request ``max_new_tokens``, optional
``eos_id`` and optional ``stop`` token sequences — all applied
host-side, so jitted step shapes stay static.

Preemption bookkeeping lives here so it survives the request leaving its
slot: ``preempt_mode`` ("recompute" dropped the pages and re-prefills
:attr:`prefill_tokens` from scratch; "offload" parked ``cached_tokens``
worth of pages in the host pool), ``resume_to`` remembers whether the
request was mid-prefill or decoding. Every emitted token is timestamped
(:attr:`token_times`) so TTFT and inter-token latency can be reported
separately — a resumed request's stall shows up as one long inter-token
gap, not a corrupted TTFT.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.sampling import SamplingParams, normalize_stops, stop_hit

__all__ = ["Request", "RequestState"]


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    PREEMPTED = "preempted"
    DONE = "done"
    CANCELLED = "cancelled"            # terminal: client went away


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                     # [L] int32 token ids
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    stop: Sequence[Sequence[int]] = ()     # token-id stop sequences
    sampling: SamplingParams = SamplingParams()
    priority: int = 0                      # higher = preempted later
    on_token: Optional[Callable[[int, "Request"], None]] = None
    on_done: Optional[Callable[["Request"], None]] = None
    arrival_s: float = 0.0                 # submit timestamp (perf_counter)

    # -- runtime state (owned by the scheduler/engine) -------------------
    state: RequestState = RequestState.QUEUED
    slot: int = -1                         # continuous-batch slot index
    prefill_pos: int = 0                   # source tokens already cached
    output: List[int] = dataclasses.field(default_factory=list)
    finish_reason: str = ""    # "eos" | "length" | "stop" | "cancelled"
    first_token_s: float = 0.0
    finish_s: float = 0.0
    token_times: List[float] = dataclasses.field(default_factory=list)

    # -- preemption state ------------------------------------------------
    preempt_mode: str = ""                 # "recompute" | "offload" | ""
    resume_to: str = ""                    # "prefill" | "decode"
    cached_tokens: int = 0                 # KV tokens parked in host pool
    preempt_count: int = 0
    # DP-sharded KV placement: assigned once at first admission
    # (least-loaded shard) and sticky for the request's lifetime —
    # resumes (recompute AND offload) land back on the same shard, so a
    # request's pages never migrate and per-shard accounting stays
    # consistent across preemption round-trips. -1 = not yet placed.
    kv_shard: int = -1
    # telemetry: a DECODE B-span is open on the request's trace track
    # (repro.obs) — the closer (preempt or retire) must balance it
    decode_span_open: bool = False

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        self.stop = normalize_stops(self.stop)
        assert self.prompt.size > 0, "empty prompt"
        assert self.max_new_tokens >= 1, "max_new_tokens must be >= 1"

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)

    @property
    def total_budget(self) -> int:
        """KV positions this request may ever occupy."""
        return self.prompt_len + self.max_new_tokens

    # -- prefill source --------------------------------------------------
    # After a recompute preemption mid-decode, "prefill" replays the
    # prompt plus every generated token except the last (the last one is
    # the pending decode input — its KV is written by the decode step that
    # consumes it, exactly as in the never-preempted run).
    @property
    def prefill_len(self) -> int:
        return self.prompt_len + max(0, len(self.output) - 1)

    @property
    def prefill_tokens(self) -> np.ndarray:
        if not self.output:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.output[:-1], np.int32)])

    @property
    def remaining_prefill(self) -> int:
        return self.prefill_len - self.prefill_pos

    def emit(self, token: int, now: float) -> bool:
        """Record one generated token; returns True when the request is
        finished (EOS, stop sequence, or length)."""
        token = int(token)
        if not self.output:
            self.first_token_s = now
        self.output.append(token)
        self.token_times.append(now)
        if self.on_token is not None:
            self.on_token(token, self)
        if self.eos_id is not None and token == self.eos_id:
            self.finish_reason = "eos"
        elif self.stop and stop_hit(self.output, self.stop) is not None:
            self.finish_reason = "stop"
        elif len(self.output) >= self.max_new_tokens:
            self.finish_reason = "length"
        else:
            return False
        self.state = RequestState.DONE
        self.finish_s = now
        if self.on_done is not None:
            self.on_done(self)
        return True

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def ttft_s(self) -> float:
        """Time to first token."""
        return self.first_token_s - self.arrival_s

    @property
    def itl_s(self) -> List[float]:
        """Inter-token latencies (gaps between consecutive emits). A
        preemption stall appears here as one long gap — never folded into
        :attr:`ttft_s`."""
        t = self.token_times
        return [b - a for a, b in zip(t, t[1:])]
