"""Request/response API of the serving engine.

A request moves QUEUED -> PREFILL -> DECODE -> DONE. Tokens stream to the
caller through ``on_token`` as they are produced; ``on_done`` fires once
with the finished request. Stopping: per-request ``max_new_tokens`` and an
optional ``eos_id`` early exit — both applied host-side, so jitted step
shapes stay static.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, List, Optional

import numpy as np


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                     # [L] int32 token ids
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    on_token: Optional[Callable[[int, "Request"], None]] = None
    on_done: Optional[Callable[["Request"], None]] = None
    arrival_s: float = 0.0                 # submit timestamp (perf_counter)

    # -- runtime state (owned by the scheduler/engine) -------------------
    state: RequestState = RequestState.QUEUED
    slot: int = -1                         # continuous-batch slot index
    prefill_pos: int = 0                   # prompt tokens already cached
    output: List[int] = dataclasses.field(default_factory=list)
    finish_reason: str = ""                # "eos" | "length"
    first_token_s: float = 0.0
    finish_s: float = 0.0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        assert self.prompt.size > 0, "empty prompt"
        assert self.max_new_tokens >= 1, "max_new_tokens must be >= 1"

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)

    @property
    def total_budget(self) -> int:
        """KV positions this request may ever occupy (admission budget)."""
        return self.prompt_len + self.max_new_tokens

    @property
    def remaining_prefill(self) -> int:
        return self.prompt_len - self.prefill_pos

    def emit(self, token: int, now: float) -> bool:
        """Record one generated token; returns True when the request is
        finished (EOS or length)."""
        token = int(token)
        if not self.output:
            self.first_token_s = now
        self.output.append(token)
        if self.on_token is not None:
            self.on_token(token, self)
        if self.eos_id is not None and token == self.eos_id:
            self.finish_reason = "eos"
        elif len(self.output) >= self.max_new_tokens:
            self.finish_reason = "length"
        else:
            return False
        self.state = RequestState.DONE
        self.finish_s = now
        if self.on_done is not None:
            self.on_done(self)
        return True

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def ttft_s(self) -> float:
        """Time to first token."""
        return self.first_token_s - self.arrival_s
