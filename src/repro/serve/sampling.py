"""Sampling for the serving engine: temperature / top-k / top-p + stops.

The decode and prefill step bodies call :func:`sample_tokens` *inside*
jit with per-slot parameter arrays (temperature, top-k, top-p, seed,
position), so the one-compile invariant holds: changing a request's
sampling settings changes traced array *values*, never shapes, and the
whole continuous batch — greedy and sampled slots mixed — runs through
one program. ``temperature <= 0`` means greedy (exact ``argmax``, the
golden-test reference path).

Determinism: each sampled token's PRNG key is
``fold_in(PRNGKey(seed), position)`` where ``position`` is the index of
the token being generated — a pure function of the request, independent
of batch composition, slot assignment or preemption history. The same
request with the same seed emits the same tokens whether it runs alone,
continuously batched with others, or preempted and resumed mid-stream.

Stop sequences are matched host-side against the output suffix
(:func:`stop_hit`), like the ``eos_id`` / ``max_new_tokens`` stops, so
jitted step shapes stay static.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30

__all__ = ["SamplingParams", "normalize_stops", "sample_tokens",
           "stop_hit"]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs.

    ``temperature <= 0`` selects greedy decoding (the default); ``top_k
    <= 0`` and ``top_p`` outside (0, 1) disable the respective filter.
    ``seed`` names the request's private PRNG stream.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


def _sample_row(logits, temperature, top_k, top_p, seed, position):
    """One slot: masked top-k/top-p categorical sample (or argmax)."""
    v = logits.shape[-1]
    lg = logits.astype(jnp.float32)
    greedy = temperature <= 0.0
    scaled = lg / jnp.where(greedy, 1.0, temperature)
    # rank in the descending sort, ties broken by vocab index (argsort
    # is stable): the keep set is decided by rank, never by comparing
    # against a threshold *value* — a value cut keeps every entry tied
    # at the k-th logit, so top_k=1 over equal logits was not argmax
    sort_idx = jnp.argsort(-scaled)
    rank = jnp.zeros((v,), jnp.int32).at[sort_idx].set(
        jnp.arange(v, dtype=jnp.int32))
    order = scaled[sort_idx]                             # descending
    # top-k width (0 => keep all)
    k_eff = jnp.clip(jnp.where(top_k <= 0, v, top_k), 1, v)
    # top-p (nucleus) width: smallest prefix with mass >= top_p
    p_eff = jnp.where((top_p <= 0.0) | (top_p >= 1.0), 1.0, top_p)
    probs = jax.nn.softmax(order)
    below = jnp.cumsum(probs) - probs                    # mass before each
    n_keep = jnp.maximum(jnp.sum(below < p_eff), 1)
    masked = jnp.where(rank < jnp.minimum(k_eff, n_keep), scaled, NEG_INF)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), position)
    sampled = jax.random.categorical(key, masked)
    return jnp.where(greedy, jnp.argmax(lg), sampled).astype(jnp.int32)


def sample_tokens(logits, temperature, top_k, top_p, seed, position):
    """Sample one token per slot, jit-stable.

    logits ``[S, V]``; all other args ``[S]`` (float32 temperature/top_p,
    int32 top_k/seed/position). Rows are independent: a slot's token
    depends only on its own logits and sampling state, so batch
    composition cannot perturb it. Returns int32 ``[S]``.
    """
    return jax.vmap(_sample_row)(
        logits, temperature.astype(jnp.float32),
        top_k.astype(jnp.int32), top_p.astype(jnp.float32),
        seed.astype(jnp.int32), position.astype(jnp.int32))


def normalize_stops(stop) -> Tuple[Tuple[int, ...], ...]:
    """Canonicalize stop sequences: tuple of non-empty int tuples."""
    if not stop:
        return ()
    out = []
    for s in stop:
        s = (int(s),) if isinstance(s, int) else tuple(int(t) for t in s)
        if s:
            out.append(s)
    return tuple(out)


def stop_hit(output: Sequence[int],
             stop: Sequence[Sequence[int]]) -> Optional[Tuple[int, ...]]:
    """The stop sequence the output now ends with, or None."""
    for s in stop:
        n = len(s)
        if n and len(output) >= n and tuple(output[-n:]) == tuple(s):
            return tuple(s)
    return None
