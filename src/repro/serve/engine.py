"""Continuous-batching serving engine with preemptive scheduling.

One :class:`Engine` owns: the model params, a ``StateCache`` (the
per-request device-state cache behind the protocol in
``repro.serve.state_cache`` — paged KV pools for attention, slot-indexed
O(1) state for recurrent mixers, or a composite of both for mixed
models like jamba; the kind is decided by ``models/api.serving_support``
and everything below talks only to the protocol surface), a
:class:`Scheduler` (admission + prefill/decode interleave + preemption
bookkeeping) and a :class:`PrefillBucketAdaptive` (per-bucket MPipeMoE
(n, strategy) resolution). Each ``step()`` runs one jitted program —
either a chunked-prefill step for the head-of-line prefilling request or
one decode step over the whole slot batch — so batch composition can
change every step while compiled programs are reused from two small
caches:

* decode: compiled **once** (slot count is static; finished / mid-prefill
  slots are masked, their KV writes going to the reserved sink page);
* prefill: one compiled step per (bucket, n, strategy) in an LRU,
  mirroring the train-side AdaptiveController cache.

Overload behaviour (``EngineOptions.preempt``): with the default
``"auto"`` policy, admission reserves only the first prefill chunk and
slots grow page-by-page on demand; when the pool runs dry the engine
preempts the lowest-priority (then youngest) victim, choosing per victim
between *recompute* (drop pages, re-prefill at resume) and *offload*
(round-trip pages over the host link) via
:class:`repro.core.memory_model.PreemptionCost` — the serving analogue
of the paper's strategy selector, gated by
``core.strategies.host_offload_supported``. ``"never"`` restores the
conservative full-budget admission-blocking baseline.

Sampling: temperature / top-k / top-p with per-request seeds and stop
sequences (``repro.serve.sampling``), executed inside the jitted steps
with per-slot parameter arrays so the one-compile invariant holds;
``temperature <= 0`` (default) is exact greedy argmax.

Bucket (n, strategy) resolution can measure candidates by wall clock
(``EngineOptions.measure``): compiled prefill candidates are timed
against the live pools (writes masked into the sink page) through the
same LRU the serving steps use — the winner's program is already warm.

Mesh-sharded serving (``EngineOptions.devices > 1``): the engine builds
a ``(data=dp, model=ep)`` mesh through
``distributed.context.make_serving_context`` (all mesh calls via the
``repro.compat`` shims), shards the expert weights over the EP axis and
replicates everything else, and threads the resulting ``DistContext``
into both jitted step bodies. Chunked prefill then runs
``pipelined_moe``'s **sharded** layout (tokens split over EP, real
dispatch/combine All-to-Alls — which the wall-clock measure therefore
times too) while decode runs the **replicated** psum-combine layout.

The cache pools have two mesh layouts
(``EngineOptions.kv_sharding``, see ``repro.serve.state_cache``):
``"replicated"`` keeps one logical pool with a replica on every device
(the PR 4 baseline — devices add compute but zero KV capacity), while
``"dp"`` shards the pools' page axis, the page table, the lens and the
decode batch over the mesh ``data`` axis — each dp group owns
``num_pages / dp`` pages with its own host-side free list, requests are
placed on a shard at admission (least-loaded, sticky for life), decode
runs data-parallel over the shards, and pool-dry preemption fires (and
picks its victim) per shard. Per-device resident KV drops ``dp``×, so
the same per-device page budget admits ``~dp``× the concurrent
requests before the first preemption. Everything else host-side —
scheduler queues, offload round-trips — is unchanged: one logical
engine, N devices under it. See ``docs/distributed.md``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import set_mesh
from repro.configs.base import ArchConfig
from repro.core.memory_model import PreemptionCost
from repro.core.strategies import host_offload_supported
from repro.core.types import TPU_V5E, HardwareSpec, Strategy
from repro.distributed.context import make_serving_context
from repro.models.api import get_model, serving_support
from repro.obs import PID_ENGINE, PID_REQUESTS, Recorder, quantile
from repro.serve.adaptive import PrefillBucketAdaptive, force_adaptive
from repro.serve.state_cache import KV_SHARDINGS, make_state_cache
from repro.serve.request import Request, RequestState
from repro.serve.sampling import SamplingParams, sample_tokens
from repro.serve.scheduler import Scheduler

log = logging.getLogger("repro.serve")

__all__ = ["Engine", "EngineOptions"]

PREEMPT_POLICIES = ("auto", "recompute", "offload", "never")
ATTN_KERNELS = ("auto", "pallas", "gather")
PREFIX_CACHE_MODES = ("on", "off")


@dataclasses.dataclass
class EngineOptions:
    page_size: int = 16
    max_slots: int = 8                 # continuous-batch width (static)
    max_seq_len: int = 512             # per-request prompt + gen budget
    num_pages: int = 0                 # 0 = auto (worst case + sink page)
    chunk: int = 64                    # prefill chunk (tokens per step)
    min_bucket: int = 8
    hw: HardwareSpec = TPU_V5E
    devices: int = 0                   # 0/1 = single device; N>1 = build
                                       # a dp x ep mesh over N devices
    kv_sharding: str = "replicated"    # "replicated" | "dp": KV pool
                                       # layout over the mesh data axis
    ep_size: int = 1                   # resolver hints; overridden by the
    dp: int = 1                        # mesh when devices > 1
    dtype: Optional[str] = None        # None = cfg.compute_dtype
    cache_size: int = 16               # LRU bound on compiled prefill steps
    adaptive: bool = True              # resolve (n, strategy) per bucket
    measure: str = "auto"              # auto | wallclock | simulate
    measure_steps: int = 2             # wallclock reps per candidate
    measure_fn: Optional[Callable] = None
    preempt: str = "auto"              # auto | recompute | offload | never
    attn_kernel: str = "auto"          # decode attention over the paged
                                       # pools: "pallas" = fused page-walk
                                       # kernel (repro.kernels.
                                       # paged_attention), "gather" =
                                       # gather_pages baseline, "auto" =
                                       # pallas on TPU / gather elsewhere
                                       # (CPU runs the kernel in interpret
                                       # mode — exact but slow). Both
                                       # paths are bit-identical.
    prefix_cache: str = "off"          # "on": cross-request prefix reuse
                                       # over the paged pools (per-shard
                                       # trie of full-page token keys +
                                       # refcounted pages + copy-on-write
                                       # — see serve/paged_kv.py). Warm
                                       # prompts skip prefill for their
                                       # cached prefix; "off" is
                                       # bit-identical to the pre-prefix
                                       # allocator. Caches without
                                       # shareable page state (constant /
                                       # composite) degrade to "off".
    allow_offload: Optional[bool] = None   # None = host_offload_supported
    preempt_mfu: float = 0.5           # assumed MFU of re-prefill (cost)
    storm_every: int = 0               # N>0: force-preempt a victim every
                                       # N steps (preemption-storm tests —
                                       # constant-state caches never run
                                       # dry on their own)
    obs: Optional[Recorder] = None     # telemetry: None = metrics-only
                                       # registry + no-op tracer (the
                                       # zero-cost disabled path); pass
                                       # Recorder(tracer=Tracer()) to
                                       # record Perfetto spans

    @property
    def max_pages_per_seq(self) -> int:
        return -(-self.max_seq_len // self.page_size)


class Engine:
    def __init__(self, cfg: ArchConfig, params=None, *,
                 options: Optional[EngineOptions] = None, key=None):
        kind, why = serving_support(cfg)
        if kind is None:
            raise NotImplementedError(f"{cfg.name}: {why}")
        self.cache_kind = kind
        self.opts = opts = options or EngineOptions()
        # the registry is always real (stats() reads it; /metrics and
        # stats() agree by construction); only the tracer is optional
        self.obs = opts.obs if opts.obs is not None else Recorder()
        assert opts.preempt in PREEMPT_POLICIES, opts.preempt
        assert opts.kv_sharding in KV_SHARDINGS, opts.kv_sharding
        assert opts.attn_kernel in ATTN_KERNELS, opts.attn_kernel
        assert opts.prefix_cache in PREFIX_CACHE_MODES, opts.prefix_cache
        self._attn_kernel = opts.attn_kernel
        if self._attn_kernel == "auto":
            self._attn_kernel = ("pallas"
                                 if jax.default_backend() == "tpu"
                                 else "gather")
        if opts.adaptive:
            cfg = force_adaptive(cfg)
        self.cfg = cfg
        self.model = get_model(cfg)
        # device mesh (devices > 1): expert weights sharded over EP;
        # the KV pool layout follows opts.kv_sharding, the rest
        # replicates
        self.dist = make_serving_context(
            opts.devices,
            num_experts=cfg.moe.num_experts if cfg.moe is not None else 0)
        if opts.kv_sharding == "dp" and self.dist is None:
            raise ValueError(
                "kv_sharding='dp' shards the KV pools over the mesh "
                "data axis — a single-device engine has no mesh to "
                "shard over (set devices > 1, or use 'replicated')")
        self._replicated = None
        if self.dist is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            self._replicated = NamedSharding(self.dist.mesh,
                                             PartitionSpec())
        ep_size = self.dist.ep_size if self.dist else opts.ep_size
        dp = self.dist.dp_size if self.dist else opts.dp
        if params is None:
            params = self.model.init(cfg, key or jax.random.PRNGKey(0))
        self.params = self._place_params(params)

        dtype = jnp.dtype(opts.dtype or cfg.compute_dtype)
        # the cache kind (paged / constant / composite) was decided by
        # serving_support above; num_pages=0 = auto (the paged cache
        # sizes the worst case itself — it owns the shard rounding +
        # per-shard sink rules)
        self.kv = make_state_cache(
            cfg, kind, num_pages=opts.num_pages,
            page_size=opts.page_size, max_slots=opts.max_slots,
            max_pages_per_seq=opts.max_pages_per_seq,
            max_seq_len=opts.max_seq_len, dtype=dtype, dist=self.dist,
            kv_sharding=opts.kv_sharding,
            prefix_cache=(opts.prefix_cache == "on"))
        if opts.prefix_cache == "on" and not self.kv.prefix_enabled:
            log.warning(
                "prefix_cache='on' but the %s cache has no shareable "
                "page-boundary state (recurrent rows are position-"
                "dependent) — prefix reuse is disabled; serving is "
                "otherwise unaffected", kind)
        if opts.kv_sharding == "dp" and self.kv.n_shards == 1:
            log.warning(
                "kv_sharding='dp' but the mesh's data axis has extent 1 "
                "(ep_split used every device for experts): the pools "
                "degenerate to the replicated layout — none of the "
                "dp-fold KV capacity/residency wins apply")
        self.scheduler = Scheduler(self.kv, chunk=opts.chunk,
                                   full_reserve=(opts.preempt == "never"),
                                   obs=self.obs)
        measure_fn = opts.measure_fn
        mode = opts.measure
        if mode == "auto":
            mode = ("wallclock" if jax.default_backend() != "cpu"
                    else "simulate")
        if measure_fn is None and mode == "wallclock":
            measure_fn = self._wallclock_measure
        self.adaptive = PrefillBucketAdaptive(
            cfg, hw=opts.hw, ep_size=ep_size, dp=dp,
            min_bucket=min(opts.min_bucket, opts.chunk),
            max_bucket=opts.chunk, measure_fn=measure_fn,
            shards=ep_size, obs=self.obs)
        # forward FLOPs/token of the active parameter set, for the
        # offload-vs-recompute preemption cost model
        self._flops_per_token = 2.0 * self.model.count_params(
            cfg, active_only=True)

        self._decode_fn = jax.jit(self._decode_step)
        self._prefill_fns: Dict[Tuple, Callable] = {}
        # per-slot sink page ids: constant for the engine's lifetime, so
        # one committed device copy serves every decode step
        self._decode_sinks = self.kv.device_sinks()
        self._next_rid = 0
        self.step_count = 0
        self._storm_tick = 0
        self.prefill_rejits = 0
        # actual trace counts of the jitted step bodies (a retrace means
        # the jit cache churned — e.g. an input arrived with a different
        # committed sharding); pinned by the compile-count regression
        # test in tests/test_serving_conformance.py
        self.decode_traces = 0
        self.prefill_traces = 0
        self.preempts: Dict[str, int] = {"recompute": 0, "offload": 0}
        # high-water mark of concurrently running requests while the
        # engine had not yet preempted anyone — the "admitted before
        # first preemption" capacity the DP-sharded benchmark reports
        self.peak_running_preempt_free = 0
        self.done: List[Request] = []
        # cancelled requests are kept apart from ``done``: they carry a
        # truncated output and (often) no tokens at all, so folding them
        # into the latency/TTFT percentiles would corrupt the SLO story
        self.cancelled: List[Request] = []
        self.metrics: Dict[str, Any] = {}
        self._init_metrics()

    # -- telemetry -------------------------------------------------------
    def _init_metrics(self) -> None:
        """Register this engine's metric families (idempotent — a shared
        registry across engines merges families)."""
        reg = self.obs.registry
        self._m_steps = reg.counter(
            "repro_engine_steps_total", "engine host steps", ["kind"])
        self._m_done = reg.counter(
            "repro_requests_done_total", "requests retired")
        self._m_tokens = reg.counter(
            "repro_tokens_generated_total", "tokens emitted to requests")
        self._m_prefill_tokens = reg.counter(
            "repro_prefill_tokens_total", "prompt tokens prefilled")
        self._m_preempts = reg.counter(
            "repro_preempts_total", "preemptions by mode", ["mode"])
        self._m_cancels = reg.counter(
            "repro_cancels_total",
            "cancelled requests by lifecycle stage", ["stage"])
        self._m_jit = reg.counter(
            "repro_jit_traces_total",
            "XLA traces of the jitted step bodies", ["body"])
        self._m_compiles = reg.counter(
            "repro_prefill_compiles_total", "compiled prefill programs")
        self._m_step_s = reg.histogram(
            "repro_step_seconds", "host wall time per engine step",
            ["kind"])
        self._m_lat = reg.histogram(
            "repro_latency_seconds", "request latency (submit to done)")
        self._m_ttft = reg.histogram(
            "repro_ttft_seconds", "time to first token")
        self._m_itl = reg.histogram(
            "repro_itl_seconds", "inter-token latency")
        # point-in-time gauges, filled by _refresh_gauges on demand
        reg.gauge("repro_waiting_requests", "admission queue depth")
        reg.gauge("repro_resuming_requests",
                  "preempted requests awaiting resume")
        reg.gauge("repro_running_slots", "occupied decode slots")
        self.obs.tracer.thread_name(PID_ENGINE, 1, "steps")
        self.kv.record_metrics(reg)

    def _refresh_gauges(self) -> None:
        """Pull point-in-time gauges into the registry: called by
        ``stats()`` and by the /metrics exporter's refresh hook, never
        per step — the disabled path pays nothing for them."""
        reg = self.obs.registry
        reg.gauge("repro_waiting_requests").set(
            len(self.scheduler.waiting))
        reg.gauge("repro_resuming_requests").set(
            len(self.scheduler.resuming))
        reg.gauge("repro_running_slots").set(
            len(self.scheduler.running))
        self.kv.record_metrics(reg)

    # -- mesh plumbing ---------------------------------------------------
    def _place_params(self, params):
        """Place the parameter tree on the mesh: expert weights sharded
        over the EP axis (matching ``moe.layer``'s shard_map in_specs, so
        no resharding on entry), everything else replicated. Leaves keep
        their single-device placement when there is no mesh."""
        if self.dist is None:
            return params
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh, ep = self.dist.mesh, self.dist.ep_size
        repl = self._replicated

        def place(path, leaf):
            under_experts = any(
                getattr(k, "key", None) == "experts" for k in path)
            # stacked expert leaves: [num_periods, num_experts, ...]
            if (ep > 1 and under_experts and leaf.ndim >= 2
                    and leaf.shape[1] % ep == 0):
                return jax.device_put(
                    leaf, NamedSharding(mesh, P(None, "model")))
            return jax.device_put(leaf, repl)

        return jax.tree_util.tree_map_with_path(place, params)

    def _put(self, x):
        """Host value -> device array (replicated under a mesh), so every
        step input carries one consistent committed sharding. Delegates
        to the KV cache's placement policy — the single source of truth
        for where step state lives."""
        return self.kv.to_device(x)

    def _put_slots(self, x):
        """Host ``[max_slots, ...]`` decode-batch array -> device, sharded
        over the slot axis when the KV pools are DP-sharded (each dp
        group computes only its own slots), replicated otherwise."""
        return self.kv.to_device_slots(x)

    def _mesh_scope(self):
        """Context activating the mesh around traces/executions (the
        jax-0.4.x resource env that bare-PartitionSpec constraints in
        ``DistContext.constrain`` need)."""
        if self.dist is None:
            return contextlib.nullcontext()
        return set_mesh(self.dist.mesh)

    def _pin_pools(self, pools):
        """Keep step outputs on the committed pool layout (replicated,
        or page-sharded over "data" under ``kv_sharding="dp"``) — without
        the constraint GSPMD may scatter the updated pools over whatever
        layout the (EP-sharded) chunk activations suggest, and the next
        step would recompile against it. Under the DP layout this is
        also the prefill→decode handoff: the chunk's KV writes land
        pinned on the owning shard's pages, so decode reads them with no
        re-placement. Delegates to the cache, which owns the layout."""
        return self.kv.pin_pools(pools)

    # -- jitted step bodies ---------------------------------------------
    def _decode_step(self, params, pools, page_table, lens, tokens, active,
                     sinks, temp, top_k, top_p, seed, pos):
        self.decode_traces += 1        # body runs only while tracing
        self._m_jit.labels(body="decode").inc()
        self.obs.tracer.instant("jit.trace", args={"body": "decode"})
        logits, new_pools = self.model.decode_step_paged(
            params, pools, page_table, lens, tokens, self.cfg,
            active=active, dist=self.dist, write_sink=sinks,
            attn_kernel=self._attn_kernel,
            kv_sharded=(self.opts.kv_sharding == "dp"
                        and self.kv.n_shards > 1))
        return sample_tokens(logits, temp, top_k, top_p, seed, pos), \
            self._pin_pools(new_pools)

    def _prefill_fn(self, bucket: int, rcfg: ArchConfig) -> Callable:
        m = rcfg.moe
        key = (bucket, (m.num_partitions, m.memory_reuse_strategy)
               if m is not None else (1, "none"))
        fn = self._prefill_fns.pop(key, None)          # LRU: re-insert
        if fn is None:
            def body(params, pools, pt_row, pos0, toks, valid_len, slot,
                     sink, temp, top_k, top_p, seed, pos, _cfg=rcfg):
                self.prefill_traces += 1
                self._m_jit.labels(body="prefill").inc()
                self.obs.tracer.instant("jit.trace",
                                        args={"body": "prefill"})
                logits, new_pools = self.model.prefill_chunk_paged(
                    params, pools, pt_row, pos0, toks, valid_len, _cfg,
                    dist=self.dist, write_sink=sink, slot=slot)
                return sample_tokens(logits, temp, top_k, top_p, seed,
                                     pos), self._pin_pools(new_pools)
            fn = jax.jit(body)
            self.prefill_rejits += 1
            self._m_compiles.inc()
        self._prefill_fns[key] = fn
        while len(self._prefill_fns) > max(1, self.opts.cache_size):
            self._prefill_fns.pop(next(iter(self._prefill_fns)))
        return fn

    # -- sampling parameter arrays ---------------------------------------
    def _sample_args(self, reqs: Sequence[Optional[Request]], *,
                     slots: bool = False):
        """Per-slot sampling arrays for ``sample_tokens`` (None slots are
        masked-off: greedy with dummy state, output discarded).
        ``slots=True`` marks a decode batch (one entry per slot), which
        shards over the slot axis with the DP-KV layout; prefill's
        single-row arrays stay replicated."""
        n = len(reqs)
        put = self._put_slots if slots else self._put
        temp = np.zeros((n,), np.float32)
        top_k = np.zeros((n,), np.int32)
        top_p = np.ones((n,), np.float32)
        seed = np.zeros((n,), np.int32)
        pos = np.zeros((n,), np.int32)
        for i, r in enumerate(reqs):
            if r is None:
                continue
            sp = r.sampling
            temp[i], top_k[i], top_p[i], seed[i] = (
                sp.temperature, sp.top_k, sp.top_p, sp.seed)
            pos[i] = len(r.output)
        return tuple(put(a) for a in (temp, top_k, top_p, seed, pos))

    # -- serve-side wall-clock measurement -------------------------------
    def _wallclock_measure(self, b: int, n: int,
                           strategy: Strategy) -> float:
        """Algorithm 1's measure function for prefill buckets: time the
        compiled candidate (n, strategy) chunk step against the live
        pools. All writes go through a zeroed page-table row, i.e. into
        the reserved sink page, and the output pools are discarded — the
        probe cannot perturb serving state. Candidates land in the same
        prefill LRU the engine serves from, so the winner is pre-warmed.
        """
        rcfg = dataclasses.replace(
            self.cfg, moe=dataclasses.replace(
                self.cfg.moe, num_partitions=n,
                memory_reuse_strategy=strategy.value))
        fn = self._prefill_fn(b, rcfg)
        kv = self.kv
        args = (self.params, kv.pools,
                self._put(np.zeros((1, kv.page_table_width), np.int32)),
                self._put(np.zeros((1,), np.int32)),
                self._put(np.zeros((1, b), np.int32)),
                self._put(np.asarray(b, np.int32)),
                self._put(np.zeros((1,), np.int32)),     # slot 0 (probe)
                self._put(np.zeros((1,), np.int32)),     # sink: page 0
                *self._sample_args([None]))
        with self._mesh_scope():
            out = fn(*args)
            jax.block_until_ready(out[0])        # compile + warm up
            reps = max(1, self.opts.measure_steps)
            t0 = time.perf_counter()
            for _ in range(reps):
                out = fn(*args)
            jax.block_until_ready(out[0])
        return (time.perf_counter() - t0) / reps

    # -- request API -----------------------------------------------------
    def submit(self, prompt, *, max_new_tokens: int = 32,
               eos_id: Optional[int] = None, stop=(),
               sampling: Optional[SamplingParams] = None,
               priority: int = 0, on_token=None, on_done=None,
               arrival_s: Optional[float] = None) -> Request:
        req = Request(rid=self._next_rid, prompt=np.asarray(prompt),
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      stop=stop, sampling=sampling or SamplingParams(),
                      priority=priority, on_token=on_token,
                      on_done=on_done,
                      arrival_s=(time.perf_counter() if arrival_s is None
                                 else arrival_s))
        self._next_rid += 1
        if not self.kv.admissible(req.total_budget):
            raise ValueError(
                f"request {req.rid}: budget {req.total_budget} tokens "
                f"exceeds engine capacity "
                f"({self.kv.max_slot_tokens} tokens per slot)")
        self.scheduler.submit(req)
        return req

    def cancel(self, req: Request) -> bool:
        """Cancel ``req`` from whatever lifecycle stage it is in,
        releasing its slot, pages and/or host-offload snapshot. Returns
        True if the request was live (now CANCELLED), False if it had
        already finished — a race every disconnect path hits, so it is
        not an error. NOT thread-safe: call between steps on the thread
        driving the engine (the ingress tier routes client disconnects
        through its engine-thread command queue for exactly this
        reason)."""
        if req.state in (RequestState.DONE, RequestState.CANCELLED):
            return False
        stage = self.scheduler.cancel(req)
        req.state = RequestState.CANCELLED
        req.finish_reason = "cancelled"
        req.finish_s = time.perf_counter()
        tracer = self.obs.tracer
        if req.decode_span_open:
            tracer.end("DECODE", pid=PID_REQUESTS, tid=req.rid)
            req.decode_span_open = False
        tracer.instant("CANCEL", pid=PID_REQUESTS, tid=req.rid,
                       args={"stage": stage,
                             "tokens": len(req.output)})
        self._m_cancels.labels(stage=stage).inc()
        self.cancelled.append(req)
        if req.on_done is not None:
            req.on_done(req)
        return True

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    def warmup(self) -> int:
        """Compile the decode program and every reachable prefill bucket
        up front, so serving latency (and benchmark numbers) reflect
        steady state instead of first-request XLA compiles. All warmup
        KV writes are masked into the sink page (inactive slots / zeroed
        page-table rows) and the resulting pools are discarded. Returns
        the number of programs compiled."""
        kv = self.kv
        before = self.prefill_rejits
        with self._mesh_scope():
            out = self._decode_fn(
                self.params, kv.pools,
                kv.device_page_table(), kv.device_lens(),
                self._put_slots(np.zeros((kv.max_slots, 1), np.int32)),
                self._put_slots(np.zeros((kv.max_slots,), bool)),
                self._decode_sinks,
                *self._sample_args([None] * kv.max_slots, slots=True))
            jax.block_until_ready(out[0])
        buckets, c = set(), 1
        while c < self.scheduler.chunk:
            buckets.add(self.adaptive.bucket_of(c))
            c *= 2
        buckets.add(self.adaptive.bucket_of(self.scheduler.chunk))
        for b in sorted(buckets):
            fn = self._prefill_fn(b, self.adaptive.cfg_for(b))
            with self._mesh_scope():
                out = fn(self.params, kv.pools, kv.device_page_table(0),
                         kv.device_lens(0),
                         self._put(np.zeros((1, b), np.int32)),
                         self._put(np.asarray(0, np.int32)),
                         self._put(np.zeros((1,), np.int32)),
                         self._put(kv.sink_row(0)),
                         *self._sample_args([None]))
                jax.block_until_ready(out[0])
        return 1 + self.prefill_rejits - before

    # -- preemption ------------------------------------------------------
    def _pick_victim(self, shard: Optional[int] = None
                     ) -> Optional[Request]:
        """Lowest priority, then youngest, among running requests that
        actually hold cache bytes — on ``shard`` when given (pool-dry is
        a per-shard event under the DP-KV layout: only a victim on the
        dry shard frees capacity the grower can use)."""
        on_shard = [r for r in self.scheduler.running.values()
                    if shard is None
                    or self.kv.shard_of_slot(r.slot) == shard]
        cands = [r for r in on_shard if self.kv.held_bytes(r.slot) > 0]
        if not cands:
            # prefix cache: every page on the shard may be shared (zero
            # exclusive bytes per slot), yet preempting still helps —
            # the victim's dropped references turn shared pages into
            # evictable trie-only entries
            cands = on_shard
        if not cands:
            return None
        return min(cands, key=lambda r: (r.priority, -r.rid))

    def _preempt_mode(self, req: Request) -> str:
        """Per-victim offload-vs-recompute choice (PreemptionCost), gated
        by hardware/host capability like the train-side strategy mask."""
        if self.opts.preempt in ("recompute", "offload"):
            return self.opts.preempt
        offload_ok = self.opts.allow_offload
        if offload_ok is None:
            offload_ok = (self.opts.hw.has_host_offload
                          and host_offload_supported())
        if not offload_ok:
            return "recompute"
        hw = self.opts.hw
        cost = PreemptionCost(
            tokens_cached=int(self.kv.lens[req.slot]),
            bytes_held=self.kv.held_bytes(req.slot),
            flops_per_token=self._flops_per_token, flops=hw.flops,
            host_bw=hw.host_bw, mfu=self.opts.preempt_mfu,
            eta=hw.interference.eta_comp,
            link_shards=self.kv.n_shards)
        return cost.choice

    def _do_preempt(self, victim: Request) -> None:
        mode = self.scheduler.preempt(victim, self._preempt_mode(victim))
        self.preempts[mode] += 1
        self._m_preempts.labels(mode=mode).inc()
        log.info("preempt rid=%d mode=%s cached=%d", victim.rid, mode,
                 victim.cached_tokens if mode == "offload" else 0)

    def _ensure(self, slot: int, tokens: int) -> bool:
        """Grow ``slot`` until it can hold ``tokens``, preempting victims
        on the slot's shard while that shard is dry. Returns False if the
        slot's own request was chosen as the victim (it must skip this
        step)."""
        shard = self.kv.shard_of_slot(slot)
        while self.kv.slot_capacity(slot) < tokens:
            if self.kv.grow_slot(slot):
                continue
            victim = self._pick_victim(shard)
            if victim is None:
                raise RuntimeError(
                    f"page pool wedged: KV shard {shard} has no free "
                    f"pages and no victim")
            vslot = victim.slot
            self._do_preempt(victim)
            if vslot == slot:
                return False
        # prefix cache: the positions this step writes may live on pages
        # shared with the trie or other requests — copy-on-write (or
        # steal) them first; a dry shard preempts like growth does.
        # No-op with the prefix cache off.
        while not self.kv.ensure_private(slot, tokens):
            victim = self._pick_victim(shard)
            if victim is None:
                raise RuntimeError(
                    f"page pool wedged: KV shard {shard} cannot supply "
                    f"a copy-on-write page and has no victim")
            vslot = victim.slot
            self._do_preempt(victim)
            if vslot == slot:
                return False
        return True

    # -- engine iteration ------------------------------------------------
    def step(self) -> Dict[str, Any]:
        """Admit, then run one jitted step (prefill chunk or decode)."""
        t0 = time.perf_counter()
        with self.obs.tracer.span("engine.step",
                                  args={"step": self.step_count}) as sp:
            # storm injection (tests/benchmarks): constant-state caches
            # hold O(1) bytes per slot and never run dry, so preemption
            # storms must be forced rather than provoked by a small pool
            if (self.opts.storm_every and self.opts.preempt != "never"
                    and self.scheduler.running):
                self._storm_tick += 1
                if self._storm_tick >= self.opts.storm_every:
                    self._storm_tick = 0
                    victim = self._pick_victim()
                    if victim is not None:
                        self._do_preempt(victim)
            self.scheduler.admit()
            if not (self.preempts["recompute"]
                    or self.preempts["offload"]):
                self.peak_running_preempt_free = max(
                    self.peak_running_preempt_free,
                    len(self.scheduler.running))
            action, req = self.scheduler.next_action()
            sp["kind"] = action
            info: Dict[str, Any] = {"kind": action}
            if action == "prefill":
                info.update(self._run_prefill(req))
            elif action == "decode":
                info.update(self._run_decode())
            elif self.scheduler.waiting or self.scheduler.resuming:
                raise RuntimeError("scheduler idle with waiting "
                                   "requests — admission wedged")
        self.step_count += 1
        self._m_steps.labels(kind=action).inc()
        self._m_step_s.labels(kind=action).observe(
            time.perf_counter() - t0)
        info.update(cache_bytes=self.kv.cache_bytes,
                    kv_used_bytes=self.kv.used_bytes,
                    free_pages=self.kv.free_units,
                    running=len(self.scheduler.running),
                    waiting=len(self.scheduler.waiting),
                    preempted=len(self.scheduler.resuming))
        self.metrics = info
        return info

    def _run_prefill(self, req: Request) -> Dict[str, Any]:
        kv, slot = self.kv, req.slot
        c = min(self.scheduler.chunk, req.remaining_prefill)
        if not self._ensure(slot, int(kv.lens[slot]) + c):
            return {"tokens": 0, "rid": req.rid, "self_preempted": True}
        bucket = self.adaptive.bucket_of(c)
        rcfg = self.adaptive.cfg_for(bucket)
        fn = self._prefill_fn(bucket, rcfg)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :c] = req.prefill_tokens[req.prefill_pos:
                                         req.prefill_pos + c]
        tracer = self.obs.tracer
        with tracer.span("prefill", args={"rid": req.rid}), \
             tracer.span("PREFILL", pid=PID_REQUESTS, tid=req.rid,
                         args={"chunk": c, "bucket": bucket,
                               "pos": req.prefill_pos}), \
             self._mesh_scope():
            tok, kv.pools = fn(self.params, kv.pools,
                               kv.device_page_table(slot),
                               kv.device_lens(slot), self._put(toks),
                               self._put(np.asarray(c, np.int32)),
                               self._put(np.asarray([slot], np.int32)),
                               self._put(kv.sink_row(slot)),
                               *self._sample_args([req]))
        req.prefill_pos += c
        kv.lens[slot] += c
        self._m_prefill_tokens.inc(c)
        self.scheduler.prefill_advanced(req)
        if req.remaining_prefill == 0:
            # publish the finished prompt's full pages for later
            # requests sharing the prefix (no-op with prefix off)
            kv.cache_slot_prefix(slot, req.prefill_tokens)
            req.state = RequestState.DECODE
            tracer.begin("DECODE", pid=PID_REQUESTS, tid=req.rid)
            req.decode_span_open = True
            # a resumed re-prefill (recompute preemption) replays tokens
            # that were already emitted — its final-chunk sample is the
            # pending decode input, not a new token
            if not req.output and req.emit(int(tok[0]),
                                           time.perf_counter()):
                self._retire(req)
        info = {"tokens": c, "bucket": bucket, "rid": req.rid}
        if rcfg.moe is not None:
            info.update(n=rcfg.moe.num_partitions,
                        strategy=rcfg.moe.memory_reuse_strategy)
        return info

    def _run_decode(self) -> Dict[str, Any]:
        kv = self.kv
        # every decoding slot writes one KV position this step — grow
        # on-demand slots first, preempting victims if the pool is dry
        # (a victim may itself be one of the decoding slots)
        for s in list(self.scheduler.decode_slots()):
            req = self.scheduler.running.get(s)
            if req is None or req.state != RequestState.DECODE:
                continue                       # preempted by an earlier
            self._ensure(s, int(kv.lens[s]) + 1)  # slot's growth
        slots = self.scheduler.decode_slots()
        if not slots:
            return {"tokens": 0}
        tokens = np.zeros((kv.max_slots, 1), np.int32)
        active = np.zeros((kv.max_slots,), bool)
        by_slot: List[Optional[Request]] = [None] * kv.max_slots
        for s in slots:
            req = self.scheduler.running[s]
            tokens[s, 0] = req.output[-1]
            active[s] = True
            by_slot[s] = req
        with self.obs.tracer.span("decode",
                                  args={"slots": len(slots)}), \
             self._mesh_scope():
            toks, kv.pools = self._decode_fn(
                self.params, kv.pools, kv.device_page_table(),
                kv.device_lens(), self._put_slots(tokens),
                self._put_slots(active), self._decode_sinks,
                *self._sample_args(by_slot, slots=True))
        toks = np.asarray(toks)
        now = time.perf_counter()
        for s in slots:
            req = self.scheduler.running[s]
            kv.lens[s] += 1                  # the input token's KV slot
            if req.emit(int(toks[s]), now):
                self._retire(req)
        return {"tokens": len(slots)}

    def _retire(self, req: Request) -> None:
        # publish the retiring request's written full pages (prompt plus
        # generated turn) before the slot frees: the trie's reference
        # keeps them alive for the conversation's next turn
        self.kv.cache_slot_prefix(req.slot, req.prefill_tokens)
        tracer = self.obs.tracer
        if req.decode_span_open:
            tracer.end("DECODE", pid=PID_REQUESTS, tid=req.rid)
            req.decode_span_open = False
        tracer.instant("RETIRE", pid=PID_REQUESTS, tid=req.rid,
                       args={"reason": req.finish_reason})
        self.scheduler.finish(req)
        self.done.append(req)
        self._m_done.inc()
        self._m_tokens.inc(len(req.output))
        self._m_lat.observe(req.latency_s)
        self._m_ttft.observe(req.ttft_s)
        for g in req.itl_s:
            self._m_itl.observe(g)

    def run_until_idle(self, max_steps: int = 1_000_000) -> None:
        steps = 0
        while self.has_work:
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"no quiescence in {max_steps} steps")

    # -- reporting -------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        # percentiles via the shared nearest-rank quantile (repro.obs)
        # — the old hand-rolled int(p/100*n) index overshot by a rank
        lat = [r.latency_s for r in self.done]
        ttft = [r.ttft_s for r in self.done]
        itl = [g for r in self.done for g in r.itl_s]
        self._refresh_gauges()
        reg = self.obs.registry
        free_fam = reg.get("repro_kv_free_units")
        return {
            "requests_done": len(self.done),
            "requests_cancelled": len(self.cancelled),
            "cancelled_by_stage": {
                dict(c.labels)["stage"]: int(c.value)
                for c in self._m_cancels.children()},
            "tokens_generated": sum(len(r.output) for r in self.done),
            "devices": 1 if self.dist is None else self.dist.mesh.size,
            "ep_size": 1 if self.dist is None else self.dist.ep_size,
            "dp_size": 1 if self.dist is None else self.dist.dp_size,
            "kv_sharding": self.opts.kv_sharding,
            "kv_shards": self.kv.n_shards,
            "attn_kernel": self._attn_kernel,
            "engine_steps": self.step_count,
            "prefill_compiles": self.prefill_rejits,
            "decode_traces": self.decode_traces,
            "prefill_traces": self.prefill_traces,
            "p50_latency_s": quantile(lat, 50),
            "p99_latency_s": quantile(lat, 99),
            "p50_ttft_s": quantile(ttft, 50),
            "p99_ttft_s": quantile(ttft, 99),
            "p50_itl_s": quantile(itl, 50),
            "p99_itl_s": quantile(itl, 99),
            # live gauges, read back from the registry so /metrics and
            # stats() report the same values by construction
            "queue_waiting": int(
                reg.gauge("repro_waiting_requests").value),
            "queue_resuming": int(
                reg.gauge("repro_resuming_requests").value),
            "running_slots": int(
                reg.gauge("repro_running_slots").value),
            "free_units_by_shard": {
                dict(c.labels)["shard"]: int(c.value)
                for c in (free_fam.children() if free_fam else ())},
            "prefix_cache": self.opts.prefix_cache,
            "prefix_hits": self.kv.prefix_hits,
            "prefix_misses": self.kv.prefix_misses,
            "prefix_hit_tokens": self.kv.prefix_hit_tokens,
            "prefix_hit_rate": (
                self.kv.prefix_hits
                / max(1, self.kv.prefix_hits + self.kv.prefix_misses)),
            "prefix_cow_copies": self.kv.prefix_cow_copies,
            "prefix_cow_bytes": self.kv.prefix_cow_bytes,
            "prefix_evicted_pages": self.kv.prefix_evicted_pages,
            "prefix_cached_pages": sum(
                self.kv.prefix_cached_pages_of(s)
                for s in range(self.kv.n_shards)),
            "prefix_shared_pages": sum(
                self.kv.prefix_shared_pages_of(s)
                for s in range(self.kv.n_shards)),
            "preempt_recompute": self.preempts["recompute"],
            "preempt_offload": self.preempts["offload"],
            "resumes": self.scheduler.resume_count,
            "swap_out_bytes": self.kv.swap_out_bytes,
            "swap_in_bytes": self.kv.swap_in_bytes,
            "cache_bytes": self.kv.cache_bytes,
            "peak_kv_used_bytes": self.kv.peak_used_bytes,
            "per_device_cache_bytes": self.kv.per_device_cache_bytes,
            "per_device_peak_kv_used_bytes":
                self.kv.per_device_peak_used_bytes,
            "peak_running_preempt_free": self.peak_running_preempt_free,
            "resolutions": {str(b): list(r) for b, r in
                            self.adaptive.resolutions.items()},
        }
