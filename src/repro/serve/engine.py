"""Continuous-batching serving engine.

One :class:`Engine` owns: the model params, a :class:`PagedKVCache`
(device page pools + host allocator), a :class:`Scheduler` (admission +
prefill/decode interleave) and a :class:`PrefillBucketAdaptive`
(per-bucket MPipeMoE (n, strategy) resolution). Each ``step()`` runs one
jitted program — either a chunked-prefill step for the head-of-line
prefilling request or one decode step over the whole slot batch — so
batch composition can change every step while compiled programs are
reused from two small caches:

* decode: compiled **once** (slot count is static; finished / mid-prefill
  slots are masked, their KV writes going to the reserved sink page);
* prefill: one compiled step per (bucket, n, strategy) in an LRU,
  mirroring the train-side AdaptiveController cache.

Greedy decoding only (argmax inside the jitted step); sampling is future
work.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.types import TPU_V5E, HardwareSpec
from repro.models.api import get_model, supports_paged
from repro.serve.adaptive import PrefillBucketAdaptive, force_adaptive
from repro.serve.paged_kv import PagedKVCache
from repro.serve.request import Request, RequestState
from repro.serve.scheduler import Scheduler

log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class EngineOptions:
    page_size: int = 16
    max_slots: int = 8                 # continuous-batch width (static)
    max_seq_len: int = 512             # per-request prompt + gen budget
    num_pages: int = 0                 # 0 = auto (worst case + sink page)
    chunk: int = 64                    # prefill chunk (tokens per step)
    min_bucket: int = 8
    hw: HardwareSpec = TPU_V5E
    ep_size: int = 1
    dp: int = 1
    dtype: Optional[str] = None        # None = cfg.compute_dtype
    cache_size: int = 16               # LRU bound on compiled prefill steps
    adaptive: bool = True              # resolve (n, strategy) per bucket
    measure_fn: Optional[Callable] = None

    @property
    def max_pages_per_seq(self) -> int:
        return -(-self.max_seq_len // self.page_size)


class Engine:
    def __init__(self, cfg: ArchConfig, params=None, *,
                 options: Optional[EngineOptions] = None, key=None):
        ok, why = supports_paged(cfg)
        if not ok:
            raise NotImplementedError(f"{cfg.name}: {why}")
        self.opts = opts = options or EngineOptions()
        if opts.adaptive:
            cfg = force_adaptive(cfg)
        self.cfg = cfg
        self.model = get_model(cfg)
        if params is None:
            params = self.model.init(cfg, key or jax.random.PRNGKey(0))
        self.params = params

        num_pages = opts.num_pages or (
            opts.max_slots * opts.max_pages_per_seq + 1)
        dtype = jnp.dtype(opts.dtype or cfg.compute_dtype)
        self.kv = PagedKVCache(cfg, num_pages=num_pages,
                               page_size=opts.page_size,
                               max_slots=opts.max_slots,
                               max_pages_per_seq=opts.max_pages_per_seq,
                               dtype=dtype)
        self.scheduler = Scheduler(self.kv, chunk=opts.chunk)
        self.adaptive = PrefillBucketAdaptive(
            cfg, hw=opts.hw, ep_size=opts.ep_size, dp=opts.dp,
            min_bucket=min(opts.min_bucket, opts.chunk),
            max_bucket=opts.chunk, measure_fn=opts.measure_fn)

        self._decode_fn = jax.jit(self._decode_step)
        self._prefill_fns: Dict[Tuple, Callable] = {}
        self._next_rid = 0
        self.step_count = 0
        self.prefill_rejits = 0
        self.done: List[Request] = []
        self.metrics: Dict[str, Any] = {}

    # -- jitted step bodies ---------------------------------------------
    def _decode_step(self, params, pools, page_table, lens, tokens, active):
        logits, new_pools = self.model.decode_step_paged(
            params, pools, page_table, lens, tokens, self.cfg,
            active=active)
        return jnp.argmax(logits, -1).astype(jnp.int32), new_pools

    def _prefill_fn(self, bucket: int, rcfg: ArchConfig) -> Callable:
        m = rcfg.moe
        key = (bucket, (m.num_partitions, m.memory_reuse_strategy)
               if m is not None else (1, "none"))
        fn = self._prefill_fns.pop(key, None)          # LRU: re-insert
        if fn is None:
            def body(params, pools, pt_row, pos0, toks, valid_len,
                     _cfg=rcfg):
                logits, new_pools = self.model.prefill_chunk_paged(
                    params, pools, pt_row, pos0, toks, valid_len, _cfg)
                return (jnp.argmax(logits, -1).astype(jnp.int32),
                        new_pools)
            fn = jax.jit(body)
            self.prefill_rejits += 1
        self._prefill_fns[key] = fn
        while len(self._prefill_fns) > max(1, self.opts.cache_size):
            self._prefill_fns.pop(next(iter(self._prefill_fns)))
        return fn

    # -- request API -----------------------------------------------------
    def submit(self, prompt, *, max_new_tokens: int = 32,
               eos_id: Optional[int] = None, on_token=None, on_done=None,
               arrival_s: Optional[float] = None) -> Request:
        req = Request(rid=self._next_rid, prompt=np.asarray(prompt),
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      on_token=on_token, on_done=on_done,
                      arrival_s=(time.perf_counter() if arrival_s is None
                                 else arrival_s))
        self._next_rid += 1
        cap = self.kv.max_pages_per_seq * self.kv.page_size
        if req.total_budget > cap or \
                self.kv.pages_for(req.total_budget) > self.kv.num_pages - 1:
            raise ValueError(
                f"request {req.rid}: budget {req.total_budget} tokens "
                f"exceeds engine capacity ({cap} per seq, "
                f"{self.kv.num_pages - 1} pages total)")
        self.scheduler.submit(req)
        return req

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    def warmup(self) -> int:
        """Compile the decode program and every reachable prefill bucket
        up front, so serving latency (and benchmark numbers) reflect
        steady state instead of first-request XLA compiles. All warmup
        KV writes are masked into the sink page (inactive slots / zeroed
        page-table rows) and the resulting pools are discarded. Returns
        the number of programs compiled."""
        kv = self.kv
        before = self.prefill_rejits
        out = self._decode_fn(self.params, kv.pools,
                              kv.device_page_table(), kv.device_lens(),
                              jnp.zeros((kv.max_slots, 1), jnp.int32),
                              jnp.zeros((kv.max_slots,), bool))
        jax.block_until_ready(out[0])
        buckets, c = set(), 1
        while c < self.scheduler.chunk:
            buckets.add(self.adaptive.bucket_of(c))
            c *= 2
        buckets.add(self.adaptive.bucket_of(self.scheduler.chunk))
        for b in sorted(buckets):
            fn = self._prefill_fn(b, self.adaptive.cfg_for(b))
            out = fn(self.params, kv.pools, kv.device_page_table(0),
                     kv.device_lens(0), jnp.zeros((1, b), jnp.int32),
                     jnp.asarray(0, jnp.int32))
            jax.block_until_ready(out[0])
        return 1 + self.prefill_rejits - before

    # -- engine iteration ------------------------------------------------
    def step(self) -> Dict[str, Any]:
        """Admit, then run one jitted step (prefill chunk or decode)."""
        self.scheduler.admit()
        action, req = self.scheduler.next_action()
        info: Dict[str, Any] = {"kind": action}
        if action == "prefill":
            info.update(self._run_prefill(req))
        elif action == "decode":
            info.update(self._run_decode())
        elif self.scheduler.waiting:
            raise RuntimeError(
                "scheduler idle with waiting requests — admission wedged")
        self.step_count += 1
        info.update(cache_bytes=self.kv.cache_bytes,
                    kv_used_bytes=self.kv.used_bytes,
                    free_pages=self.kv.free_pages,
                    running=len(self.scheduler.running),
                    waiting=len(self.scheduler.waiting))
        self.metrics = info
        return info

    def _run_prefill(self, req: Request) -> Dict[str, Any]:
        kv, slot = self.kv, req.slot
        c = min(self.scheduler.chunk, req.remaining_prefill)
        bucket = self.adaptive.bucket_of(c)
        rcfg = self.adaptive.cfg_for(bucket)
        fn = self._prefill_fn(bucket, rcfg)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :c] = req.prompt[req.prefill_pos:req.prefill_pos + c]
        tok, kv.pools = fn(self.params, kv.pools,
                           kv.device_page_table(slot), kv.device_lens(slot),
                           jnp.asarray(toks), jnp.asarray(c, jnp.int32))
        req.prefill_pos += c
        kv.lens[slot] += c
        self.scheduler.prefill_advanced(req)
        if req.remaining_prefill == 0:
            req.state = RequestState.DECODE
            if req.emit(int(tok[0]), time.perf_counter()):
                self._retire(req)
        info = {"tokens": c, "bucket": bucket, "rid": req.rid}
        if rcfg.moe is not None:
            info.update(n=rcfg.moe.num_partitions,
                        strategy=rcfg.moe.memory_reuse_strategy)
        return info

    def _run_decode(self) -> Dict[str, Any]:
        kv = self.kv
        slots = self.scheduler.decode_slots()
        tokens = np.zeros((kv.max_slots, 1), np.int32)
        active = np.zeros((kv.max_slots,), bool)
        for s in slots:
            tokens[s, 0] = self.scheduler.running[s].output[-1]
            active[s] = True
        toks, kv.pools = self._decode_fn(
            self.params, kv.pools, kv.device_page_table(), kv.device_lens(),
            jnp.asarray(tokens), jnp.asarray(active))
        toks = np.asarray(toks)
        now = time.perf_counter()
        for s in slots:
            req = self.scheduler.running[s]
            kv.lens[s] += 1                  # the input token's KV slot
            if req.emit(int(toks[s]), now):
                self._retire(req)
        return {"tokens": len(slots)}

    def _retire(self, req: Request) -> None:
        self.scheduler.finish(req)
        self.done.append(req)

    def run_until_idle(self, max_steps: int = 1_000_000) -> None:
        steps = 0
        while self.has_work:
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"no quiescence in {max_steps} steps")

    # -- reporting -------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        lat = sorted(r.latency_s for r in self.done)
        pct = (lambda p: lat[min(len(lat) - 1,
                                 int(p / 100 * len(lat)))] if lat else 0.0)
        return {
            "requests_done": len(self.done),
            "tokens_generated": sum(len(r.output) for r in self.done),
            "engine_steps": self.step_count,
            "prefill_compiles": self.prefill_rejits,
            "p50_latency_s": pct(50),
            "p99_latency_s": pct(99),
            "cache_bytes": self.kv.cache_bytes,
            "peak_kv_used_bytes": self.kv.peak_used_bytes,
            "resolutions": {str(b): list(r) for b, r in
                            self.adaptive.resolutions.items()},
        }
