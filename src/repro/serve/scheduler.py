"""Continuous-batching scheduler: admission + prefill/decode interleave.

Policy:
* **Admission** is FCFS by a KV/token budget: a queued request is
  admitted when a batch slot is free and the paged cache can reserve its
  whole budget (prompt + max_new_tokens) up front — so nothing mid-flight
  can starve (no preemption needed).
* **Interleaving**: prefill is chunked (``chunk`` tokens per step) and
  alternates with decode whenever both have work, bounding decode-token
  latency by one chunk instead of one whole prompt — the serving analogue
  of MPipeMoE's pipelining (keep both "streams" busy instead of letting a
  long prefill stall every running sequence).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.serve.paged_kv import PagedKVCache
from repro.serve.request import Request, RequestState


class Scheduler:
    def __init__(self, kv: PagedKVCache, *, chunk: int = 64):
        assert chunk >= 1
        self.kv = kv
        self.chunk = chunk
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}          # slot -> request
        self._prefilling: Deque[int] = deque()         # slots, FCFS
        self._last_was_prefill = False

    # -- queue side ------------------------------------------------------
    def submit(self, req: Request) -> None:
        assert req.state == RequestState.QUEUED
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def free_slots(self) -> List[int]:
        return [s for s in range(self.kv.max_slots) if s not in self.running]

    # -- admission -------------------------------------------------------
    def admit(self) -> List[Request]:
        """Move QUEUED requests into free slots while the page budget
        holds. FCFS — a too-big head-of-line request blocks (no unfair
        overtake that could starve it forever)."""
        admitted = []
        free = deque(self.free_slots())
        while self.waiting and free:
            req = self.waiting[0]
            if not self.kv.can_admit(req.total_budget):
                break
            self.waiting.popleft()
            slot = free.popleft()
            self.kv.alloc_slot(slot, req.total_budget)
            req.slot = slot
            req.state = RequestState.PREFILL
            self.running[slot] = req
            self._prefilling.append(slot)
            admitted.append(req)
        return admitted

    # -- step planning ---------------------------------------------------
    def decode_slots(self) -> List[int]:
        return [s for s, r in self.running.items()
                if r.state == RequestState.DECODE]

    def next_action(self) -> Tuple[str, Optional[Request]]:
        """('prefill', request) | ('decode', None) | ('idle', None)."""
        has_prefill = bool(self._prefilling)
        has_decode = bool(self.decode_slots())
        if has_prefill and (not has_decode or not self._last_was_prefill):
            self._last_was_prefill = True
            return "prefill", self.running[self._prefilling[0]]
        if has_decode:
            self._last_was_prefill = False
            return "decode", None
        return "idle", None

    def prefill_advanced(self, req: Request) -> None:
        """Book-keeping after one prefill chunk of ``req`` ran."""
        if req.remaining_prefill <= 0:
            assert self._prefilling[0] == req.slot
            self._prefilling.popleft()

    def finish(self, req: Request) -> None:
        """Release a DONE request's slot and pages."""
        assert req.state == RequestState.DONE
        self.kv.free_slot(req.slot)
        self.running.pop(req.slot, None)
        req.slot = -1
