"""Continuous-batching scheduler: admission, preemption, interleave.

Policy:
* **Admission** is FCFS. Two reservation modes:
  - ``full_reserve=True`` (the conservative baseline): a queued request
    is admitted only when the cache can reserve its whole budget
    (prompt + max_new_tokens) up front — nothing mid-flight can ever
    starve, but the pool is massively over-reserved and bursty traffic
    queues behind it;
  - ``full_reserve=False`` (default): admission needs only a free slot
    plus pages for the request's *prompt* (its ``max_new_tokens`` decode
    budget is NOT reserved); decode grows page by page on demand, and
    when the pool runs dry the engine preempts a victim instead of
    wedging. Reserving the whole prompt up front keeps prefill from
    stealing pages mid-flight — only decode growth preempts — which
    damps preemption ping-pong under overload.
* **Placement** (DP-sharded KV pools): a fresh request is placed on the
  **least-loaded** shard (most free pages, ties to the lowest id) that
  has a free slot and can reserve its pages; the placement is **sticky**
  for the request's lifetime — every resume, recompute or offload, lands
  back on the same shard. With one shard (replicated pools) placement
  degenerates to the PR 2–4 behaviour.
* **Preemption** (:meth:`preempt`): the victim leaves its slot as
  PREEMPTED, either dropping its pages for later re-prefill (recompute)
  or parking them in the host pool (offload), and joins the resume
  queue. Pool-dry is a **per-shard** event: the victim is chosen among
  the dry shard's own requests (freeing pages elsewhere would not help).
  Resumes are strictly prioritized over fresh admissions, oldest first
  (lowest rid), with head-of-line blocking in both queues — the oldest
  work always makes progress, which is what guarantees the preemption
  storm converges. A resume blocked on its sticky shard blocks fresh
  admissions too (no overtake that could starve it forever).
* **Interleaving**: prefill is chunked (``chunk`` tokens per step) and
  alternates with decode whenever both have work, bounding decode-token
  latency by one chunk instead of one whole prompt — the serving analogue
  of MPipeMoE's pipelining (keep both "streams" busy instead of letting a
  long prefill stall every running sequence).

The scheduler talks only to the ``StateCache`` protocol
(``repro.serve.state_cache``) — slots, shards, reservations, offload —
so the same admission/preemption machinery serves paged-KV attention
models, constant-state recurrent models and composite (mixed-mixer)
models without a branch anywhere below this docstring.

Mesh-sharded serving: the scheduler stays device-count agnostic — it
plans over *logical* shards and slots the cache defines
(one shard when the pools replicate). All allocator state is host-side,
so one admission / preemption decision is valid on every device and no
per-device bookkeeping exists to drift out of sync (the would-be
distributed-consensus problem is designed away; see
``docs/distributed.md``).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.obs import PID_REQUESTS, Recorder
from repro.serve.request import Request, RequestState
from repro.serve.state_cache import StateCache

__all__ = ["Scheduler"]


class Scheduler:
    def __init__(self, kv: StateCache, *, chunk: int = 64,
                 full_reserve: bool = False,
                 obs: Optional[Recorder] = None):
        assert chunk >= 1
        self.kv = kv
        self.chunk = chunk
        self.full_reserve = full_reserve
        self.obs = obs if obs is not None else Recorder()
        self.waiting: Deque[Request] = deque()
        self.resuming: List[Request] = []              # PREEMPTED requests
        self.running: Dict[int, Request] = {}          # slot -> request
        self._prefilling: Deque[int] = deque()         # slots, FCFS
        self._last_was_prefill = False
        self.resume_count = 0
        self._m_resumes = self.obs.registry.counter(
            "repro_resumes_total", "preempted requests resumed")
        self._m_admits = self.obs.registry.counter(
            "repro_admits_total", "admissions by kind", ["kind"])

    # -- queue side ------------------------------------------------------
    def submit(self, req: Request) -> None:
        assert req.state == RequestState.QUEUED
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.resuming or self.running)

    def free_slots_of(self, shard: int) -> List[int]:
        return [s for s in self.kv.slots_of(shard)
                if s not in self.running]

    # -- admission -------------------------------------------------------
    def _admit_resume(self, req: Request, slot: int) -> None:
        if req.preempt_mode == "offload":
            self.kv.restore_slot(req.rid, slot, req.cached_tokens)
            req.state = (RequestState.PREFILL if req.resume_to == "prefill"
                         else RequestState.DECODE)
        else:                                   # recompute: re-prefill
            cached = self.kv.alloc_slot_prefix(
                slot, req.prefill_len, req.prefill_tokens,
                page_aligned=self.full_reserve)
            req.prefill_pos = cached            # skip the cached prefix
            req.state = RequestState.PREFILL
        self.resuming.remove(req)
        req.preempt_mode = ""
        req.cached_tokens = 0
        self.resume_count += 1
        self._m_resumes.inc()
        self._m_admits.labels(kind="resume").inc()
        tracer = self.obs.tracer
        tracer.instant("RESUME", pid=PID_REQUESTS, tid=req.rid,
                       args={"to": req.resume_to, "slot": slot})
        if req.state == RequestState.DECODE:
            tracer.begin("DECODE", pid=PID_REQUESTS, tid=req.rid)
            req.decode_span_open = True

    def _place_fresh(self, req: Request
                     ) -> Optional[Tuple[int, int, int]]:
        """(shard, slot, pages-to-reserve-in-tokens) for a fresh
        admission: the least-loaded shard that has a free slot and fits
        the reservation; None when no shard can take it right now. The
        returned ``need`` is the one source of truth for what
        ``admit()`` then actually reserves."""
        need = req.total_budget if self.full_reserve else req.prompt_len
        by_shard = {s: self.free_slots_of(s)
                    for s in range(self.kv.n_shards)}
        cands = [s for s, sl in by_shard.items() if sl]
        # cache-aware placement first: the shard holding the longest
        # published prefix of this prompt (no-op with the prefix cache
        # off, and under one shard it degenerates to a hit probe);
        # otherwise least-loaded
        shard, _ = self.kv.match_prefix(req.prefill_tokens, need,
                                        candidates=cands)
        if shard is None:
            shard = self.kv.best_shard(need, candidates=cands)
        if shard is None:
            return None
        return shard, by_shard[shard][0], need

    def admit(self) -> List[Request]:
        """Move resumable then QUEUED requests into free slots while the
        page budget holds. FCFS with head-of-line blocking in both queues
        (no unfair overtake that could starve the head forever); resumes
        strictly precede fresh admissions so preempted work cannot be
        starved by new arrivals stealing its pages. Fresh requests are
        placed on the least-loaded shard; resumes go back to their sticky
        shard."""
        admitted = []
        while True:
            if self.resuming:
                req = min(self.resuming, key=lambda r: r.rid)
                shard = req.kv_shard
                slots = self.free_slots_of(shard)
                if not slots:
                    break
                if req.preempt_mode == "offload":
                    if not self.kv.can_restore(req.rid):
                        break
                elif not self.kv.can_admit(req.prefill_len, shard):
                    break
                slot = slots[0]
                self._admit_resume(req, slot)
            elif self.waiting:
                req = self.waiting[0]
                placement = self._place_fresh(req)
                if placement is None:
                    break
                shard, slot, need = placement
                cached = self.kv.alloc_slot_prefix(
                    slot, need, req.prefill_tokens,
                    page_aligned=self.full_reserve)
                req.prefill_pos = cached        # skip the cached prefix
                self.waiting.popleft()
                req.kv_shard = shard
                req.state = RequestState.PREFILL
                self._m_admits.labels(kind="fresh").inc()
                tracer = self.obs.tracer
                tracer.thread_name(PID_REQUESTS, req.rid,
                                   f"req {req.rid}")
                tracer.instant("ADMIT", pid=PID_REQUESTS, tid=req.rid,
                               args={"shard": shard, "slot": slot,
                                     "reserved_tokens": need,
                                     "cached_tokens": cached})
            else:
                break
            req.slot = slot
            self.running[slot] = req
            if req.state == RequestState.PREFILL:
                self._prefilling.append(slot)
            admitted.append(req)
        return admitted

    # -- preemption ------------------------------------------------------
    def preempt(self, req: Request, mode: str) -> str:
        """Evict a running request: free or offload its pages, move it to
        the resume queue. Returns the mode actually applied (offload of
        an empty cache degrades to recompute). The request keeps its
        ``kv_shard`` — resumes land back on the same shard."""
        slot = req.slot
        assert self.running.get(slot) is req, f"request {req.rid} not running"
        req.resume_to = ("prefill" if req.state == RequestState.PREFILL
                         else "decode")
        req.cached_tokens = int(self.kv.lens[slot])
        if mode == "offload" and req.cached_tokens > 0:
            self.kv.offload_slot(slot, req.rid)
        else:
            mode = "recompute"
            self.kv.free_slot(slot)
            req.prefill_pos = 0
            req.cached_tokens = 0
            req.resume_to = "prefill"
        if slot in self._prefilling:
            self._prefilling.remove(slot)
        del self.running[slot]
        req.slot = -1
        req.state = RequestState.PREEMPTED
        req.preempt_mode = mode
        req.preempt_count += 1
        self.resuming.append(req)
        tracer = self.obs.tracer
        if req.decode_span_open:
            tracer.end("DECODE", pid=PID_REQUESTS, tid=req.rid)
            req.decode_span_open = False
        tracer.instant("PREEMPT", pid=PID_REQUESTS, tid=req.rid,
                       args={"mode": mode, "resume_to": req.resume_to,
                             "cached_tokens": req.cached_tokens})
        return mode

    # -- cancellation ----------------------------------------------------
    def cancel(self, req: Request) -> str:
        """Release everything ``req`` holds, from whatever lifecycle
        stage it is in, and return that stage ("queued" | "prefill" |
        "decode" | "preempted"). The engine (``Engine.cancel``) owns the
        state transition, callbacks and telemetry; this method owns the
        queue/slot/page bookkeeping:

        * QUEUED — drop from the waiting queue (nothing allocated yet);
        * PREFILL / DECODE — publish the completed full prefix pages
          (later requests sharing the prompt still benefit; no-op with
          the prefix cache off), then free the slot;
        * PREEMPTED — drop from the resume queue; an offload victim's
          host snapshot is discarded (its device pages were already
          freed at offload time, so nothing device-side moves).
        """
        stage = req.state.value
        if req.state == RequestState.QUEUED:
            self.waiting.remove(req)
        elif req.state in (RequestState.PREFILL, RequestState.DECODE):
            slot = req.slot
            assert self.running.get(slot) is req, \
                f"request {req.rid} not running in slot {slot}"
            self.kv.cache_slot_prefix(slot, req.prefill_tokens)
            self.kv.free_slot(slot)
            if slot in self._prefilling:
                self._prefilling.remove(slot)
            del self.running[slot]
            req.slot = -1
        elif req.state == RequestState.PREEMPTED:
            self.resuming.remove(req)
            if req.preempt_mode == "offload":
                self.kv.drop_offload(req.rid)
            req.preempt_mode = ""
            req.cached_tokens = 0
        else:
            raise ValueError(
                f"cancel of request {req.rid} in terminal state "
                f"{req.state.value}")
        return stage

    # -- step planning ---------------------------------------------------
    def decode_slots(self) -> List[int]:
        return [s for s, r in self.running.items()
                if r.state == RequestState.DECODE]

    def next_action(self) -> Tuple[str, Optional[Request]]:
        """('prefill', request) | ('decode', None) | ('idle', None)."""
        has_prefill = bool(self._prefilling)
        has_decode = bool(self.decode_slots())
        if has_prefill and (not has_decode or not self._last_was_prefill):
            self._last_was_prefill = True
            return "prefill", self.running[self._prefilling[0]]
        if has_decode:
            self._last_was_prefill = False
            return "decode", None
        return "idle", None

    def prefill_advanced(self, req: Request) -> None:
        """Book-keeping after one prefill chunk of ``req`` ran."""
        if req.remaining_prefill <= 0:
            assert self._prefilling[0] == req.slot
            self._prefilling.popleft()

    def finish(self, req: Request) -> None:
        """Release a DONE request's slot and pages."""
        assert req.state == RequestState.DONE
        self.kv.free_slot(req.slot)
        self.running.pop(req.slot, None)
        req.slot = -1
