"""Synthetic request traces for serving benchmarks and the CLI.

Poisson arrivals (exponential inter-arrival gaps at ``rate`` req/s) with
log-uniform-ish mixed prompt/generation lengths — deterministic in the
seed, so benchmark runs are reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

__all__ = ["TraceEntry", "dense_greedy_reference", "poisson_trace",
           "replay", "run_poisson"]


def dense_greedy_reference(params, cfg, prompt, max_new: int):
    """Golden reference: dense-cache sequential prefill + greedy decode,
    one request at a time (the legacy serve loop). The token-exactness
    oracle the paged / continuously-batched / preemptive engine is
    checked against in tests and the overload benchmark."""
    import jax.numpy as jnp

    from repro.models import lm

    toks = np.asarray(prompt)[None, :]
    logits, cache = lm.prefill(params, {"tokens": toks}, cfg,
                               max_len=len(prompt) + max_new,
                               dtype=jnp.float32)
    out = [int(np.argmax(np.asarray(logits[0, -1])))]
    for _ in range(max_new - 1):
        lg, cache = lm.decode_step(
            params, cache, np.asarray([[out[-1]]], np.int32), cfg)
        out.append(int(np.argmax(np.asarray(lg[0]))))
    return out


@dataclasses.dataclass(frozen=True)
class TraceEntry:
    arrival_s: float
    prompt: np.ndarray                 # [L] int32
    max_new_tokens: int


def poisson_trace(num_requests: int, *, rate: float, vocab_size: int,
                  prompt_len_range=(8, 96), gen_len_range=(4, 48),
                  seed: int = 0) -> List[TraceEntry]:
    rng = np.random.Generator(np.random.Philox(key=seed))
    gaps = rng.exponential(1.0 / rate, size=num_requests)
    arrivals = np.cumsum(gaps) - gaps[0]          # first request at t=0
    lo, hi = prompt_len_range
    plens = np.exp(rng.uniform(np.log(lo), np.log(hi + 1),
                               size=num_requests)).astype(int).clip(lo, hi)
    glo, ghi = gen_len_range
    glens = rng.integers(glo, ghi + 1, size=num_requests)
    return [TraceEntry(arrival_s=float(arrivals[i]),
                       prompt=rng.integers(0, vocab_size, size=int(plens[i]),
                                           dtype=np.int32),
                       max_new_tokens=int(glens[i]))
            for i in range(num_requests)]


def run_poisson(cfg, options, *, requests: int, rate: float,
                prompt_max: int, gen_max: int, seed: int = 0,
                eos_id=None, time_scale: float = 1.0, sampling=None,
                params=None, on_engine=None):
    """Build an Engine for ``cfg``/``options``, replay a Poisson trace
    through it, and return ``(engine, wall_s)`` — the shared body of the
    serving CLI and ``benchmarks/serving.py``. ``sampling`` (a
    :class:`repro.serve.sampling.SamplingParams`) applies to every
    request; ``params`` reuses an existing parameter tree (so two engines
    can be compared on identical weights); ``on_engine(engine)`` runs
    after warmup but before the replay — the hook the CLI uses to attach
    the live ``/metrics`` exporter to the engine's gauge refresher."""
    import time

    from repro.serve.engine import Engine

    engine = Engine(cfg, params, options=options)
    engine.warmup()        # steady-state numbers, not XLA compile time
    if on_engine is not None:
        on_engine(engine)
    trace = poisson_trace(requests, rate=rate, vocab_size=cfg.vocab_size,
                          prompt_len_range=(4, prompt_max),
                          gen_len_range=(2, gen_max), seed=seed)
    t0 = time.perf_counter()
    replay(engine, trace, eos_id=eos_id, time_scale=time_scale,
           sampling=sampling)
    return engine, time.perf_counter() - t0


def replay(engine, trace: List[TraceEntry], *, eos_id=None,
           time_scale: float = 1.0, sampling=None):
    """Drive ``engine`` through ``trace`` in wall-clock time (arrival
    offsets multiplied by ``time_scale``; 0 submits everything up front).
    Returns the list of submitted Requests (done when this returns)."""
    import time

    t0 = time.perf_counter()
    pending = list(trace)
    requests = []
    kw = {} if sampling is None else {"sampling": sampling}
    while pending or engine.has_work:
        now = time.perf_counter() - t0
        while pending and pending[0].arrival_s * time_scale <= now:
            e = pending.pop(0)
            # latency clocks start at the *scheduled* arrival, so
            # queueing delay accrued while the engine was mid-step is
            # part of the reported percentiles
            requests.append(engine.submit(
                e.prompt, max_new_tokens=e.max_new_tokens, eos_id=eos_id,
                arrival_s=t0 + e.arrival_s * time_scale, **kw))
        if engine.has_work:
            engine.step()
        elif pending:
            # idle until the next scheduled arrival, in one sleep — the
            # 0.05 s cap keeps very long gaps responsive to wall-clock
            # drift without degenerating into a 1 kHz busy-poll
            time.sleep(max(0.0, min(
                0.05, pending[0].arrival_s * time_scale - now)))
    return requests
