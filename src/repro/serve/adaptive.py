"""Per-bucket adaptive (n, strategy) resolution for chunked prefill.

Incoming prefill chunks have arbitrary token counts; jitting one program
per count would thrash the compile cache, and MPipeMoE's Algorithm 1
resolves a different optimal pipeline granularity ``n`` per token count.
So chunk sizes are bucketed to powers of two and each bucket resolved
once through the persistent :class:`repro.core.Resolver` (the same
hash/range-cached searcher the train-side controller uses) — the engine
then keeps one compiled prefill step per (bucket, n, strategy), mirroring
the train-side LRU cache.

``measure_fn(bucket_tokens, n, strategy) -> seconds`` injects the
measurement Algorithm 1 ranks candidates by: the analytic pipeline
simulator by default, or the engine's wall-clock candidate timer
(``EngineOptions.measure="wallclock"``, the auto choice on non-CPU
backends — the same split the train-side ``AdaptiveOptions.measure``
makes).

Mesh-sharded serving: buckets stay keyed by **global** chunk token
counts (the LRU of compiled steps is global-shaped too), while
``shards`` (= the mesh's EP extent) makes the analytic *granularity*
measure model each device's ``bucket / shards`` token share. A
wall-clock ``measure_fn`` needs no such correction — it times the
compiled *global* chunk, whose execution already contains the
per-device split and the real All-to-Alls. The Eq. 10 *strategy*
selection inside the Resolver still sees the global count — accepted,
because memory-reuse strategies only change execution under training's
``wrap_chunk`` remat; at serving time the strategy is inert (it is part
of the cache key, nothing more).
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Callable, Dict, Optional, Tuple

from repro.configs.base import ArchConfig
from repro.core.pipeline_sim import simulate
from repro.core.selector import Resolver, moe_workload
from repro.core.types import TPU_V5E, HardwareSpec, Strategy
from repro.obs import Recorder

log = logging.getLogger("repro.serve")

__all__ = ["PrefillBucketAdaptive", "force_adaptive"]


class PrefillBucketAdaptive:
    """Bucket prefill token counts -> concrete (n, strategy) configs."""

    def __init__(self, cfg: ArchConfig, *, hw: HardwareSpec = TPU_V5E,
                 ep_size: int = 1, dp: int = 1, min_bucket: int = 8,
                 max_bucket: int = 512,
                 measure_fn: Optional[Callable[[int, int, Strategy], float]]
                 = None, shards: int = 1,
                 obs: Optional[Recorder] = None):
        assert min_bucket > 0 and max_bucket >= min_bucket
        self.cfg = cfg
        self.min_bucket = min_bucket
        self.max_bucket = max_bucket
        self.shards = max(1, int(shards))
        if cfg.moe is not None and self.shards > 1 and measure_fn is None:
            # analytic path under a mesh: model the per-device share of
            # the bucket (the wall-clock path times the global chunk)
            def measure_fn(b: int, n: int, strategy: Strategy,
                           _cfg=cfg) -> float:
                w = moe_workload(_cfg, max(1, b // self.shards), ep_size,
                                 dp=dp)
                return simulate(w, hw, n, strategy)
        self.resolver = (Resolver(cfg, ep_size=ep_size, hw=hw,
                                  measure_fn=measure_fn, dp=dp, obs=obs)
                         if cfg.moe is not None else None)
        # bucket -> (n, strategy); insertion-ordered for reporting
        self.resolutions: Dict[int, Tuple[int, str]] = {}

    def bucket_of(self, ntok: int) -> int:
        """Smallest power-of-two bucket >= ntok, clamped to the range."""
        b = self.min_bucket
        while b < ntok and b < self.max_bucket:
            b *= 2
        return min(b, self.max_bucket)

    def cfg_for(self, bucket: int) -> ArchConfig:
        """Concrete config for one bucket; resolves (and logs) once."""
        if self.resolver is None:                  # dense model: no knobs
            self.resolutions.setdefault(bucket, (1, "none"))
            return self.cfg
        rcfg = self.resolver.resolve(bucket)
        resolved = (rcfg.moe.num_partitions, rcfg.moe.memory_reuse_strategy)
        if self.resolutions.get(bucket) != resolved:
            log.info("serve adaptive: bucket %d -> n=%d strategy=%s",
                     bucket, *resolved)
            self.resolutions[bucket] = resolved
        return rcfg

    @property
    def search_calls(self) -> int:
        return self.resolver.search_calls if self.resolver else 0


def force_adaptive(cfg: ArchConfig) -> ArchConfig:
    """Reset cfg.moe to the adaptive placeholders so every bucket is
    resolved by Algorithm 1 / Eq. 10 instead of a baked-in (n, strategy)."""
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, num_partitions=0, memory_reuse_strategy="adaptive"))
