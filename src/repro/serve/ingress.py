"""Async streaming ingress tier: a stdlib-asyncio HTTP/SSE front end
over :class:`repro.serve.Engine` — the production "front door" the
offline trace replays never had.

Threading model (three threads, one engine):

* the **engine thread** owns the engine outright. It loops over a
  thread-safe command queue (submits, cancels, shutdown) and calls
  ``engine.step()`` whenever there is work — nothing else ever touches
  engine/scheduler/cache state, so the single-threaded invariants of
  the serving stack survive concurrent clients for free;
* the **asyncio thread** runs the event loop: a hand-rolled HTTP/1.1
  server (stdlib ``asyncio.start_server`` — no new dependencies) that
  parses requests, enforces admission, and streams tokens out as SSE;
* tokens cross from the engine thread to a per-request
  ``asyncio.Queue`` via ``loop.call_soon_threadsafe`` from the
  request's ``on_token``/``on_done`` callbacks — per-decode-step
  streaming with no polling.

Endpoints:

* ``POST /generate`` — JSON body (``prompt`` token ids,
  ``max_new_tokens``, optional ``eos_id`` / ``stop`` / ``temperature``
  / ``top_k`` / ``top_p`` / ``seed``), response is an SSE stream: one
  ``data:`` event per generated token carrying ``token_id``, the token
  ``offset`` in the output stream, and ``finish_reason`` (null until
  the final event, which carries the reason and no token). The
  ``X-Admission`` response header reports ``accepted`` or ``degraded``.
* ``GET /healthz`` — liveness probe (``ok``). Live Prometheus metrics
  stay with ``repro.obs.MetricsServer`` (``--metrics-port``) — the
  ingress records into that same registry rather than growing its own.

Overload (``IngressOptions.admission_queue`` bounds requests accepted
but not yet finished — the backpressure valve):

* ``shed_policy="reject"`` — 429 with a ``Retry-After`` hint: the
  client sees the overload immediately and can back off or go
  elsewhere; nothing joins the queue;
* ``shed_policy="degrade"`` — admit, but clamp ``max_new_tokens`` to
  ``degrade_max_new``: every client gets *some* tokens (a prefix of
  exactly what the unclamped run would have produced — greedy decoding
  is deterministic) and the queue drains faster instead of growing.

A client disconnect mid-stream (EOF on the socket, or a failed write)
propagates to ``Engine.cancel`` through the engine-thread command
queue: the request's slot, pages and/or host-offload snapshot are
released within one engine step, from whatever lifecycle stage it was
in (see ``Scheduler.cancel``).

:class:`IngressClient` is the matching blocking SSE client (stdlib
socket + hand-rolled HTTP) used by the tests and by the closed-loop
load generator in ``benchmarks/serving.py --ingress-loadgen``; owning
the socket directly is what lets tests inject a mid-stream disconnect
by simply closing it.
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import contextlib
import dataclasses
import json
import queue
import socket
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.obs import PID_INGRESS
from repro.serve.request import Request
from repro.serve.sampling import SamplingParams

__all__ = ["IngressClient", "IngressOptions", "IngressServer",
           "SHED_POLICIES", "StreamResult"]

SHED_POLICIES = ("reject", "degrade")

_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            413: "Payload Too Large", 429: "Too Many Requests",
            500: "Internal Server Error"}


@dataclasses.dataclass
class IngressOptions:
    host: str = "127.0.0.1"
    port: int = 0                  # 0 = ephemeral (tests/benchmarks)
    admission_queue: int = 8       # accepted-but-unfinished bound
    shed_policy: str = "reject"    # "reject" | "degrade"
    degrade_max_new: int = 8       # clamped budget under overload
    retry_after_s: float = 1.0     # 429 Retry-After hint (seconds)
    max_body_bytes: int = 1 << 20


def _sse(token_id: Optional[int], offset: int,
         finish_reason: Optional[str]) -> bytes:
    return b"data: " + json.dumps(
        {"token_id": token_id, "offset": offset,
         "finish_reason": finish_reason},
        separators=(",", ":")).encode() + b"\n\n"


class _ClientGone(Exception):
    """The SSE consumer hung up mid-stream."""


class IngressServer:
    """HTTP/SSE ingress over one :class:`Engine` (module docstring).

    ``start()`` launches the asyncio and engine threads and binds the
    port (``.host`` / ``.port`` / ``.url`` afterwards); ``stop()``
    drains the engine, lets open streams flush, and tears both threads
    down. The engine must already be constructed (and ideally
    ``warmup()``-ed) by the caller; the ingress records its metrics and
    spans into the engine's own ``repro.obs`` recorder.
    """

    def __init__(self, engine, *, options: Optional[IngressOptions] = None):
        self.engine = engine
        self.opts = opts = options or IngressOptions()
        assert opts.shed_policy in SHED_POLICIES, opts.shed_policy
        assert opts.admission_queue >= 1, "admission_queue must be >= 1"
        assert opts.degrade_max_new >= 1, "degrade_max_new must be >= 1"
        self.obs = engine.obs
        self.host = opts.host
        self.port = 0
        self._cmds: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._inflight = 0             # accepted, not yet done/cancelled
        self._open_streams = 0         # SSE responses currently open
        self._shutdown = False
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._loop_thread: Optional[threading.Thread] = None
        self._engine_thread: Optional[threading.Thread] = None
        self._stop_ev: Optional[asyncio.Event] = None
        self._started = False
        reg = self.obs.registry
        self._m_requests = reg.counter(
            "repro_ingress_requests_total",
            "ingress admission outcomes", ["outcome"])
        self._m_disconnects = reg.counter(
            "repro_ingress_disconnects_total",
            "client disconnects mid-stream")
        self._m_stream_s = reg.histogram(
            "repro_ingress_stream_seconds",
            "SSE stream wall time, accept to close")
        self._g_inflight = reg.gauge(
            "repro_ingress_inflight_requests",
            "requests accepted but not yet finished")
        self._g_streams = reg.gauge(
            "repro_ingress_open_streams", "SSE streams currently open")

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "IngressServer":
        assert not self._started, "ingress already started"
        self._started = True
        started = threading.Event()
        self._loop_thread = threading.Thread(
            target=self._loop_main, args=(started,),
            name="ingress-loop", daemon=True)
        self._loop_thread.start()
        started.wait()
        self._engine_thread = threading.Thread(
            target=self._engine_loop, name="ingress-engine", daemon=True)
        self._engine_thread.start()
        return self

    def stop(self, drain: bool = True, timeout: float = 30.0) -> None:
        """Shut down: optionally let the engine finish its in-flight
        work (``drain``), flush open streams, then stop both threads.
        Idempotent."""
        if not self._started:
            return
        self._started = False
        fut: "concurrent.futures.Future" = concurrent.futures.Future()
        self._cmds.put((self._request_shutdown(drain), fut))
        self._engine_thread.join(timeout=timeout)
        deadline = time.perf_counter() + timeout
        while self._open_streams and time.perf_counter() < deadline:
            time.sleep(0.005)          # final SSE events still flushing
        self._loop.call_soon_threadsafe(self._stop_ev.set)
        self._loop_thread.join(timeout=timeout)

    def _request_shutdown(self, drain: bool):
        def fn():
            self._shutdown = True
            self._drain = drain
        return fn

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    # -- engine thread ---------------------------------------------------
    def _call(self, fn) -> "concurrent.futures.Future":
        """Run ``fn()`` on the engine thread; resolve the future with
        its result (or exception). The only path into engine state."""
        fut: "concurrent.futures.Future" = concurrent.futures.Future()
        self._cmds.put((fn, fut))
        return fut

    def _engine_loop(self) -> None:
        eng = self.engine
        self._drain = True
        while True:
            try:
                # block while idle (nothing to step); just poll the
                # queue between steps otherwise
                cmd = self._cmds.get(block=not eng.has_work,
                                     timeout=0.05)
            except queue.Empty:
                cmd = None
            while cmd is not None:
                fn, fut = cmd
                try:
                    fut.set_result(fn())
                except BaseException as e:      # noqa: BLE001
                    fut.set_exception(e)
                try:
                    cmd = self._cmds.get_nowait()
                except queue.Empty:
                    cmd = None
            if self._shutdown and not (self._drain and eng.has_work):
                break
            if eng.has_work:
                eng.step()

    # -- asyncio thread --------------------------------------------------
    def _loop_main(self, started: threading.Event) -> None:
        self._loop = asyncio.new_event_loop()
        asyncio.set_event_loop(self._loop)
        try:
            self._loop.run_until_complete(self._main(started))
        finally:
            self._loop.close()

    async def _main(self, started: threading.Event) -> None:
        self._stop_ev = asyncio.Event()
        server = await asyncio.start_server(
            self._handle, self.opts.host, self.opts.port)
        addr = server.sockets[0].getsockname()
        self.host, self.port = addr[0], int(addr[1])
        started.set()
        async with server:
            await self._stop_ev.wait()
        # the server no longer accepts; cancel any handler that is
        # still around (stop() already waited for streams to flush)
        tasks = [t for t in asyncio.all_tasks()
                 if t is not asyncio.current_task()]
        for t in tasks:
            t.cancel()
        await asyncio.gather(*tasks, return_exceptions=True)

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            line = await reader.readline()
            parts = line.decode("latin-1").split()
            if len(parts) < 2:
                return
            method, path = parts[0], parts[1].split("?", 1)[0]
            headers: Dict[str, str] = {}
            while True:
                hline = await reader.readline()
                if hline in (b"\r\n", b"\n", b""):
                    break
                k, _, v = hline.decode("latin-1").partition(":")
                headers[k.strip().lower()] = v.strip()
            if method == "GET" and path == "/healthz":
                await self._respond(writer, 200, b"ok\n", "text/plain")
            elif method == "POST" and path == "/generate":
                await self._generate(reader, writer, headers)
            else:
                await self._respond(writer, 404, b"not found\n",
                                    "text/plain")
        except (ConnectionResetError, BrokenPipeError,
                asyncio.IncompleteReadError, asyncio.CancelledError):
            pass
        finally:
            with contextlib.suppress(Exception):
                writer.close()

    async def _respond(self, writer, status: int, body: bytes,
                       ctype: str, extra: Tuple[Tuple[str, str], ...] = ()
                       ) -> None:
        head = [f"HTTP/1.1 {status} {_REASONS[status]}",
                f"Content-Type: {ctype}",
                f"Content-Length: {len(body)}", "Connection: close"]
        head += [f"{k}: {v}" for k, v in extra]
        writer.write(("\r\n".join(head) + "\r\n\r\n").encode() + body)
        await writer.drain()

    def _parse_generate(self, body: bytes) -> Dict[str, Any]:
        spec = json.loads(body)
        prompt = np.asarray([int(t) for t in spec["prompt"]], np.int32)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        out = {"prompt": prompt,
               "max_new_tokens": int(spec.get("max_new_tokens", 32)),
               "eos_id": (int(spec["eos_id"])
                          if spec.get("eos_id") is not None else None),
               "stop": tuple(tuple(int(t) for t in s)
                             for s in spec.get("stop", ()))}
        sp = SamplingParams(
            temperature=float(spec.get("temperature", 0.0)),
            top_k=int(spec.get("top_k", 0)),
            top_p=float(spec.get("top_p", 1.0)),
            seed=int(spec.get("seed", 0)))
        out["sampling"] = sp
        return out

    async def _generate(self, reader, writer,
                        headers: Dict[str, str]) -> None:
        opts, tracer = self.opts, self.obs.tracer
        t0 = time.perf_counter()
        try:
            n = int(headers.get("content-length", "0"))
        except ValueError:
            n = 0
        if n <= 0 or n > opts.max_body_bytes:
            self._m_requests.labels(outcome="bad_request").inc()
            await self._respond(writer, 413 if n > opts.max_body_bytes
                                else 400, b"bad body\n", "text/plain")
            return
        body = await reader.readexactly(n)
        try:
            spec = self._parse_generate(body)
        except (ValueError, KeyError, TypeError, json.JSONDecodeError) as e:
            self._m_requests.labels(outcome="bad_request").inc()
            await self._respond(writer, 400, f"{e}\n".encode(),
                                "text/plain")
            return

        # -- admission / load shedding -----------------------------------
        degraded = False
        with self._lock:
            over = self._inflight >= opts.admission_queue
            if over and opts.shed_policy == "reject":
                self._m_requests.labels(outcome="rejected").inc()
                tracer.instant("SHED", pid=PID_INGRESS, tid=0,
                               args={"policy": "reject"})
                retry = max(1, int(-(-opts.retry_after_s // 1)))
                await self._respond(
                    writer, 429, b"overloaded\n", "text/plain",
                    extra=(("Retry-After", str(retry)),))
                return
            if over:                           # degrade: clamp budget
                degraded = True
                spec["max_new_tokens"] = min(spec["max_new_tokens"],
                                             opts.degrade_max_new)
            self._inflight += 1
            self._g_inflight.set(self._inflight)

        loop = asyncio.get_running_loop()
        q: "asyncio.Queue" = asyncio.Queue()

        def post(item) -> None:
            # engine thread -> event loop; the loop may already be
            # gone during shutdown races — drop, the stream is dead
            try:
                loop.call_soon_threadsafe(q.put_nowait, item)
            except RuntimeError:
                pass

        def on_token(tok: int, _req: Request) -> None:
            post(("token", tok))

        def on_done(req: Request) -> None:
            with self._lock:
                self._inflight -= 1
                self._g_inflight.set(self._inflight)
            post(("done", req.finish_reason))

        def do_submit() -> Request:
            return self.engine.submit(
                spec["prompt"], max_new_tokens=spec["max_new_tokens"],
                eos_id=spec["eos_id"], stop=spec["stop"],
                sampling=spec["sampling"], on_token=on_token,
                on_done=on_done)

        try:
            req = await asyncio.wrap_future(self._call(do_submit))
        except ValueError as e:                # over engine capacity
            with self._lock:
                self._inflight -= 1
                self._g_inflight.set(self._inflight)
            self._m_requests.labels(outcome="bad_request").inc()
            await self._respond(writer, 400, f"{e}\n".encode(),
                                "text/plain")
            return
        outcome = "degraded" if degraded else "accepted"
        self._m_requests.labels(outcome=outcome).inc()
        tracer.thread_name(PID_INGRESS, req.rid, f"req {req.rid}")
        tracer.begin("STREAM", pid=PID_INGRESS, tid=req.rid,
                     args={"outcome": outcome,
                           "max_new": spec["max_new_tokens"]})

        self._open_streams += 1
        self._g_streams.set(self._open_streams)
        watcher = asyncio.ensure_future(self._watch_eof(reader))
        offset = 0
        try:
            writer.write((
                "HTTP/1.1 200 OK\r\n"
                "Content-Type: text/event-stream\r\n"
                "Cache-Control: no-cache\r\n"
                f"X-Admission: {outcome}\r\n"
                "Connection: close\r\n\r\n").encode())
            await writer.drain()
            while True:
                getter = asyncio.ensure_future(q.get())
                done, _ = await asyncio.wait(
                    {getter, watcher},
                    return_when=asyncio.FIRST_COMPLETED)
                if getter not in done:
                    getter.cancel()
                    raise _ClientGone
                kind, val = getter.result()
                if kind == "token":
                    writer.write(_sse(int(val), offset, None))
                    await writer.drain()
                    offset += 1
                else:                          # ("done", reason)
                    writer.write(_sse(None, offset, val))
                    await writer.drain()
                    break
            self._m_stream_s.observe(time.perf_counter() - t0)
        except (_ClientGone, ConnectionResetError, BrokenPipeError):
            self._m_disconnects.inc()
            tracer.instant("DISCONNECT", pid=PID_INGRESS, tid=req.rid,
                           args={"offset": offset})
            # the cancel runs on the engine thread between steps; a
            # request that happens to finish first is a no-op there
            self._call(lambda: self.engine.cancel(req))
        finally:
            tracer.end("STREAM", pid=PID_INGRESS, tid=req.rid)
            watcher.cancel()
            self._open_streams -= 1
            self._g_streams.set(self._open_streams)

    @staticmethod
    async def _watch_eof(reader: asyncio.StreamReader) -> None:
        """Resolve when the client half-closes or resets — stray bytes
        after the request body are drained and ignored."""
        while True:
            try:
                chunk = await reader.read(1024)
            except (ConnectionResetError, BrokenPipeError):
                return
            if not chunk:
                return


# ---------------------------------------------------------------------------
# Blocking SSE client (tests + benchmark load generator)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StreamResult:
    """One client-side request outcome."""
    status: int                    # HTTP status (200 / 429 / 400 / ...)
    tokens: List[int]              # token ids received, in order
    finish_reason: str             # "" unless the final event arrived
    degraded: bool = False         # X-Admission: degraded
    retry_after_s: float = 0.0     # 429 Retry-After hint
    ttft_s: float = 0.0            # send -> first token event
    latency_s: float = 0.0         # send -> stream end (or disconnect)


class IngressClient:
    """Minimal blocking SSE client over a raw socket, so tests and the
    load generator control the connection directly — a mid-stream
    disconnect is just ``disconnect_after=`` (the socket closes with
    the stream unread, which is exactly what a vanished client looks
    like to the server)."""

    def __init__(self, host: str, port: int, *, timeout: float = 60.0):
        self.host, self.port, self.timeout = host, int(port), timeout

    def healthz(self) -> bool:
        with socket.create_connection((self.host, self.port),
                                      self.timeout) as sock:
            sock.sendall((f"GET /healthz HTTP/1.1\r\n"
                          f"Host: {self.host}\r\n"
                          f"Connection: close\r\n\r\n").encode())
            return b" 200 " in sock.makefile("rb").readline()

    def generate(self, prompt, *, max_new_tokens: int = 32,
                 eos_id: Optional[int] = None, stop=(),
                 temperature: float = 0.0, top_k: int = 0,
                 top_p: float = 1.0, seed: int = 0,
                 disconnect_after: Optional[int] = None) -> StreamResult:
        """POST /generate and consume the SSE stream.
        ``disconnect_after=N`` closes the socket after the N-th token
        event (N=0: right after the headers), simulating a client that
        went away mid-stream."""
        body = json.dumps({
            "prompt": [int(t) for t in np.asarray(prompt).reshape(-1)],
            "max_new_tokens": int(max_new_tokens), "eos_id": eos_id,
            "stop": [list(map(int, s)) for s in stop],
            "temperature": temperature, "top_k": top_k, "top_p": top_p,
            "seed": seed}).encode()
        head = (f"POST /generate HTTP/1.1\r\nHost: {self.host}\r\n"
                f"Content-Type: application/json\r\n"
                f"Content-Length: {len(body)}\r\n"
                f"Connection: close\r\n\r\n").encode()
        t0 = time.perf_counter()
        with socket.create_connection((self.host, self.port),
                                      self.timeout) as sock:
            sock.sendall(head + body)
            f = sock.makefile("rb")
            status = int(f.readline().split()[1])
            headers: Dict[str, str] = {}
            while True:
                line = f.readline()
                if line in (b"\r\n", b"\n", b""):
                    break
                k, _, v = line.decode("latin-1").partition(":")
                headers[k.strip().lower()] = v.strip()
            res = StreamResult(
                status=status, tokens=[], finish_reason="",
                degraded=(headers.get("x-admission") == "degraded"),
                retry_after_s=float(headers.get("retry-after", 0.0)))
            if status != 200:
                res.latency_s = time.perf_counter() - t0
                return res
            if disconnect_after == 0:
                res.latency_s = time.perf_counter() - t0
                return res                     # close with stream unread
            for event in self._events(f):
                if event.get("finish_reason") is not None:
                    res.finish_reason = event["finish_reason"]
                    break
                res.tokens.append(int(event["token_id"]))
                if len(res.tokens) == 1:
                    res.ttft_s = time.perf_counter() - t0
                if disconnect_after is not None \
                        and len(res.tokens) >= disconnect_after:
                    break                      # hang up mid-stream
            res.latency_s = time.perf_counter() - t0
            return res

    @staticmethod
    def _events(f):
        """Parse ``data:`` SSE events off a socket file object."""
        data: List[bytes] = []
        for raw in f:
            line = raw.rstrip(b"\r\n")
            if line.startswith(b"data:"):
                data.append(line[5:].strip())
            elif not line and data:
                yield json.loads(b"\n".join(data))
                data = []
