"""The ``StateCache`` protocol: per-request device state behind one
slot-indexed surface, so ``Engine``/``Scheduler`` never see a concrete
cache implementation.

Everything the engine needs from "the cache" is a small contract:

* **slot lifecycle** — ``alloc_slot`` / ``grow_slot`` / ``free_slot``
  plus per-slot token accounting (the shared host ``lens`` array);
* **admission budgeting** — ``admissible`` (can this request *ever*
  fit a slot), ``can_admit`` / ``best_shard`` (does it fit *now*, and
  where), ``held_bytes`` (what a victim would release);
* **preemption snapshot/restore** — ``offload_slot`` parks a slot's
  device state in a host pool keyed by request id, ``restore_slot``
  brings it back (placement is sticky: a request restores onto its
  original dp shard);
* **dp-shard placement** — slots partition over ``n_shards`` mesh data
  groups (``shard_of_slot`` / ``slots_of``), with the committed device
  layouts for pools and per-slot rows (``pool_sharding`` /
  ``to_device_slots`` / ``pin_pools``);
* **device buffers for the jit'd step** — ``pools`` (the arrays the
  model reads/writes), ``device_page_table`` / ``device_lens`` /
  ``device_sinks`` / ``sink_row`` (the int32 step inputs, defensively
  copied — see the host-buffer aliasing gotcha in
  ``docs/architecture.md``);
* **byte accounting** — ``cache_bytes`` / ``used_bytes`` / peaks /
  swap counters for ``Engine.stats()``.

Implementations:

* :class:`~repro.serve.paged_kv.PagedKVCache` — paged attention KV
  (full K/V per token, or the compressed MLA latent ``c_kv`` — same
  allocator, latent trailing dims);
* :class:`ConstantStateCache` (here) — slot-indexed recurrent state
  for mamba/xLSTM mixers: O(1) bytes per sequence regardless of
  length, so there is nothing to page — admission is by free slot,
  growth is free, and snapshot/restore moves one fixed-size slot row;
* :class:`CompositeStateCache` (here) — mixed-mixer models (jamba =
  attn + mamba layers): one paged sub-cache for the attention layers
  and one constant-state sub-cache for the recurrent layers, fanned
  out behind the same protocol.

:func:`make_state_cache` builds the right implementation from the
``cache_kind`` reported by ``models/api.serving_support``.
"""
from __future__ import annotations

import abc
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import kv_cache

__all__ = ["KV_SHARDINGS", "CompositeStateCache", "ConstantStateCache",
           "StateCache", "make_state_cache"]

KV_SHARDINGS = ("replicated", "dp")


def _round_up(x: int, mult: int) -> int:
    return -(-int(x) // mult) * mult


class StateCache(abc.ABC):
    """Abstract per-request device-state cache (see module docstring).

    The base class owns what every implementation shares: shard
    topology (slots partitioned over the mesh data axis), the committed
    device placements, the host ``lens`` array the engine mutates in
    place, and the swap-byte counters. Subclasses own the actual state
    arrays and the lifecycle that binds them to slots.
    """

    kind: str = "abstract"

    def __init__(self, cfg: ArchConfig, *, max_slots: int, dist=None,
                 kv_sharding: str = "replicated", shards: int = 0):
        assert kv_sharding in KV_SHARDINGS, kv_sharding
        self.cfg = cfg
        self.dist = dist
        self.kv_sharding = kv_sharding
        # shard count: the mesh's dp extent under "dp" (overridable for
        # host-side allocator tests that have no mesh), else 1
        if shards:
            n_shards = int(shards)
        elif kv_sharding == "dp" and dist is not None:
            n_shards = dist.dp_size
        else:
            n_shards = 1
        self.n_shards = max(1, n_shards)
        # slots round up to the shard count so device arrays shard evenly
        self.max_slots = _round_up(max_slots, self.n_shards)
        self.slots_per_shard = self.max_slots // self.n_shards

        # -- committed device placements --------------------------------
        self._replicated = None
        self._pool_spec = None       # pools: state axis 1 over "data"
        self._slot_spec = None       # [slots, ...] arrays over "data"
        self._slot_specs = {}        # per-rank cache for to_device_slots
        if dist is not None:
            self._replicated = dist.named_sharding()
            if self.n_shards > 1:
                self._pool_spec = dist.named_sharding(None, "dp")
                self._slot_spec = dist.named_sharding("dp")
                self._slot_specs = {1: self._slot_spec}

        self.lens = np.zeros((self.max_slots,), np.int32)
        self.swap_out_bytes = 0
        self.swap_in_bytes = 0

    # -- shard topology (concrete) ---------------------------------------
    def shard_of_slot(self, slot: int) -> int:
        """Owning dp shard of ``slot`` (0 under the replicated layout)."""
        return slot // self.slots_per_shard

    def slots_of(self, shard: int) -> range:
        """The contiguous slot-id range owned by ``shard``."""
        return range(shard * self.slots_per_shard,
                     (shard + 1) * self.slots_per_shard)

    # -- admission budget ------------------------------------------------
    @property
    @abc.abstractmethod
    def max_slot_tokens(self) -> int:
        """Hard per-request token ceiling: the most tokens any single
        slot of this cache can ever hold (submit-time rejection)."""

    def admissible(self, total_tokens: int) -> bool:
        """Could a request of ``total_tokens`` ever be served?"""
        return 0 < int(total_tokens) <= self.max_slot_tokens

    @abc.abstractmethod
    def can_admit(self, total_tokens: int,
                  shard: Optional[int] = None) -> bool:
        """Can ``total_tokens`` be reserved now — on ``shard``, or on
        the best shard when None?"""

    @abc.abstractmethod
    def best_shard(self, total_tokens: int,
                   candidates: Optional[Sequence[int]] = None
                   ) -> Optional[int]:
        """Least-loaded sticky placement among ``candidates`` (default:
        all shards); None when no shard fits."""

    # -- slot lifecycle ---------------------------------------------------
    @abc.abstractmethod
    def alloc_slot(self, slot: int, tokens: int) -> None:
        """Bind ``slot`` with capacity for ``tokens``; resets lens to 0."""

    @abc.abstractmethod
    def grow_slot(self, slot: int) -> bool:
        """Extend the slot's capacity by one unit. False when the
        slot's shard is dry (caller preempts a victim and retries)."""

    @abc.abstractmethod
    def free_slot(self, slot: int) -> None:
        """Release the slot's state; lens resets to 0."""

    @abc.abstractmethod
    def slot_capacity(self, slot: int) -> int:
        """Tokens the slot can hold with its current reservation."""

    @abc.abstractmethod
    def held_bytes(self, slot: int) -> int:
        """Device bytes a preemption of this slot would release (0 for
        an unbound slot — such a slot is not a preemption victim)."""

    # -- preemption snapshot / restore ------------------------------------
    @abc.abstractmethod
    def offload_slot(self, slot: int, rid: int) -> int:
        """Snapshot the slot's state to the host pool (keyed by request
        id), release the device side. Returns bytes copied."""

    @abc.abstractmethod
    def restore_slot(self, rid: int, slot: int, tokens: int) -> int:
        """Restore a parked request onto ``slot`` of its original shard
        at length ``tokens``. Returns bytes copied."""

    @abc.abstractmethod
    def can_restore(self, rid: int) -> bool:
        """Does the parked request's shard have room to restore now?"""

    @abc.abstractmethod
    def drop_offload(self, rid: int) -> None:
        """Discard a parked request's host snapshot (cancellation — it
        will never resume). Device state was already released at
        ``offload_slot`` time, so this is pure host bookkeeping."""

    @property
    @abc.abstractmethod
    def offloaded_count(self) -> int:
        """Requests currently parked in the host pool."""

    @property
    @abc.abstractmethod
    def host_bytes(self) -> int:
        """Bytes currently parked in the host pool."""

    # -- cross-request prefix cache ----------------------------------------
    # The protocol ships no-op defaults so the engine/scheduler stay
    # implementation-agnostic: a cache that cannot share state across
    # requests (constant-state recurrent rows are position-dependent —
    # no snapshot exists at page boundaries) simply never reports hits.
    # PagedKVCache overrides the lot (refcounted pages + per-shard trie
    # + copy-on-write) when built with ``prefix_cache=True``.

    prefix_enabled: bool = False
    prefix_hits: int = 0
    prefix_misses: int = 0
    prefix_hit_tokens: int = 0
    prefix_evicted_pages: int = 0
    prefix_cow_copies: int = 0
    prefix_cow_bytes: int = 0

    def match_prefix(self, token_ids, total_tokens: int,
                     candidates: Optional[Sequence[int]] = None
                     ) -> Tuple[Optional[int], int]:
        """Longest cached prefix of ``token_ids`` usable by a request of
        ``total_tokens`` budget: ``(shard, cached_tokens)`` of the best
        feasible hit among ``candidates`` (default: all shards), or
        ``(None, 0)`` on a miss — placement then falls back to
        :meth:`best_shard`."""
        return None, 0

    def alloc_slot_prefix(self, slot: int, tokens: int, token_ids,
                          *, page_aligned: bool = False) -> int:
        """:meth:`alloc_slot` that binds the longest cached prefix of
        ``token_ids`` instead of allocating fresh pages for it. Returns
        the number of prefix tokens already cached (``lens[slot]`` is
        set to it) — 0 here and for any cache without a prefix index.
        ``page_aligned`` floors the hit to a page boundary so no shared
        page is ever written (the full-reserve scheduler: its slots must
        never need an extra copy-on-write target page beyond the
        reservation, because nothing may ever be preempted to free
        one)."""
        self.alloc_slot(slot, tokens)
        return 0

    def cache_slot_prefix(self, slot: int, token_ids) -> None:
        """Publish the slot's written full pages into the prefix index
        (``token_ids`` = exactly the tokens written so far). No-op for
        caches without a prefix index."""

    def ensure_private(self, slot: int, tokens: int) -> bool:
        """Make positions ``lens[slot]:tokens`` writable without
        corrupting state shared with other requests (copy-on-write).
        False when the shard has no page for the copy — the caller
        preempts a victim and retries, like :meth:`grow_slot`."""
        return True

    def prefix_cached_pages_of(self, shard: int) -> int:
        """Pages currently reachable through the prefix index on
        ``shard`` (0 without one)."""
        return 0

    def prefix_shared_pages_of(self, shard: int) -> int:
        """Pages on ``shard`` referenced by more than one owner
        (slots and/or the prefix index) — the dedup win, live."""
        return 0

    # -- device buffers for the jit'd step --------------------------------
    @property
    def pool_sharding(self):
        """The pools' committed layout (state axis over "data" under
        ``kv_sharding="dp"``, replicated otherwise; None unsharded).
        Step outputs must be pinned back to this (:meth:`pin_pools`)."""
        return self._pool_spec if self._pool_spec is not None \
            else self._replicated

    def pin_pools(self, pools):
        """Constrain step-output pools back to the committed pool
        layout. Traceable — the engine calls this *inside* its jitted
        step bodies, so the prefill→decode handoff needs no copy."""
        spec = self.pool_sharding
        if spec is None:
            return pools
        return jax.tree_util.tree_map(
            lambda x: jax.lax.with_sharding_constraint(x, spec), pools)

    def to_device(self, x):
        """Host array -> device array (replicated under a mesh)."""
        if self._replicated is not None:
            return jax.device_put(x, self._replicated)
        return jnp.asarray(x)

    def to_device_slots(self, x):
        """Host ``[max_slots, ...]`` array -> device, sharded over the
        slot axis under the DP layout (each dp group holds only its own
        slots' rows), replicated otherwise."""
        if self._slot_spec is not None:
            nd = np.ndim(x)
            spec = self._slot_specs.get(nd)      # hot path: decode calls
            if spec is None:                     # this every step
                spec = self.dist.named_sharding(
                    "dp", *((None,) * (nd - 1)))
                self._slot_specs[nd] = spec
            return jax.device_put(x, spec)
        return self.to_device(x)

    def device_lens(self, slot: Optional[int] = None):
        """Device mirror of the host ``lens`` array (one row when
        ``slot`` is given, slot-sharded full array otherwise)."""
        # NOTE: always .copy() — jnp.asarray of a host numpy array can
        # be zero-copy on CPU, and the engine mutates lens in place
        # while the dispatched step is still running asynchronously.
        if slot is None:
            return self.to_device_slots(self.lens.copy())
        return self.to_device(self.lens[slot:slot + 1].copy())

    @property
    @abc.abstractmethod
    def page_table_width(self) -> int:
        """Columns of the per-slot page-table step input (1 when the
        implementation has no real page table — the row is then a
        constant dummy that only keeps the jitted signature uniform)."""

    @abc.abstractmethod
    def device_page_table(self, slot: Optional[int] = None):
        """``[max_slots, W]`` (decode) or ``[1, W]`` (one slot's
        prefill) int32 page-table step input."""

    @abc.abstractmethod
    def device_sinks(self):
        """Per-slot masked-write sink ids ``[max_slots]`` for decode."""

    @abc.abstractmethod
    def sink_row(self, slot: int) -> np.ndarray:
        """``[1]`` masked-write sink id for one slot's prefill chunk."""

    @property
    def replicas(self) -> int:
        """Physical copies of each pool element (1 unsharded; every
        mesh device under "replicated"; the ep devices of one dp group
        under "dp")."""
        if self.dist is None:
            return 1
        return self.dist.mesh.size // self.n_shards

    # -- byte accounting ---------------------------------------------------
    @property
    @abc.abstractmethod
    def free_units(self) -> int:
        """Free allocation units (pages for a paged cache, slots for a
        constant-state cache) — a load signal for ``Engine.step()``."""

    @abc.abstractmethod
    def free_units_of(self, shard: int) -> int:
        """Free allocation units on one shard (the per-shard breakdown
        of :attr:`free_units` — a ``/metrics`` gauge per shard)."""

    def record_metrics(self, registry) -> None:
        """Refresh this cache's point-in-time gauges into a
        ``repro.obs`` registry (family registration is idempotent).
        Called on demand — by ``Engine.stats()`` and the ``/metrics``
        exporter's refresh hook — never on the per-step hot path.
        Subclasses extend with their own gauges via ``super()``."""
        g = registry.gauge
        g("repro_kv_cache_bytes",
          "allocated device state bytes").set(self.cache_bytes)
        g("repro_kv_used_bytes",
          "state bytes bound to live sequences").set(self.used_bytes)
        g("repro_kv_host_bytes",
          "bytes parked in the host offload pool").set(self.host_bytes)
        g("repro_kv_offloaded_requests",
          "requests parked in the host pool").set(self.offloaded_count)
        g("repro_swap_out_bytes",
          "cumulative device-to-host offload traffic").set(
            self.swap_out_bytes)
        g("repro_swap_in_bytes",
          "cumulative host-to-device restore traffic").set(
            self.swap_in_bytes)
        fam = g("repro_kv_free_units",
                "free cache units (pages or slots) per shard", ["shard"])
        for s in range(self.n_shards):
            fam.labels(shard=s).set(self.free_units_of(s))

    @property
    @abc.abstractmethod
    def cache_bytes(self) -> int:
        """Total logical bytes of the allocated device state (constant)."""

    @property
    @abc.abstractmethod
    def per_device_cache_bytes(self) -> int:
        """State bytes resident on one device."""

    @property
    @abc.abstractmethod
    def used_bytes(self) -> int:
        """Bytes currently bound to live sequences."""

    @property
    @abc.abstractmethod
    def peak_used_bytes(self) -> int:
        """High-water mark of :attr:`used_bytes`."""

    @property
    @abc.abstractmethod
    def per_device_peak_used_bytes(self) -> int:
        """Peak bytes resident on one device (busiest shard under dp)."""


class ConstantStateCache(StateCache):
    """Slot-indexed constant-size recurrent state (mamba conv window +
    SSM state, xLSTM cell state).

    Device side: ``models/kv_cache.init_state_slots`` stacks each
    recurrent layer's per-sequence state to ``[n_periods, max_slots,
    ...]`` — the jitted decode step reads/writes all slots batchwise,
    chunked prefill slices one slot's row. There is no paging: a
    sequence's state is O(1) in its length, so

    * admission is by **free slot** (the per-slot byte cost is fixed at
      construction — ``slot_bytes``);
    * ``grow_slot`` always succeeds (nothing grows);
    * preemption snapshot/restore moves one fixed-size slot row to the
      host pool and back (offload is always a tiny copy — see
      ``core.memory_model.PreemptionCost``);
    * dp sharding shards the **slot axis** of every state array, so
      decode stays data-parallel exactly like the paged layout.

    ``alloc_slot`` zeroes the slot's rows: a freed slot's stale state
    must never leak into the next request, and a recompute-resume must
    re-prefill from the zero state.
    """

    kind = "constant"

    def __init__(self, cfg: ArchConfig, *, max_slots: int,
                 max_seq_len: int, dtype=jnp.bfloat16, dist=None,
                 kv_sharding: str = "replicated", shards: int = 0):
        super().__init__(cfg, max_slots=max_slots, dist=dist,
                         kv_sharding=kv_sharding, shards=shards)
        self.max_seq_len = int(max_seq_len)
        self.pools: Any = kv_cache.init_state_slots(cfg, self.max_slots,
                                                    dtype)
        if self.pool_sharding is not None:
            self.pools = jax.device_put(self.pools, self.pool_sharding)
        self._allocated: List[bool] = [False] * self.max_slots
        # rid -> (host state tree, owning shard): preempted-by-offload
        # requests parked until resume (sticky placement, like paged)
        self._offloaded: Dict[int, Tuple[Any, int]] = {}
        self._peak_slots = 0
        self._peak_by_shard = [0] * self.n_shards

    # -- admission budget ------------------------------------------------
    @property
    def max_slot_tokens(self) -> int:
        return self.max_seq_len

    def free_slots_of(self, shard: int) -> int:
        return sum(not self._allocated[s] for s in self.slots_of(shard))

    def can_admit(self, total_tokens: int,
                  shard: Optional[int] = None) -> bool:
        if not self.admissible(total_tokens):
            return False
        shards = range(self.n_shards) if shard is None else (shard,)
        return any(self.free_slots_of(s) > 0 for s in shards)

    def best_shard(self, total_tokens: int,
                   candidates: Optional[Sequence[int]] = None
                   ) -> Optional[int]:
        cands = range(self.n_shards) if candidates is None else candidates
        best = None
        for s in cands:
            if not self.can_admit(total_tokens, s):
                continue
            if best is None or self.free_slots_of(s) > \
                    self.free_slots_of(best):
                best = s
        return best

    # -- slot lifecycle ---------------------------------------------------
    def _note_peak(self, shard: int) -> None:
        self._peak_slots = max(self._peak_slots, sum(self._allocated))
        used = self.slots_per_shard - self.free_slots_of(shard)
        self._peak_by_shard[shard] = max(self._peak_by_shard[shard], used)

    def _set_slot(self, slot: int, host=None) -> None:
        """Write one slot's state rows: zeros (alloc) or a host
        snapshot (restore), re-pinned to the committed pool layout."""
        spec = self.pool_sharding

        def upd(leaf, h=None):
            row = 0 if h is None else jnp.asarray(h, leaf.dtype)
            out = leaf.at[:, slot].set(row)
            return out if spec is None else jax.device_put(out, spec)

        if host is None:
            self.pools = jax.tree_util.tree_map(upd, self.pools)
        else:
            self.pools = jax.tree_util.tree_map(upd, self.pools, host)

    def alloc_slot(self, slot: int, tokens: int) -> None:
        assert not self._allocated[slot], f"slot {slot} already allocated"
        assert self.admissible(tokens), \
            f"alloc_slot of {tokens} tokens > {self.max_slot_tokens}"
        self._allocated[slot] = True
        self._set_slot(slot)             # zero: no stale-state leakage
        self.lens[slot] = 0
        self._note_peak(self.shard_of_slot(slot))

    def grow_slot(self, slot: int) -> bool:
        return True                      # state is O(1) in length

    def free_slot(self, slot: int) -> None:
        self._allocated[slot] = False
        self.lens[slot] = 0

    def slot_capacity(self, slot: int) -> int:
        return self.max_slot_tokens

    @property
    def slot_bytes(self) -> int:
        """Fixed per-slot state bytes (the admission budget unit)."""
        return self.cache_bytes // self.max_slots

    def held_bytes(self, slot: int) -> int:
        return self.slot_bytes if self._allocated[slot] else 0

    # -- preemption snapshot / restore ------------------------------------
    def offload_slot(self, slot: int, rid: int) -> int:
        assert self._allocated[slot], f"offload of empty slot {slot}"
        assert rid not in self._offloaded, f"rid {rid} already offloaded"
        shard = self.shard_of_slot(slot)
        host = jax.tree_util.tree_map(
            lambda leaf: np.asarray(leaf[:, slot]).copy(), self.pools)
        nbytes = kv_cache.tree_bytes(host)
        self._offloaded[rid] = (host, shard)
        self.swap_out_bytes += nbytes
        self.free_slot(slot)
        return nbytes

    def offloaded_shard(self, rid: int) -> int:
        return self._offloaded[rid][1]

    def can_restore(self, rid: int) -> bool:
        # the state is one fixed-size row — any free slot of the owning
        # shard can take it, and the caller only offers free slots
        return rid in self._offloaded

    def drop_offload(self, rid: int) -> None:
        del self._offloaded[rid]

    def restore_slot(self, rid: int, slot: int, tokens: int) -> int:
        host, shard = self._offloaded[rid]
        # validate before popping: a refused restore must not lose the
        # parked state
        assert not self._allocated[slot], f"slot {slot} already allocated"
        assert self.shard_of_slot(slot) == shard, \
            f"restore of rid {rid} onto slot {slot} (shard " \
            f"{self.shard_of_slot(slot)}) but its state lives on shard " \
            f"{shard} — placement is sticky"
        del self._offloaded[rid]
        self._allocated[slot] = True
        self._set_slot(slot, host)
        self.lens[slot] = tokens
        nbytes = kv_cache.tree_bytes(host)
        self.swap_in_bytes += nbytes
        self._note_peak(shard)
        return nbytes

    @property
    def offloaded_count(self) -> int:
        return len(self._offloaded)

    @property
    def host_bytes(self) -> int:
        return sum(kv_cache.tree_bytes(host)
                   for host, _ in self._offloaded.values())

    # -- device buffers for the jit'd step --------------------------------
    @property
    def page_table_width(self) -> int:
        return 1

    def device_page_table(self, slot: Optional[int] = None):
        # constant dummies (recurrent layers never index a page table);
        # cached — the content can never change
        if slot is None:
            if not hasattr(self, "_dev_pt"):
                self._dev_pt = self.to_device_slots(
                    np.zeros((self.max_slots, 1), np.int32))
            return self._dev_pt
        if not hasattr(self, "_dev_pt_row"):
            self._dev_pt_row = self.to_device(np.zeros((1, 1), np.int32))
        return self._dev_pt_row

    def device_sinks(self):
        if not hasattr(self, "_dev_sinks"):
            self._dev_sinks = self.to_device_slots(
                np.zeros((self.max_slots,), np.int32))
        return self._dev_sinks

    def sink_row(self, slot: int) -> np.ndarray:
        return np.zeros((1,), np.int32)

    # -- byte accounting ---------------------------------------------------
    @property
    def free_units(self) -> int:
        return self.max_slots - sum(self._allocated)

    def free_units_of(self, shard: int) -> int:
        return self.free_slots_of(shard)

    @property
    def cache_bytes(self) -> int:
        return kv_cache.cache_bytes(self.pools)

    @property
    def per_device_cache_bytes(self) -> int:
        return self.cache_bytes // self.n_shards

    @property
    def used_bytes(self) -> int:
        return sum(self._allocated) * self.slot_bytes

    @property
    def peak_used_bytes(self) -> int:
        return self._peak_slots * self.slot_bytes

    @property
    def per_device_peak_used_bytes(self) -> int:
        if self.n_shards == 1:
            return self.peak_used_bytes
        return max(self._peak_by_shard) * self.slot_bytes


class CompositeStateCache(StateCache):
    """Mixed-mixer models (jamba: attn + mamba layers): one
    :class:`~repro.serve.paged_kv.PagedKVCache` for the attention
    layers and one :class:`ConstantStateCache` for the recurrent
    layers, behind the single protocol surface.

    The two sub-caches share slot numbering, shard topology and the
    host ``lens`` array (aliased — the engine mutates one buffer and
    both device mirrors see it). Lifecycle calls fan out to both;
    admission and capacity are gated by the paged side (pages are the
    scarce resource — the constant side can always take a slot the
    paged side granted); the page-table/sink step inputs come from the
    paged side (recurrent layers ignore them). ``pools`` is the merged
    per-layer dict — the two key sets are disjoint by construction
    (``init_paged_pools`` covers exactly the attn layers,
    ``init_state_slots`` exactly the rest).
    """

    kind = "composite"

    def __init__(self, paged: "StateCache", state: ConstantStateCache):
        # no super().__init__: topology is inherited from the sub-caches
        # (asserted identical), not rebuilt
        assert paged.n_shards == state.n_shards, "shard topology mismatch"
        assert paged.max_slots == state.max_slots, "slot count mismatch"
        self.paged = paged
        self.state = state
        self.cfg = paged.cfg
        self.dist = paged.dist
        self.kv_sharding = paged.kv_sharding
        self.n_shards = paged.n_shards
        self.max_slots = paged.max_slots
        self.slots_per_shard = paged.slots_per_shard
        self._replicated = paged._replicated
        self._pool_spec = paged._pool_spec
        self._slot_spec = paged._slot_spec
        self._slot_specs = paged._slot_specs
        # one lens buffer, three views: engine writes kv.lens[slot] and
        # both sub-caches' device mirrors read the same array
        state.lens = paged.lens
        self.lens = paged.lens
        self._paged_keys = frozenset(paged.pools)
        self._state_keys = frozenset(state.pools)
        assert not (self._paged_keys & self._state_keys)

    # -- merged pools ------------------------------------------------------
    @property
    def pools(self):
        return {**self.paged.pools, **self.state.pools}

    @pools.setter
    def pools(self, new):
        self.paged.pools = {k: v for k, v in new.items()
                            if k in self._paged_keys}
        self.state.pools = {k: v for k, v in new.items()
                            if k in self._state_keys}

    # -- admission budget ------------------------------------------------
    @property
    def max_slot_tokens(self) -> int:
        return min(self.paged.max_slot_tokens, self.state.max_slot_tokens)

    def can_admit(self, total_tokens: int,
                  shard: Optional[int] = None) -> bool:
        return (self.paged.can_admit(total_tokens, shard)
                and self.state.can_admit(total_tokens, shard))

    def best_shard(self, total_tokens: int,
                   candidates: Optional[Sequence[int]] = None
                   ) -> Optional[int]:
        cands = [s for s in (range(self.n_shards) if candidates is None
                             else candidates)
                 if self.state.can_admit(total_tokens, s)]
        return self.paged.best_shard(total_tokens, cands)

    # -- slot lifecycle ---------------------------------------------------
    def alloc_slot(self, slot: int, tokens: int) -> None:
        self.paged.alloc_slot(slot, tokens)
        self.state.alloc_slot(slot, tokens)

    def grow_slot(self, slot: int) -> bool:
        return self.paged.grow_slot(slot)    # constant side never grows

    def free_slot(self, slot: int) -> None:
        self.paged.free_slot(slot)
        self.state.free_slot(slot)

    def slot_capacity(self, slot: int) -> int:
        return self.paged.slot_capacity(slot)

    def held_bytes(self, slot: int) -> int:
        return self.paged.held_bytes(slot) + self.state.held_bytes(slot)

    # -- preemption snapshot / restore ------------------------------------
    def offload_slot(self, slot: int, rid: int) -> int:
        return (self.paged.offload_slot(slot, rid)
                + self.state.offload_slot(slot, rid))

    def restore_slot(self, rid: int, slot: int, tokens: int) -> int:
        return (self.paged.restore_slot(rid, slot, tokens)
                + self.state.restore_slot(rid, slot, tokens))

    def can_restore(self, rid: int) -> bool:
        return self.paged.can_restore(rid) and self.state.can_restore(rid)

    def drop_offload(self, rid: int) -> None:
        self.paged.drop_offload(rid)
        self.state.drop_offload(rid)

    @property
    def offloaded_count(self) -> int:
        return self.paged.offloaded_count

    @property
    def host_bytes(self) -> int:
        return self.paged.host_bytes + self.state.host_bytes

    # -- device buffers for the jit'd step --------------------------------
    @property
    def page_table_width(self) -> int:
        return self.paged.page_table_width

    def device_page_table(self, slot: Optional[int] = None):
        return self.paged.device_page_table(slot)

    def device_sinks(self):
        return self.paged.device_sinks()

    def sink_row(self, slot: int) -> np.ndarray:
        return self.paged.sink_row(slot)

    # -- byte accounting ---------------------------------------------------
    @property
    def swap_out_bytes(self) -> int:
        return self.paged.swap_out_bytes + self.state.swap_out_bytes

    @property
    def swap_in_bytes(self) -> int:
        return self.paged.swap_in_bytes + self.state.swap_in_bytes

    @property
    def free_units(self) -> int:
        return self.paged.free_units

    def free_units_of(self, shard: int) -> int:
        # pages are the scarce resource — mirror free_units
        return self.paged.free_units_of(shard)

    def record_metrics(self, registry) -> None:
        # paged side carries the composite's aggregate gauges (it sees
        # only its own bytes), so take the base bookkeeping from *this*
        # object's properties and the per-shard paged extras explicitly
        StateCache.record_metrics(self, registry)
        self.paged.record_shard_metrics(registry)

    @property
    def cache_bytes(self) -> int:
        return self.paged.cache_bytes + self.state.cache_bytes

    @property
    def per_device_cache_bytes(self) -> int:
        return (self.paged.per_device_cache_bytes
                + self.state.per_device_cache_bytes)

    @property
    def used_bytes(self) -> int:
        return self.paged.used_bytes + self.state.used_bytes

    @property
    def peak_used_bytes(self) -> int:
        # sum of sub-cache peaks: an upper bound on the true composite
        # peak (the two high-water marks need not coincide)
        return self.paged.peak_used_bytes + self.state.peak_used_bytes

    @property
    def per_device_peak_used_bytes(self) -> int:
        return (self.paged.per_device_peak_used_bytes
                + self.state.per_device_peak_used_bytes)


def make_state_cache(cfg: ArchConfig, kind: str, *, num_pages: int,
                     page_size: int, max_slots: int,
                     max_pages_per_seq: int, max_seq_len: int,
                     dtype=jnp.bfloat16, dist=None,
                     kv_sharding: str = "replicated",
                     prefix_cache: bool = False) -> StateCache:
    """Build the :class:`StateCache` for ``cfg`` from the cache kind
    reported by ``models/api.serving_support`` ("paged" | "constant" |
    "composite"). The paged knobs (``num_pages`` / ``page_size`` /
    ``max_pages_per_seq``) are ignored by a pure constant-state cache;
    ``max_seq_len`` bounds the constant cache's per-request budget.
    ``prefix_cache`` turns on cross-request prefix reuse — **pure paged
    caches only**: recurrent state at position t depends on every prior
    token, so no shareable snapshot exists at a page boundary, and both
    the constant and composite kinds silently degrade to prefix-off
    (the engine stays correct either way — hits just never happen)."""
    from repro.serve.paged_kv import PagedKVCache   # lazy: avoids cycle

    if kind == "paged":
        return PagedKVCache(cfg, num_pages=num_pages, page_size=page_size,
                            max_slots=max_slots,
                            max_pages_per_seq=max_pages_per_seq,
                            dtype=dtype, dist=dist, kv_sharding=kv_sharding,
                            prefix_cache=prefix_cache)
    if kind == "constant":
        return ConstantStateCache(cfg, max_slots=max_slots,
                                  max_seq_len=max_seq_len, dtype=dtype,
                                  dist=dist, kv_sharding=kv_sharding)
    if kind == "composite":
        paged = PagedKVCache(cfg, num_pages=num_pages, page_size=page_size,
                             max_slots=max_slots,
                             max_pages_per_seq=max_pages_per_seq,
                             dtype=dtype, dist=dist,
                             kv_sharding=kv_sharding)
        state = ConstantStateCache(cfg, max_slots=paged.max_slots,
                                   max_seq_len=max_seq_len, dtype=dtype,
                                   dist=dist, kv_sharding=kv_sharding)
        return CompositeStateCache(paged, state)
    raise ValueError(f"unknown cache kind {kind!r}")
