"""``repro.serve`` — continuous-batching serving engine (PR 2 + PR 3).

Module map
----------
``engine.py``     :class:`Engine` / :class:`EngineOptions` — owns params,
                  page pools, scheduler and the two compiled-step caches
                  (decode: one program; prefill: LRU per
                  (bucket, n, strategy)); ``submit()`` / ``step()`` /
                  ``run_until_idle()`` / ``stats()``; preemption
                  orchestration (victim pick + offload-vs-recompute cost
                  model) and serve-side wall-clock (n, strategy)
                  measurement.
``scheduler.py``  :class:`Scheduler` — FCFS admission (full-budget
                  reservation with ``full_reserve``, prompt-only
                  reservation + on-demand decode growth otherwise),
                  preemption / resume queues, chunked-prefill / decode
                  interleaving. Talks only to the ``StateCache``
                  protocol.
``state_cache.py`` :class:`StateCache` — the per-request device-state
                  protocol the engine/scheduler program against: slot
                  lifecycle, admission, shard placement, snapshot /
                  restore for preemption, device buffers for the jitted
                  steps, byte accounting. Implementations:
                  :class:`ConstantStateCache` (slot-indexed O(1)
                  recurrent state — mamba conv window + SSM state,
                  xLSTM cell state), :class:`CompositeStateCache`
                  (paged + constant sub-caches for mixed-mixer models
                  like jamba) and :class:`PagedKVCache` below. The
                  kind is chosen by ``models/api.serving_support`` via
                  :func:`make_state_cache`.
``paged_kv.py``   :class:`PagedKVCache` — the paged ``StateCache``:
                  host page allocator (per-shard free lists, page
                  table, per-slot lengths, host offload pool) over the
                  device pools from ``models/kv_cache
                  .init_paged_pools`` (full K/V per token, or the
                  compressed MLA latent); each shard's local page 0 is
                  its reserved masked-write sink (one shard unsharded);
                  ``cache_bytes`` / ``used_bytes`` / ``per_device_*`` /
                  ``swap_*_bytes`` accounting. ``prefix_cache=True``
                  adds the cross-request prefix cache: a per-shard
                  refcounted trie of published full-page prefixes,
                  hit-binding at admission, copy-on-write before any
                  shared-page write, LRU eviction of trie-only pages.
``adaptive.py``   :class:`PrefillBucketAdaptive` — power-of-two token
                  buckets resolved once each through the persistent
                  ``core.Resolver`` (MPipeMoE Algorithm 1 + Eq. 10),
                  by analytic simulation or wall-clock candidate timing.
``request.py``    :class:`Request` / :class:`RequestState` — QUEUED →
                  PREFILL → DECODE → DONE with PREEMPTED round-trips,
                  streaming ``on_token`` / ``on_done`` callbacks,
                  ``max_new_tokens`` / ``eos_id`` / stop-sequence stops,
                  per-token timestamps (TTFT vs inter-token latency).
``sampling.py``   :class:`SamplingParams` / :func:`sample_tokens` —
                  jit-stable temperature / top-k / top-p with
                  per-request seeded streams; host-side stop matching.
``trace.py``      Poisson arrival traces + wall-clock ``replay``.
``ingress.py``    :class:`IngressServer` / :class:`IngressOptions` —
                  asyncio HTTP/SSE front end: per-decode-step token
                  streaming, bounded admission with ``reject`` /
                  ``degrade`` load shedding, client-disconnect →
                  ``Engine.cancel`` propagation; plus the blocking
                  :class:`IngressClient` used by tests and the
                  ``--ingress-loadgen`` benchmark.

Telemetry: every engine carries a ``repro.obs.Recorder`` — a metrics
registry ``stats()`` and the live ``/metrics`` exporter both read, plus
an (optional) span tracer emitting request-lifecycle / engine-step /
resolver-retune spans as Perfetto-loadable Chrome trace-event JSON.
Disabled-by-default tracing is a no-op recorder and adds zero jit
traces (pinned by the conformance matrix) — see ``docs/observability.md``.

Mesh-sharded serving (``EngineOptions.devices``): the engine builds a
dp x ep mesh (``distributed.context.make_serving_context``), shards
expert weights over EP, and drives chunked prefill through
``pipelined_moe``'s sharded (All-to-All) layout and decode through the
replicated psum layout. ``EngineOptions.kv_sharding`` picks the pool
layout: ``"replicated"`` (every device holds the whole pool) or
``"dp"`` (pages sharded over the data axis — per-shard free lists,
sticky least-loaded placement, per-shard pool-dry preemption,
data-parallel decode) — see ``docs/distributed.md``.

Invariants (tested in ``tests/test_serving.py`` /
``tests/test_preemption.py`` / ``tests/test_sampling.py`` /
``tests/test_serving_sharded.py``): paged + continuously batched greedy
decode emits exactly the tokens of the dense sequential loop — including
through recompute and offload preemptions, and on a device mesh; every
page returns to the free list once the pool drains; masked writes only
ever touch the sink page; a request's sampled tokens depend only on
(request, seed), never on batch composition.
"""
from repro.serve.adaptive import PrefillBucketAdaptive, force_adaptive
from repro.serve.engine import Engine, EngineOptions
from repro.serve.ingress import (IngressClient, IngressOptions,
                                 IngressServer, StreamResult)
from repro.serve.paged_kv import PagedKVCache
from repro.serve.request import Request, RequestState
from repro.serve.sampling import (SamplingParams, normalize_stops,
                                  sample_tokens, stop_hit)
from repro.serve.scheduler import Scheduler
from repro.serve.state_cache import (CompositeStateCache,
                                     ConstantStateCache, StateCache,
                                     make_state_cache)
from repro.serve.trace import (TraceEntry, dense_greedy_reference,
                               poisson_trace, replay, run_poisson)

__all__ = [
    "CompositeStateCache", "ConstantStateCache", "Engine", "EngineOptions",
    "IngressClient", "IngressOptions", "IngressServer", "PagedKVCache",
    "PrefillBucketAdaptive", "Request", "RequestState", "SamplingParams",
    "Scheduler", "StateCache", "StreamResult", "TraceEntry",
    "dense_greedy_reference", "force_adaptive", "make_state_cache",
    "normalize_stops", "poisson_trace", "replay", "run_poisson",
    "sample_tokens", "stop_hit",
]
