"""``repro.serve`` — continuous-batching serving engine (PR 2).

Module map
----------
``engine.py``     :class:`Engine` / :class:`EngineOptions` — owns params,
                  page pools, scheduler and the two compiled-step caches
                  (decode: one program; prefill: LRU per
                  (bucket, n, strategy)); ``submit()`` / ``step()`` /
                  ``run_until_idle()`` / ``stats()``.
``scheduler.py``  :class:`Scheduler` — FCFS admission by KV/token budget
                  (whole prompt+gen budget reserved up front) and
                  chunked-prefill / decode interleaving.
``paged_kv.py``   :class:`PagedKVCache` — host page allocator (free list,
                  page table, per-slot lengths) over the device pools from
                  ``models/kv_cache.init_paged_pools``; page 0 is the
                  reserved masked-write sink; ``cache_bytes`` /
                  ``used_bytes`` / ``peak_used_bytes`` accounting.
``adaptive.py``   :class:`PrefillBucketAdaptive` — power-of-two token
                  buckets resolved once each through the persistent
                  ``core.Resolver`` (MPipeMoE Algorithm 1 + Eq. 10).
``request.py``    :class:`Request` / :class:`RequestState` — QUEUED →
                  PREFILL → DECODE → DONE, streaming ``on_token`` /
                  ``on_done`` callbacks, per-request ``max_new_tokens``
                  and ``eos_id`` stop.
``trace.py``      Poisson arrival traces + wall-clock ``replay``.

Invariants (tested in ``tests/test_serving.py``): paged + continuously
batched greedy decode emits exactly the tokens of the dense sequential
loop; a slot's pages are reserved for its full budget at admission and
all return to the free list on completion; masked writes only ever touch
the sink page.
"""
from repro.serve.adaptive import PrefillBucketAdaptive, force_adaptive
from repro.serve.engine import Engine, EngineOptions
from repro.serve.paged_kv import PagedKVCache
from repro.serve.request import Request, RequestState
from repro.serve.scheduler import Scheduler
from repro.serve.trace import (TraceEntry, poisson_trace, replay,
                               run_poisson)

__all__ = [
    "Engine", "EngineOptions", "PagedKVCache", "PrefillBucketAdaptive",
    "Request", "RequestState", "Scheduler", "TraceEntry", "force_adaptive",
    "poisson_trace", "replay", "run_poisson",
]
