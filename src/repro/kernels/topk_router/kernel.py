"""Top-k gating Pallas TPU kernel: fused softmax + iterative top-k.

One pass over a [bt, E] logits tile in VMEM: fp32 softmax, then k
(static, <= 8) argmax+mask iterations on the VPU — no [T,E] probs round
trip to HBM between softmax and top-k, no XLA sort (top-k via k maxes is
cheaper than a full sort for k << E).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _kernel(logits_ref, probs_ref, idx_ref, *, k: int, renorm: bool):
    x = logits_ref[...].astype(jnp.float32)          # [bt, E]
    m = jnp.max(x, axis=-1, keepdims=True)
    ex = jnp.exp(x - m)
    probs = ex / jnp.sum(ex, axis=-1, keepdims=True)

    work = probs
    cols = jax.lax.broadcasted_iota(jnp.int32, work.shape, 1)
    tops, idxs = [], []
    for _ in range(k):
        best = jnp.max(work, axis=-1)
        bidx = jnp.argmax(work, axis=-1).astype(jnp.int32)
        tops.append(best)
        idxs.append(bidx)
        work = jnp.where(cols == bidx[:, None], NEG, work)
    top_p = jnp.stack(tops, axis=-1)                 # [bt, k]
    top_i = jnp.stack(idxs, axis=-1)
    if renorm and k > 1:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    probs_ref[...] = top_p
    idx_ref[...] = top_i


def topk_router_kernel(logits, k: int, *, renorm: bool = True,
                       block_t: int = 256, interpret: bool = False):
    """logits: [T, E] -> (probs [T, k] f32, idx [T, k] i32)."""
    t, e = logits.shape
    bt = min(block_t, t)
    assert t % bt == 0, (t, bt)
    grid = (t // bt,)
    return pl.pallas_call(
        functools.partial(_kernel, k=k, renorm=renorm),
        grid=grid,
        in_specs=[pl.BlockSpec((bt, e), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bt, k), lambda i: (i, 0)),
                   pl.BlockSpec((bt, k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((t, k), jnp.float32),
                   jax.ShapeDtypeStruct((t, k), jnp.int32)],
        interpret=interpret,
    )(logits)
