from repro.kernels.topk_router.ops import topk_router
from repro.kernels.topk_router.ref import topk_router_ref

__all__ = ["topk_router", "topk_router_ref"]
