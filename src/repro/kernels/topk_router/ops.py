"""Jit'd wrapper for the top-k router kernel (pads T to the tile)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.topk_router.kernel import topk_router_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def topk_router(logits, k: int, renorm: bool = True, block_t: int = 256):
    t, e = logits.shape
    bt = min(block_t, t)
    pad = (-t) % bt
    lp = jnp.pad(logits, ((0, pad), (0, 0))) if pad else logits
    probs, idx = topk_router_kernel(lp, k, renorm=renorm, block_t=bt,
                                    interpret=_interpret())
    return probs[:t], idx[:t]
