"""Pure-jnp oracle for the top-k router kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_router_ref(logits, k: int, renorm: bool = True):
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    if renorm and k > 1:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_i.astype(jnp.int32)
