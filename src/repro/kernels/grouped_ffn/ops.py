"""Jit'd wrapper for the grouped expert FFN Pallas kernel.

Pads the capacity dim to the token-tile multiple, dispatches to the
kernel (interpret mode on CPU), casts the fp32 accumulator back, and
carries a custom VJP whose backward uses the jnp reference (the paper's
S3/S4 recompute semantics: T_M is rebuilt from T_DI, never stored).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.grouped_ffn.kernel import grouped_ffn_kernel
from repro.kernels.grouped_ffn.ref import grouped_ffn_ref, _ACTS


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def grouped_ffn(x, w_up, w_gate, w_down, act: str = "silu"):
    e, c, m = x.shape
    bc = 128 if c >= 128 else max(8, 1 << (c - 1).bit_length())
    pad = (-c) % bc
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0))) if pad else x
    h = w_up.shape[-1]
    bh = min(512, h)
    while h % bh:
        bh //= 2
    out = grouped_ffn_kernel(xp, w_up, w_gate, w_down, act=act,
                             block_c=bc, block_h=max(bh, 1),
                             interpret=_interpret())
    return out[:, :c].astype(x.dtype)


def _fwd(x, w_up, w_gate, w_down, act):
    return grouped_ffn(x, w_up, w_gate, w_down, act), \
        (x, w_up, w_gate, w_down)


def _bwd(act, res, g):
    x, w_up, w_gate, w_down = res
    # recompute T_M (paper's recompute restore) and differentiate the
    # jnp reference — exact gradients, no stored hidden activation
    def f(x_, wu_, wg_, wd_):
        out = grouped_ffn_ref(x_, wu_, wg_, wd_, act=act)
        return out.astype(x.dtype)
    if w_gate is None:
        _, vjp = jax.vjp(lambda a, b, d: f(a, b, None, d), x, w_up, w_down)
        dx, dwu, dwd = vjp(g)
        return dx, dwu, None, dwd
    _, vjp = jax.vjp(f, x, w_up, w_gate, w_down)
    return vjp(g)


grouped_ffn.defvjp(_fwd, _bwd)
