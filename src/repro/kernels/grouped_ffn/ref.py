"""Pure-jnp oracle for the fused grouped expert FFN kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_ACTS = {"silu": jax.nn.silu,
         "gelu": lambda x: jax.nn.gelu(x, approximate=True),
         "relu": jax.nn.relu}


def grouped_ffn_ref(x, w_up, w_gate, w_down, *, act: str = "silu"):
    """x: [E, C, M]; w_up/w_gate: [E, M, H]; w_down: [E, H, M]."""
    h = jnp.einsum("ecm,emh->ech", x.astype(jnp.float32),
                   w_up.astype(jnp.float32))
    if w_gate is not None:
        g = jnp.einsum("ecm,emh->ech", x.astype(jnp.float32),
                       w_gate.astype(jnp.float32))
        h = _ACTS[act](g) * h
    else:
        h = _ACTS[act](h)
    return jnp.einsum("ech,ehm->ecm", h, w_down.astype(jnp.float32))
