from repro.kernels.grouped_ffn.ops import grouped_ffn
from repro.kernels.grouped_ffn.ref import grouped_ffn_ref

__all__ = ["grouped_ffn", "grouped_ffn_ref"]
