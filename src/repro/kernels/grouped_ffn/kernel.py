"""Fused grouped expert FFN Pallas TPU kernel.

The paper's expert stage C is a per-expert 2-GEMM FFN. On GPU, MPipeMoE
keeps T_M (the hidden activation) in HBM and manages its reuse; the
TPU-native adaptation goes further: GEMM1 -> activation -> GEMM2 are fused
so each T_M *tile* lives only in VMEM and the full T_M never touches HBM
in the forward pass — the kernel-level analogue of strategy S3/S4.

Grid: (experts, token-tiles, hidden-tiles). The hidden dim is the
innermost (sequential on TPU) axis and accumulates into the fp32 output
tile, which Pallas keeps resident in VMEM across the accumulation.

Block shapes are MXU-aligned (multiples of 128); VMEM budget per step:
  x (bc x M) + w_up/w_gate/w_down (M x bh each) + out (bc x M)
e.g. bc=128, bh=256, M=8192, bf16: 2+4+4+4+4 = ~18 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_ACTS = {"silu": jax.nn.silu,
         "gelu": lambda x: jax.nn.gelu(x, approximate=True),
         "relu": jax.nn.relu}


def _kernel(x_ref, wu_ref, wd_ref, o_ref, *, act: str):
    h = jnp.dot(x_ref[0], wu_ref[0], preferred_element_type=jnp.float32)
    h = _ACTS[act](h)
    contrib = jnp.dot(h.astype(x_ref.dtype), wd_ref[0],
                      preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = contrib[None]

    @pl.when(pl.program_id(2) > 0)
    def _acc():
        o_ref[...] += contrib[None]


def _kernel_gated(x_ref, wu_ref, wg_ref, wd_ref, o_ref, *, act: str):
    up = jnp.dot(x_ref[0], wu_ref[0], preferred_element_type=jnp.float32)
    gate = jnp.dot(x_ref[0], wg_ref[0], preferred_element_type=jnp.float32)
    h = _ACTS[act](gate) * up
    contrib = jnp.dot(h.astype(x_ref.dtype), wd_ref[0],
                      preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = contrib[None]

    @pl.when(pl.program_id(2) > 0)
    def _acc():
        o_ref[...] += contrib[None]


def grouped_ffn_kernel(x, w_up, w_gate, w_down, *, act: str = "silu",
                       block_c: int = 128, block_h: int = 128,
                       interpret: bool = False):
    """x: [E, C, M]; w_up/w_gate: [E, M, H]; w_down: [E, H, M] -> [E, C, M]
    (fp32 accumulator output; caller casts)."""
    e, c, m = x.shape
    h = w_up.shape[-1]
    bc = min(block_c, c)
    bh = min(block_h, h)
    assert c % bc == 0 and h % bh == 0, (c, bc, h, bh)
    grid = (e, c // bc, h // bh)

    x_spec = pl.BlockSpec((1, bc, m), lambda e_, c_, h_: (e_, c_, 0))
    wu_spec = pl.BlockSpec((1, m, bh), lambda e_, c_, h_: (e_, 0, h_))
    wd_spec = pl.BlockSpec((1, bh, m), lambda e_, c_, h_: (e_, h_, 0))
    o_spec = pl.BlockSpec((1, bc, m), lambda e_, c_, h_: (e_, c_, 0))

    if w_gate is None:
        return pl.pallas_call(
            functools.partial(_kernel, act=act),
            grid=grid,
            in_specs=[x_spec, wu_spec, wd_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((e, c, m), jnp.float32),
            interpret=interpret,
        )(x, w_up, w_down)
    return pl.pallas_call(
        functools.partial(_kernel_gated, act=act),
        grid=grid,
        in_specs=[x_spec, wu_spec, wu_spec, wd_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((e, c, m), jnp.float32),
        interpret=interpret,
    )(x, w_up, w_gate, w_down)
