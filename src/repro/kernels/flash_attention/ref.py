"""Pure-jnp oracle for the flash-attention kernel (naive softmax)."""
from __future__ import annotations

import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [BH, Sq, D]; k/v: [BKV, Sk, D] (BH = BKV * group)."""
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    g = bh // bkv
    if g > 1:
        k = jnp.repeat(k, g, axis=0)
        v = jnp.repeat(v, g, axis=0)
    s = jnp.einsum("hqd,htd->hqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("hqt,htd->hqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
