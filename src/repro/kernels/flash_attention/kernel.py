"""FlashAttention Pallas TPU kernel (forward).

Grid (batch*q_heads, q_tiles, kv_tiles); kv is the innermost (sequential
on TPU) axis, so the online-softmax running stats (m, l) and the output
accumulator live in VMEM scratch across kv steps — scores never touch
HBM. GQA is handled by the KV BlockSpec index maps (kv head = q head //
group): no materialized repeat, the DMA just re-reads the shared head.
Causal/sliding-window masking is positional; fully-masked tiles write
nothing but are still visited (grid is static).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # [bq, D]
    k = k_ref[0]                                   # [bk, D]
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < seq_k
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           window: int = 0, block_q: int = 512,
                           block_k: int = 512, interpret: bool = False):
    """q: [BH, Sq, D]; k/v: [BKV, Sk, D] with BH = BKV * group.

    Returns [BH, Sq, D]. Callers flatten (batch, heads) into dim 0; the
    index maps route q-head h to kv-head h // group.
    """
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    group = bh // bkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    sq_p, sk_p = -(-sq // bq) * bq, -(-sk // bk) * bk
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0)))
    grid = (bh, sq_p // bq, sk_p // bk)
    scale = d ** -0.5

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, seq_k=sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
