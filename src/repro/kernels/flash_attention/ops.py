"""Jit'd wrapper: [B,S,H,D]-layout entry point for the flash kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512):
    """q: [B,Sq,Hq,D]; k/v: [B,Sk,Kv,D] -> [B,Sq,Hq,D]."""
    b, sq, hq, d = q.shape
    _, sk, kv, _ = k.shape
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, d)
    out = flash_attention_kernel(qf, kf, vf, causal=causal, window=window,
                                 block_q=block_q, block_k=block_k,
                                 interpret=_interpret())
    return out.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
