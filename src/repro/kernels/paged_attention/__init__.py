from repro.kernels.paged_attention.ops import (paged_decode_attention,
                                               paged_mla_decode)
from repro.kernels.paged_attention.ref import (paged_decode_attention_ref,
                                               paged_mla_decode_ref)

__all__ = ["paged_decode_attention", "paged_mla_decode",
           "paged_decode_attention_ref", "paged_mla_decode_ref"]
