"""Paged-attention decode Pallas TPU kernels (plain GQA + MLA latent).

Grid (slots, pages); pages is the innermost (sequential on TPU) axis.
Each step DMAs ONE physical page: the page table is a scalar-prefetch
operand, so the KV BlockSpec index maps route block ``j`` of slot ``b``
straight to ``page_table[b, j]`` — resident KV is never materialized
contiguously in HBM, which is the whole point vs the
``kv_cache.gather_pages`` baseline whose copy grows with context.

Exactness contract: the per-page score tiles (flash-style QK tiling)
are staged into a full-length VMEM scratch along with the value pages,
and the masking / softmax / PV contraction run ONCE over the staged
``[T]`` axis at the last page — the same ops, in the same order, as the
gather reference (``ref.py``). A running-rescale online softmax would
be algebraically equal but not bit-equal (``exp(a)*exp(b) !=
exp(a+b)``); we trade its O(block) score memory for O(T)-per-slot VMEM
staging so decode stays token-exact across the kernel/gather A/B that
the serving conformance tier pins. Sink pages and grown-ahead pages
(slots holding more pages than ``pages_for(lens)``) need no separate
mask: every position ``>= lens`` is cut by the length mask, and the
page walk only ever reads pages named by the slot's own page-table row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38   # matches repro.models.layers.attention.NEG_INF


# ---------------------------------------------------------------------------
# Plain GQA decode
# ---------------------------------------------------------------------------

def _decode_kernel(pt_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                   s_scr, v_scr, *, ps: int, window: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)
    npages = pl.num_programs(1)

    q = q_ref[0]                                       # [Kv, G, D]
    k = k_ref[0]                                       # [ps, Kv, D]
    s = jnp.einsum("kgd,tkd->kgt", q, k,
                   preferred_element_type=jnp.float32) * scale
    s_scr[:, :, pl.ds(j * ps, ps)] = s
    v_scr[pl.ds(j * ps, ps)] = v_ref[0]

    @pl.when(j == npages - 1)
    def _finish():
        t = npages * ps
        cl = lens_ref[b]
        idx = jax.lax.broadcasted_iota(jnp.int32, (t,), 0)
        valid = idx < cl
        if window > 0:
            valid = valid & (idx >= cl - window)
        s_all = jnp.where(valid[None, None, :], s_scr[...], NEG_INF)
        m = s_all.max(axis=-1, keepdims=True)
        p = jnp.exp(s_all - m)
        l = p.sum(axis=-1, keepdims=True)
        out = jnp.einsum("kgt,tkd->kgd", p / jnp.maximum(l, 1e-30),
                         v_scr[...].astype(jnp.float32))
        o_ref[0] = out.astype(o_ref.dtype)


def paged_decode_attention_kernel(q, k_pool, v_pool, page_table, lens, *,
                                  window: int = 0,
                                  interpret: bool = False):
    """q: [B, Kv, G, D]; pools: [P, ps, Kv, D]; page_table: [B, NP]
    int32; lens: [B] int32 — valid cache entries per slot INCLUDING the
    token scattered this step. Returns [B, Kv, G, D] in q's dtype.
    """
    b, kv, g, d = q.shape
    _, ps = page_table.shape[0], k_pool.shape[1]
    npages = page_table.shape[1]
    t = npages * ps

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # page_table, lens
        grid=(b, npages),
        in_specs=[
            pl.BlockSpec((1, kv, g, d), lambda bi, j, pt, ln: (bi, 0, 0, 0)),
            pl.BlockSpec((1, ps, kv, d),
                         lambda bi, j, pt, ln: (pt[bi, j], 0, 0, 0)),
            pl.BlockSpec((1, ps, kv, d),
                         lambda bi, j, pt, ln: (pt[bi, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, kv, g, d),
                               lambda bi, j, pt, ln: (bi, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, g, t), jnp.float32),
            pltpu.VMEM((t, kv, d), v_pool.dtype),
        ],
    )
    return pl.pallas_call(
        functools.partial(_decode_kernel, ps=ps, window=window,
                          scale=d ** -0.5),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, d), q.dtype),
        interpret=interpret,
    )(page_table, lens, q, k_pool, v_pool)


# ---------------------------------------------------------------------------
# MLA latent decode (absorbed formulation)
# ---------------------------------------------------------------------------

def _mla_kernel(pt_ref, lens_ref, qa_ref, qr_ref, ckv_ref, kr_ref, o_ref,
                s_scr, c_scr, *, ps: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)
    npages = pl.num_programs(1)

    qa = qa_ref[0]                                     # [H, R]
    qr = qr_ref[0]                                     # [H, E]
    ckv = ckv_ref[0]                                   # [ps, R]
    kr = kr_ref[0]                                     # [ps, E]
    s = (jnp.einsum("hr,tr->ht", qa, ckv.astype(qa.dtype),
                    preferred_element_type=jnp.float32)
         + jnp.einsum("he,te->ht", qr, kr.astype(qr.dtype),
                      preferred_element_type=jnp.float32))
    s_scr[:, pl.ds(j * ps, ps)] = s
    c_scr[pl.ds(j * ps, ps)] = ckv

    @pl.when(j == npages - 1)
    def _finish():
        t = npages * ps
        ln = lens_ref[b]
        idx = jax.lax.broadcasted_iota(jnp.int32, (t,), 0)
        # decode queries sit at absolute position ``lens``; key position
        # t is visible iff t <= lens (the just-written token included)
        s_all = s_scr[...] * scale
        s_all = jnp.where((idx <= ln)[None, :], s_all, NEG_INF)
        p = jax.nn.softmax(s_all, axis=-1)
        o_ref[0] = jnp.einsum("ht,tr->hr", p,
                              c_scr[...].astype(jnp.float32))


def paged_mla_decode_kernel(q_abs, q_rope, ckv_pool, kr_pool, page_table,
                            lens, *, scale: float,
                            interpret: bool = False):
    """q_abs: [B, H, R] (latent-absorbed); q_rope: [B, H, E]; ckv_pool:
    [P, ps, R]; kr_pool: [P, ps, E]; lens: [B] int32 — the slot's
    absolute decode position (visible keys are ``t <= lens``). Returns
    the latent context [B, H, R] float32 (``c_kv`` doubles as K and V,
    so the pages are staged once).
    """
    b, h, r = q_abs.shape
    e = q_rope.shape[-1]
    ps = ckv_pool.shape[1]
    npages = page_table.shape[1]
    t = npages * ps

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # page_table, lens
        grid=(b, npages),
        in_specs=[
            pl.BlockSpec((1, h, r), lambda bi, j, pt, ln: (bi, 0, 0)),
            pl.BlockSpec((1, h, e), lambda bi, j, pt, ln: (bi, 0, 0)),
            pl.BlockSpec((1, ps, r),
                         lambda bi, j, pt, ln: (pt[bi, j], 0, 0)),
            pl.BlockSpec((1, ps, e),
                         lambda bi, j, pt, ln: (pt[bi, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, r),
                               lambda bi, j, pt, ln: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, t), jnp.float32),
            pltpu.VMEM((t, r), ckv_pool.dtype),
        ],
    )
    return pl.pallas_call(
        functools.partial(_mla_kernel, ps=ps, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, r), jnp.float32),
        interpret=interpret,
    )(page_table, lens, q_abs, q_rope, ckv_pool, kr_pool)
