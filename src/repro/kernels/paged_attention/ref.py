"""Pure-lax paged-decode references (CPU oracle for the Pallas kernels).

These restate the legacy gather path — ``kv_cache.gather_pages``
followed by ``attention.decode_attention`` (plain) or the absorbed MLA
einsums — as self-contained functions on the pool/page-table layout, so
the exactness tier can pin kernel == ref == gather bitwise on CPU
without importing the model layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38   # matches repro.models.layers.attention.NEG_INF


def _gather(pool, page_table):
    """[P, ps, ...] x [B, NP] -> [B, NP*ps, ...] (pool[page_table])."""
    b, npages = page_table.shape
    ps = pool.shape[1]
    return pool[page_table].reshape((b, npages * ps) + pool.shape[2:])


def paged_decode_attention_ref(q, k_pool, v_pool, page_table, lens, *,
                               window: int = 0):
    """q: [B, Kv, G, D]; pools: [P, ps, Kv, D]; lens: [B] — valid cache
    entries per slot (including the token written this step). Returns
    [B, Kv, G, D] in q's dtype."""
    b, kv_heads, g, d = q.shape
    k = _gather(k_pool, page_table)                  # [B, T, Kv, D]
    v = _gather(v_pool, page_table)
    t = k.shape[1]
    s_ = jnp.einsum("bkgd,btkd->bkgt", q, k,
                    preferred_element_type=jnp.float32) * (d ** -0.5)
    cl = jnp.atleast_1d(jnp.asarray(lens))[:, None]  # [B, 1]
    idx = jnp.arange(t)[None, :]
    valid = idx < cl
    if window > 0:
        valid = valid & (idx >= cl - window)
    s_ = jnp.where(valid[:, None, None, :], s_, NEG_INF)
    m = s_.max(axis=-1, keepdims=True)
    p = jnp.exp(s_ - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgt,btkd->bkgd", p / jnp.maximum(l, 1e-30),
                     v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_mla_decode_ref(q_abs, q_rope, ckv_pool, kr_pool, page_table,
                         lens, *, scale: float):
    """q_abs: [B, H, R]; q_rope: [B, H, E]; ckv_pool: [P, ps, R];
    kr_pool: [P, ps, E]; lens: [B] — the slot's absolute decode position
    (keys at ``t <= lens`` are visible). Returns the latent context
    [B, H, R] float32."""
    dt = q_abs.dtype
    ckv = _gather(ckv_pool, page_table)              # [B, T, R]
    kr = _gather(kr_pool, page_table)                # [B, T, E]
    t = ckv.shape[1]
    s_ = (jnp.einsum("bhr,btr->bht", q_abs, ckv.astype(dt),
                     preferred_element_type=jnp.float32)
          + jnp.einsum("bhe,bte->bht", q_rope, kr.astype(dt),
                       preferred_element_type=jnp.float32))
    s_ = s_ * scale
    mask = jnp.arange(t)[None, None, :] <= lens[:, None, None]
    s_ = jnp.where(mask, s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    return jnp.einsum("bht,btr->bhr", p, ckv.astype(jnp.float32))
