"""[B,...]-layout entry points for the paged-decode kernels.

``dist`` (mesh-sharded serving) wraps the kernel in a ``shard_map``
over the dp axis so page reads stay shard-local: slots (q, page table,
lens, output) are slot-sharded; the pools are page-sharded when the
engine runs ``kv_sharding="dp"`` and replicated otherwise. One body
serves both layouts because every page a slot's page-table row names —
allocated pages AND its sink fill — lives on the slot's own shard
(``PagedKVCache`` places slot ``i`` on shard ``i // slots_per_shard``
and allocates only from that shard's free list), so global page ids
localize as ``page_table % local_pages``, which degenerates to the
identity when the pool is replicated. The HLO therefore contains no
all-gather of the page pool — the dissolution of the PR 5 open
question that ``gather_pages`` could not guarantee.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import (
    paged_decode_attention_kernel, paged_mla_decode_kernel)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _run_sharded(fn, dist, kv_sharded, qargs, pools, page_table, lens):
    """shard_map ``fn(*qargs, *pools, page_table, lens)`` over the dp
    axis; output is slot-sharded like ``qargs[0]``."""
    from jax.sharding import PartitionSpec as P
    from repro import compat

    dp = dist.dp_axes[0]

    def slot_spec(a):
        return P(*((dp,) + (None,) * (a.ndim - 1)))

    def pool_spec(a):
        return slot_spec(a) if kv_sharded else P()

    n_q = len(qargs)

    def body(*args):
        qs, ps = args[:n_q], args[n_q:-2]
        pt, ln = args[-2], args[-1]
        pt = pt % ps[0].shape[0]      # global -> shard-local page ids
        return fn(*qs, *ps, pt, ln)

    wrapped = compat.shard_map(
        body, mesh=dist.mesh,
        in_specs=(tuple(slot_spec(a) for a in qargs)
                  + tuple(pool_spec(a) for a in pools)
                  + (slot_spec(page_table), slot_spec(lens))),
        out_specs=slot_spec(qargs[0]), check_rep=False)
    return wrapped(*qargs, *pools, page_table, lens)


def paged_decode_attention(q, k_pool, v_pool, page_table, lens, *,
                           window: int = 0, dist=None,
                           kv_sharded: bool = False):
    """q: [B, 1, Hq, D]; pools: [P, ps, Kv, D]; page_table: [B, NP];
    lens: [B] — valid cache entries per slot including the token
    scattered this step. Returns [B, 1, Hq, D] (drop-in for
    ``decode_attention`` over gathered pages)."""
    b, s, hq, d = q.shape
    assert s == 1, "paged decode kernel is single-query"
    kv = k_pool.shape[2]
    qe = q.reshape(b, kv, hq // kv, d)
    pt = page_table.astype(jnp.int32)
    ln = jnp.broadcast_to(
        jnp.atleast_1d(jnp.asarray(lens)).astype(jnp.int32), (b,))

    def call(qe, kp, vp, pt, ln):
        return paged_decode_attention_kernel(
            qe, kp, vp, pt, ln, window=window, interpret=_interpret())

    if dist is None:
        out = call(qe, k_pool, v_pool, pt, ln)
    else:
        out = _run_sharded(call, dist, kv_sharded, (qe,),
                           (k_pool, v_pool), pt, ln)
    return out.reshape(b, 1, hq, d)


def paged_mla_decode(q_abs, q_rope, ckv_pool, kr_pool, page_table, lens,
                     *, scale: float, dist=None, kv_sharded: bool = False):
    """q_abs: [B, 1, H, R] (latent-absorbed query); q_rope: [B, 1, H, E];
    ckv_pool: [P, ps, R]; kr_pool: [P, ps, E]; lens: [B] — the slot's
    absolute decode position. Returns the latent context [B, 1, H, R]
    float32 (the caller applies ``w_uv``/``w_o``)."""
    b, s, h, r = q_abs.shape
    assert s == 1, "paged MLA decode kernel is single-query"
    qa, qr = q_abs[:, 0], q_rope[:, 0]
    pt = page_table.astype(jnp.int32)
    ln = jnp.broadcast_to(
        jnp.atleast_1d(jnp.asarray(lens)).astype(jnp.int32), (b,))

    def call(qa, qr, cp, kp, pt, ln):
        return paged_mla_decode_kernel(
            qa, qr, cp, kp, pt, ln, scale=scale, interpret=_interpret())

    if dist is None:
        ctx = call(qa, qr, ckv_pool, kr_pool, pt, ln)
    else:
        ctx = _run_sharded(call, dist, kv_sharded, (qa, qr),
                           (ckv_pool, kr_pool), pt, ln)
    return ctx[:, None]
