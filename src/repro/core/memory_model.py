"""Analytic memory models: MoE layer footprint + serving preemption cost.

:class:`MoEMemory` is the paper's footprint model of an MoE layer
(Eqs. 1–6). All quantities in *elements* by default (paper convention);
multiply by ``bytes_per`` for bytes. B is the token batch, M model dim,
H hidden dim, E experts, n pipeline partitions.

:class:`PreemptionCost` extends the same capacity-vs-bandwidth trade to
the serving engine's state cache: when capacity runs dry, a victim
request is preempted either by *recompute* (drop its cached state, pay
the re-prefill FLOPs again) or by *offload* (round-trip its bytes over
the host link — the serving analogue of strategies S1–S3's activation
offload). The selector mirrors the paper's Eq. 7–10 structure: compare
seconds of redundant compute against seconds of host-link copies, masked
by hardware capability (no host offload ⇒ recompute only).

The same model covers both cache geometries behind the ``StateCache``
protocol (``repro.serve.state_cache``). For a **paged** KV cache
``bytes_held`` grows linearly with ``tokens_cached`` (pages x page
bytes), so both sides of the trade scale with sequence length and the
offload/recompute choice is roughly length-independent. For a
**constant-state** cache (recurrent mixers: mamba / xLSTM) ``bytes_held``
is one fixed slot row regardless of how many tokens were absorbed into
it — recompute cost still grows with ``tokens_cached`` while offload
cost is flat, so past :func:`crossover_tokens` offload always wins.
That asymmetry is the quantitative reason recurrent models preempt so
cheaply: an O(1) snapshot buys back an O(len) re-prefill.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEMemory:
    b: int
    m: int
    h: int
    e: int
    n: int = 4
    bytes_per: float = 4.0
    optimizer_states: int = 4      # params + grads + adam m + adam v

    # -- Eq. 1: model states -------------------------------------------
    @property
    def m_ms(self) -> float:
        return self.optimizer_states * (self.e * self.m
                                        + 2 * self.h * self.m)

    # -- Eq. 2: activations (T_I, T_DI, T_DO, T_O are (B,M); T_M is (B,H))
    @property
    def m_act(self) -> float:
        return 4 * self.b * self.m + self.b * self.h

    # -- Eq. 3: temporary buffers (two adjacent gradient tensors live)
    @property
    def m_buf(self) -> float:
        return self.b * self.m + self.b * self.h

    # -- Eq. 4: with pipelining, peak temp = activations of the pipeline
    @property
    def m_buf_pipe(self) -> float:
        return self.m_act_pipe

    @property
    def m_act_pipe(self) -> float:
        return 4 * self.b * self.m + self.b * self.h

    # -- Eq. 5: savings from sharing partition buffers.
    # T_DI and T_DO shrink from m to 2m/n (double buffer), T_M to m/n.
    @property
    def delta_act(self) -> float:
        return self.b * (2 * self.m * (self.n - 2) / self.n
                         + self.h * (self.n - 1) / self.n)

    @property
    def delta_buf(self) -> float:
        return self.delta_act

    # -- Eq. 6: saving ratio -------------------------------------------
    @property
    def phi(self) -> float:
        return ((self.delta_act + self.delta_buf)
                / (self.m_ms + self.m_act_pipe + self.m_buf_pipe))

    # -- convenience ----------------------------------------------------
    def totals(self) -> dict:
        scale = self.bytes_per
        return {
            "model_states": self.m_ms * scale,
            "activations": self.m_act * scale,
            "temp_buffers": self.m_buf * scale,
            "act_reused": (self.m_act - self.delta_act) * scale,
            "buf_reused": (self.m_buf_pipe - self.delta_buf) * scale,
            "phi": self.phi,
        }


@dataclasses.dataclass(frozen=True)
class PreemptionCost:
    """Offload-vs-recompute decision for one preemption victim.

    * recompute: free the victim's KV pages now (cost ~0) and re-prefill
      its ``tokens_cached`` tokens at resume — pay the forward FLOPs once
      more, at ``mfu`` fraction of device peak;
    * offload: copy ``bytes_held`` of pages to host now and back at
      resume — pay ``2 * bytes / host_bw``, degraded by the memcpy
      interference factor ``eta`` (paper Fig. 3) and divided across
      ``link_shards`` concurrent swap streams.

    Both costs are *added latency for this request*; the engine picks the
    argmin per victim, gated by host-offload capability.

    Per-shard capacity (DP-sharded KV pools): with the pool split into
    ``dp`` independent per-device shards, pool-dry — and therefore
    preemption — fires per shard, so up to ``dp`` victims can be
    swapping over the one host link at once. ``link_shards`` models that
    contention: the effective per-victim link bandwidth is
    ``host_bw / link_shards``, which shifts the crossover toward
    recompute as the machine scales out. With replicated pools (one
    logical shard) it is 1 and the PR 3 model is recovered exactly.
    """
    tokens_cached: int
    bytes_held: int
    flops_per_token: float       # forward FLOPs per token (~2 x active P)
    flops: float                 # device peak FLOP/s
    host_bw: float               # host link B/s
    mfu: float = 0.5             # achieved fraction of peak at re-prefill
    eta: float = 0.95            # memcpy interference (Interference.eta)
    link_shards: int = 1         # KV shards contending for the host link

    @property
    def recompute_s(self) -> float:
        return self.tokens_cached * self.flops_per_token \
            / max(self.flops * self.mfu, 1.0)

    @property
    def offload_s(self) -> float:
        bw = self.host_bw * self.eta / max(self.link_shards, 1)
        return 2.0 * self.bytes_held / max(bw, 1.0)

    @property
    def choice(self) -> str:
        return "offload" if self.offload_s < self.recompute_s \
            else "recompute"


def crossover_tokens(bytes_held: float, flops_per_token: float,
                     flops: float, host_bw: float, *, mfu: float = 0.5,
                     eta: float = 0.95, link_shards: int = 1) -> float:
    """Cached-token count above which offloading ``bytes_held`` beats
    recomputing the prefill (``offload_s < recompute_s`` in
    :class:`PreemptionCost`, solved for ``tokens_cached``).

    For a constant-state cache ``bytes_held`` is the fixed per-slot state
    size, so this is a single number per model: every victim longer than
    it should offload. For a paged cache ``bytes_held`` itself grows with
    the sequence, so the comparison must be re-evaluated per victim —
    which is exactly what the engine does.
    """
    bw = host_bw * eta / max(link_shards, 1)
    seconds_per_token = flops_per_token / max(flops * mfu, 1.0)
    return (2.0 * bytes_held / max(bw, 1.0)) / max(seconds_per_token,
                                                   1e-30)
