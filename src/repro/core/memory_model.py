"""Analytic memory-footprint model of an MoE layer (paper Eqs. 1–6).

All quantities in *elements* by default (paper convention); multiply by
``bytes_per`` for bytes. B is the token batch, M model dim, H hidden dim,
E experts, n pipeline partitions.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEMemory:
    b: int
    m: int
    h: int
    e: int
    n: int = 4
    bytes_per: float = 4.0
    optimizer_states: int = 4      # params + grads + adam m + adam v

    # -- Eq. 1: model states -------------------------------------------
    @property
    def m_ms(self) -> float:
        return self.optimizer_states * (self.e * self.m
                                        + 2 * self.h * self.m)

    # -- Eq. 2: activations (T_I, T_DI, T_DO, T_O are (B,M); T_M is (B,H))
    @property
    def m_act(self) -> float:
        return 4 * self.b * self.m + self.b * self.h

    # -- Eq. 3: temporary buffers (two adjacent gradient tensors live)
    @property
    def m_buf(self) -> float:
        return self.b * self.m + self.b * self.h

    # -- Eq. 4: with pipelining, peak temp = activations of the pipeline
    @property
    def m_buf_pipe(self) -> float:
        return self.m_act_pipe

    @property
    def m_act_pipe(self) -> float:
        return 4 * self.b * self.m + self.b * self.h

    # -- Eq. 5: savings from sharing partition buffers.
    # T_DI and T_DO shrink from m to 2m/n (double buffer), T_M to m/n.
    @property
    def delta_act(self) -> float:
        return self.b * (2 * self.m * (self.n - 2) / self.n
                         + self.h * (self.n - 1) / self.n)

    @property
    def delta_buf(self) -> float:
        return self.delta_act

    # -- Eq. 6: saving ratio -------------------------------------------
    @property
    def phi(self) -> float:
        return ((self.delta_act + self.delta_buf)
                / (self.m_ms + self.m_act_pipe + self.m_buf_pipe))

    # -- convenience ----------------------------------------------------
    def totals(self) -> dict:
        scale = self.bytes_per
        return {
            "model_states": self.m_ms * scale,
            "activations": self.m_act * scale,
            "temp_buffers": self.m_buf * scale,
            "act_reused": (self.m_act - self.delta_act) * scale,
            "buf_reused": (self.m_buf_pipe - self.delta_buf) * scale,
            "phi": self.phi,
        }
