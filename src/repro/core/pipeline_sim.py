"""Discrete-event simulator of the micro-batch pipeline (Fig. 4b/7).

Used as the default ``measure_fn`` for Algorithm 1 when no hardware is
available: chunks flow S_i -> C_i -> R_i; the collective "stream" (ICI)
serializes all S/R ops, the compute stream serializes all C ops, the host
stream serializes offload copies. Interference slows streams per Fig. 3.
Per-op issue overhead reproduces the fine-granularity penalty (GPU
under-utilization in the paper; smaller-than-MXU tiles on TPU).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core.perf_model import MoEWorkload
from repro.core.types import HardwareSpec, Strategy


def simulate(w: MoEWorkload, hw: HardwareSpec, n: int,
             strategy: Strategy = Strategy.NONE,
             include_backward: bool = True) -> float:
    """Makespan (seconds) of the pipelined MoE layer with n partitions."""
    if n < 1:
        raise ValueError(n)
    mu = hw.mu(strategy)
    eta = hw.eta(strategy)
    sigma = hw.interference.sigma
    ov = hw.launch_overhead_s

    # efficiency loss for tiny per-chunk GEMMs: tokens/chunk below ~256
    # rows underfill the 128x128 MXU pipeline
    chunk_tokens = max(1, w.b // n)
    util = min(1.0, chunk_tokens / 256.0)

    gemms = 3 if w.gated else 2
    t_c = gemms * (w.v_comp / n) / (sigma * hw.flops * util) + ov
    t_s = (w.v_comm / n) / (mu * hw.ici_bw) + ov
    t_r = t_s
    t_h = ((w.v_mem / n) * (1 + w.h / w.m if strategy in
                            (Strategy.S1, Strategy.S2) else 1)
           / (eta * hw.host_bw) + ov) if strategy.needs_host else 0.0

    def phase(t_send, t_comp, t_recv, start_time):
        """Readiness-driven schedule of S_i -> C_i -> R_i over a shared
        collective stream and a compute stream (paper Fig. 7a: S/R
        alternate on one stream as they become ready, FCFS)."""
        comm_free = start_time
        comp_free = start_time
        host_free = start_time
        s_done = {}
        c_done = {}
        next_s = 0
        pending_r = []
        done_r = 0
        while done_r < n:
            # candidate comm jobs: next S (always ready), ready R's
            cands = []
            if next_s < n:
                cands.append(("S", next_s, comm_free))
            for i in sorted(pending_r):
                cands.append(("R", i, max(comm_free, c_done[i])))
            kind, i, start = min(cands, key=lambda x: (x[2], x[0] == "S"))
            if kind == "S":
                s_done[i] = start + t_send
                comm_free = s_done[i]
                c_start = max(comp_free, s_done[i])
                c_done[i] = c_start + t_comp
                comp_free = c_done[i]
                if t_h:
                    host_free = max(host_free, s_done[i]) + t_h
                pending_r.append(i)
                next_s += 1
            else:
                comm_free = start + t_recv
                pending_r.remove(i)
                done_r += 1
        return max(comm_free, comp_free, host_free)

    makespan = phase(t_s, t_c, t_r, 0.0)
    if include_backward:
        extra_comm = 1 if strategy in (Strategy.S2, Strategy.S4) else 0
        extra_comp = 1 if strategy in (Strategy.S3, Strategy.S4) else 0
        bt_c = (gemms + extra_comp) * (w.v_comp / n) / (
            sigma * hw.flops * util) + ov
        bt_s = ((1 + extra_comm) * (w.v_comm / n) / (mu * hw.ici_bw) + ov)
        makespan = phase(bt_s, bt_c, bt_s, makespan)
        # BEYOND-PAPER term (n-independent with the explicit ZeRO-3
        # expert-weight gather): one all-gather fwd + one reduce-scatter
        # of the fp32 weight grads bwd. Without the explicit gather this
        # cost was PER CHUNK (shard_map AD psums at each cotangent site)
        # and flipped the optimal n — see EXPERIMENTS §Perf.
        makespan += 2 * w.weight_psum_bytes / (mu * hw.ici_bw)
    return makespan


def sweep_partitions(w: MoEWorkload, hw: HardwareSpec,
                     candidates=(1, 2, 4, 8, 16, 32),
                     strategy: Strategy = Strategy.NONE
                     ) -> Dict[int, float]:
    return {n: simulate(w, hw, n, strategy) for n in candidates
            if w.b // n >= 1}
