"""MPipeMoE execution engine: micro-batch pipelined expert parallelism.

This is the paper's core (§III-B..E), TPU-adapted:

* The local token batch is split into ``n`` micro-batches **along the
  token dimension** (paper Fig. 5b — each chunk's All-to-All remains a
  true all-to-all over the EP axis, never point-to-point).
* Chunks are processed by an *unrolled* Python loop: chunk bodies are
  data-independent, so XLA's latency-hiding scheduler overlaps chunk
  i+1's dispatch collective with chunk i's expert GEMMs (the paper's
  multi-CUDA-stream pipeline, expressed as async HLO collectives).
  ``pipeline_unroll=False`` switches to ``lax.scan`` (serial; useful to
  compare compile size / memory).
* Memory reuse: each chunk is wrapped in the strategy's remat/offload
  policy (``core.strategies``). Residuals ``t_di``/``t_m`` are tagged
  here; dropping them re-runs the dispatch A2A (re-communication) or
  GEMM1 (recompute) in backward — S1–S4 of Table II. With reuse enabled
  the per-chunk buffers are dead after the chunk's combine, so XLA's
  buffer assignment shares one allocation across chunks: the paper's
  m -> m/n "memory bubbles" compression.

Two distributed layouts:
* ``sharded``  (train/prefill): tokens sharded over dp x ep; full
  dispatch-A2A -> grouped FFN -> combine-A2A pipeline.
* ``replicated`` (decode): tokens replicated over the EP axis (batches at
  decode are far smaller than the mesh); each device computes only its
  local experts and the combine is a psum — no A2A on the critical path.

Both layouts are live at inference time: the serving engine
(``repro.serve``, see ``docs/distributed.md``) drives chunked prefill
through the ``sharded`` path and continuous-batch decode through
``replicated``, selected purely by ``mode`` — there is no separate
serving fork of this module.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig
from repro.core.strategies import Strategy, wrap_chunk
from repro.moe import dispatch as D
from repro.moe import experts as E
from repro.moe import router as R

__all__ = ["capacity_for", "gather_expert_weights", "pipelined_moe"]


def capacity_for(tokens: int, top_k: int, cf: float, num_experts: int,
                 multiple: int = 8) -> int:
    cap = max(1, math.ceil(tokens * top_k * cf / num_experts))
    return -(-cap // multiple) * multiple


def _resolve_partitions(cfg: ArchConfig, t_local: int, mode: str) -> int:
    if mode == "decode" or not cfg.moe.pipeline:
        return 1
    n = cfg.moe.num_partitions or 4          # 0 = adaptive; default 4
    n = max(1, min(n, t_local))
    while t_local % n:
        n -= 1
    return n


def _chunk_fn(params, chunk, *, cfg: ArchConfig, ep_axis: Optional[str],
              ep_size: int, cap: int, use_kernel: bool):
    """route -> dispatch -> A2A -> expert FFN -> A2A -> combine."""
    m = cfg.moe
    e_total = m.num_experts
    e_local = e_total // ep_size
    t = chunk.shape[0]

    probs, eidx, aux = R.route(params["router"], chunk, cfg)
    dest, valid = D.dispatch_plan(eidx, e_total, cap)
    buf = D.dispatch(chunk, dest, e_total, cap)          # [E, cap, M]

    if ep_size > 1:
        buf = buf.reshape(ep_size, e_local, cap, -1)
        buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0)
        buf = buf.reshape(ep_size * e_local, cap, -1)    # src-major
    t_di = checkpoint_name(buf, "t_di")                  # paper's T_DI
    if ep_size > 1:
        # [ep(src), e_local, cap, M] -> [e_local, ep*cap, M]
        ein = t_di.reshape(ep_size, e_local, cap, -1).transpose(1, 0, 2, 3)
        ein = ein.reshape(e_local, ep_size * cap, -1)
    else:
        ein = t_di

    eout = E.apply_grouped(params["experts"], ein, cfg,
                           use_kernel=use_kernel)        # paper's T_DO

    if ep_size > 1:
        eout = eout.reshape(e_local, ep_size, cap, -1).transpose(1, 0, 2, 3)
        eout = jax.lax.all_to_all(eout, ep_axis, split_axis=0,
                                  concat_axis=0)
        eout = eout.reshape(e_total, cap, -1)
    out = D.combine(eout, dest, probs, t)

    if m.num_shared_experts:
        # always-on shared experts: dense, independent of the A2As —
        # XLA overlaps this compute with the in-flight collectives.
        out = out + E.apply_shared(params["shared"], chunk, cfg)
    return out, aux


def _replicated_decode(params, tokens, *, cfg: ArchConfig,
                       ep_axis: Optional[str], ep_size: int,
                       use_kernel: bool):
    """Decode path: tokens replicated over EP; combine via psum."""
    m = cfg.moe
    e_total = m.num_experts
    e_local = e_total // ep_size
    t = tokens.shape[0]
    cap = capacity_for(t, m.top_k, max(m.capacity_factor, 2.0), e_total)

    probs, eidx, aux = R.route(params["router"], tokens, cfg)
    dest, valid = D.dispatch_plan(eidx, e_total, cap)
    buf = D.dispatch(tokens, dest, e_total, cap)         # [E, cap, M]
    if ep_size > 1:
        my = jax.lax.axis_index(ep_axis)
        local = jax.lax.dynamic_slice_in_dim(buf, my * e_local, e_local, 0)
    else:
        local = buf
    eout = E.apply_grouped(params["experts"], local, cfg,
                           use_kernel=use_kernel)
    if ep_size > 1:
        full = jnp.zeros_like(buf)
        full = jax.lax.dynamic_update_slice_in_dim(full, eout,
                                                   my * e_local, 0)
        full = jax.lax.psum(full, ep_axis)
    else:
        full = eout
    out = D.combine(full, dest, probs, t)
    if m.num_shared_experts:
        out = out + E.apply_shared(params["shared"], tokens, cfg)
    return out, aux


def gather_expert_weights(params, dp_axes):
    """Explicit ZeRO-3 gather: expert weights arrive dp-sharded on their
    output dim; one all_gather here (outside the chunk loop) means the
    transpose is ONE reduce-scatter of the accumulated weight gradient —
    instead of one full fp32 psum per pipeline chunk (which dominated the
    collective term at n=16, see EXPERIMENTS §Perf iteration J-ZeRO3)."""
    if not dp_axes:
        return params
    out = dict(params)
    out["experts"] = {
        k: jax.lax.all_gather(v, dp_axes, axis=v.ndim - 1, tiled=True)
        for k, v in params["experts"].items()}
    return out


def pipelined_moe(params, tokens, *, cfg: ArchConfig,
                  ep_axis: Optional[str] = None, ep_size: int = 1,
                  mode: str = "train", use_kernel: bool = False,
                  dp_axes: Tuple[str, ...] = ()
                  ) -> Tuple[jax.Array, dict]:
    """tokens: [T_local, M] -> ([T_local, M], aux losses)."""
    m = cfg.moe
    params = gather_expert_weights(params, dp_axes)
    if mode == "decode" and ep_size > 1:
        return _replicated_decode(params, tokens, cfg=cfg, ep_axis=ep_axis,
                                  ep_size=ep_size, use_kernel=use_kernel)

    t_local = tokens.shape[0]
    n = _resolve_partitions(cfg, t_local, mode)
    chunk_t = t_local // n
    cap = capacity_for(chunk_t, m.top_k, m.capacity_factor, m.num_experts)
    strategy = Strategy(m.memory_reuse_strategy) \
        if m.memory_reuse_strategy != "adaptive" else Strategy.NONE

    def chunk_fn(p, c):
        return _chunk_fn(p, c, cfg=cfg, ep_axis=ep_axis, ep_size=ep_size,
                         cap=cap, use_kernel=use_kernel)

    if mode == "train":
        chunk_fn = wrap_chunk(chunk_fn, strategy)

    if m.pipeline_unroll or n == 1:
        outs, auxes = [], []
        for i in range(n):
            o, a = chunk_fn(params, tokens[i * chunk_t:(i + 1) * chunk_t])
            outs.append(o)
            auxes.append(a)
        out = jnp.concatenate(outs, axis=0) if n > 1 else outs[0]
        aux = jax.tree_util.tree_map(
            lambda *xs: sum(xs) / float(n), *auxes)
    else:
        chunks = tokens.reshape(n, chunk_t, -1)
        _, (outs, auxes) = jax.lax.scan(
            lambda _, c: (0, chunk_fn(params, c)), 0, chunks)
        out = outs.reshape(t_local, -1)
        aux = jax.tree_util.tree_map(lambda x: x.mean(), auxes)
    return out, aux
