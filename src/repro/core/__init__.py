"""MPipeMoE core: adaptive pipelined expert parallelism + memory reuse.

Layer map (see ``docs/architecture.md``): analytic models
(``memory_model`` Eqs. 1–6 + serving :class:`PreemptionCost`,
``perf_model`` Eqs. 7–10, ``pipeline_sim``), the runtime knob resolvers
(``granularity`` Algorithm 1, ``selector`` — one-shot :func:`resolve`
and the persistent :class:`Resolver`), the memory-reuse strategy
policies (``strategies`` S1–S4 as remat/offload policies), and the
pipelined MoE layer body itself (``pipeline_moe``).
"""
from repro.core.granularity import GranularitySearcher
from repro.core.memory_model import (MoEMemory, PreemptionCost,
                                     crossover_tokens)
from repro.core.perf_model import (MoEWorkload, all_costs, cost,
                                   select_strategy, stream_times)
from repro.core.pipeline_moe import capacity_for, pipelined_moe
from repro.core.pipeline_sim import simulate, sweep_partitions
from repro.core.selector import (Resolver, make_searcher, moe_workload,
                                 resolve, resolve_strategy)
from repro.core.strategies import (host_offload_supported, remat_policy,
                                   wrap_chunk)
from repro.core.types import (CPU_HOST, GPU_A100, HW_SPECS, Q_TABLE,
                              TPU_V5E, HardwareSpec, Interference, Strategy,
                              resolve_hw)

__all__ = [
    "CPU_HOST", "GPU_A100", "GranularitySearcher", "HW_SPECS", "MoEMemory",
    "MoEWorkload", "PreemptionCost", "Q_TABLE", "TPU_V5E", "HardwareSpec",
    "Interference", "Resolver", "Strategy", "all_costs", "capacity_for",
    "cost", "crossover_tokens",
    "host_offload_supported", "make_searcher", "moe_workload",
    "pipelined_moe", "remat_policy", "resolve", "resolve_hw",
    "resolve_strategy", "select_strategy", "simulate", "stream_times",
    "sweep_partitions", "wrap_chunk",
]
