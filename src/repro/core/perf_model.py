"""Performance model for memory-reusing strategies (paper Eq. 10).

The end-to-end time of the pipelined MoE step is the max over three
"streams" — compute (expert GEMMs), collective (All-to-All), host copy
(offload traffic) — each being (amount of work) / (effective speed), where
effective speed carries the interference slowdown factors (mu, sigma, eta;
paper Fig. 3). Strategy choice = argmin cost, exactly as in §III-E.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.types import Q_TABLE, HardwareSpec, Strategy


@dataclasses.dataclass(frozen=True)
class MoEWorkload:
    """Per-device MoE layer workload (one direction of the layer).

    b: local tokens routed per step; m/h: model/hidden dims; k: top-k;
    ep: expert-parallel group size; dtype_bytes: activation bytes;
    e_local: experts resident per device; dp: data-parallel width (the
    expert-weight gradient psum crosses it once per *pipeline chunk* —
    a term the paper's model omits; measured on the 256-chip dry-run it
    flipped the optimal n for jamba from 16 to 4, see EXPERIMENTS §Perf).
    """
    b: int
    m: int
    h: int
    k: int = 1
    ep: int = 16
    dtype_bytes: int = 2
    gated: bool = False
    e_local: int = 1
    dp: int = 16

    @property
    def weight_psum_bytes(self) -> float:
        """fp32 expert-weight grads psum'd over dp per chunk (backward)."""
        if self.dp <= 1:
            return 0.0
        gemms = 3 if self.gated else 2
        return gemms * self.e_local * self.m * self.h * 4.0

    @property
    def v_comp(self) -> float:
        """FLOPs of ONE expert GEMM pass over the dispatched tokens
        (paper's v0_comp = b*H*M, up to the factor-2 MAC convention)."""
        gemms = 3 if self.gated else 2          # up(+gate)+down counted by q1
        del gemms  # q1 in Q_TABLE already counts GEMMs; one unit here:
        return 2.0 * self.b * self.k * self.m * self.h

    @property
    def v_comm(self) -> float:
        """Bytes one All-to-All moves off-device: b*k tokens of M dims,
        (ep-1)/ep of which cross links."""
        return (self.b * self.k * self.m * self.dtype_bytes
                * (self.ep - 1) / self.ep)

    @property
    def v_mem(self) -> float:
        """Bytes of one T_DI host copy (paper's v0_mem = b*M)."""
        return self.b * self.k * self.m * self.dtype_bytes


def _q_scaled(strategy: Strategy, w: MoEWorkload):
    """Rescale Table II's q3 (which assumes H=4M) to the real H/M ratio,
    and q1 for gated experts (3 GEMMs instead of 2 in forward)."""
    (q1f, q2f, q3f), (q1b, q2b, q3b) = Q_TABLE[strategy]
    ratio = w.h / w.m / 4.0
    # q3 decomposes as [T_DI copies] + 4*[T_M copies]
    t_m_f = {Strategy.S1: 4, Strategy.S2: 4}.get(strategy, 0)
    t_di_f = q3f - t_m_f
    q3f = t_di_f + t_m_f * ratio
    t_m_b = t_m_f
    t_di_b = q3b - t_m_b
    q3b = t_di_b + t_m_b * ratio
    if w.gated:
        q1f, q1b = q1f * 1.5, q1b * 1.5
    return (q1f, q2f, q3f), (q1b, q2b, q3b)


def stream_times(strategy: Strategy, w: MoEWorkload, hw: HardwareSpec,
                 n_partitions: int = 1) -> Dict[str, float]:
    """Per-stream seconds for forward+backward of one MoE layer."""
    (q1f, q2f, q3f), (q1b, q2b, q3b) = _q_scaled(strategy, w)
    mu = hw.mu(strategy)
    eta = hw.eta(strategy)
    sigma = hw.interference.sigma
    comp = (q1f + q1b) * w.v_comp / (sigma * hw.flops)
    comm = (q2f + q2b) * w.v_comm / (mu * hw.ici_bw)
    mem = (q3f + q3b) * w.v_mem / (eta * hw.host_bw)
    # kernel-launch / collective-issue overhead grows with granularity
    ops_per_chunk = (q1f + q2f + q3f + q1b + q2b + q3b)
    overhead = n_partitions * ops_per_chunk * hw.launch_overhead_s
    return {"comp": comp, "comm": comm, "mem": mem, "overhead": overhead}


def cost(strategy: Strategy, w: MoEWorkload, hw: HardwareSpec,
         n_partitions: int = 1) -> float:
    """Eq. 10: pipeline time = slowest stream (+ issue overhead)."""
    t = stream_times(strategy, w, hw, n_partitions)
    return max(t["comp"], t["comm"], t["mem"]) + t["overhead"]


def select_strategy(w: MoEWorkload, hw: HardwareSpec,
                    n_partitions: int = 1,
                    allow: Optional[list] = None) -> Strategy:
    """Adaptive selection (§III-E): cheapest of the four memory-reusing
    strategies (reuse is MPipeMoE's point — NONE is the PipeMoE ablation,
    selectable explicitly), host-capacity aware; ties broken toward lower
    memory footprint."""
    cands = list(allow) if allow else [Strategy.S1, Strategy.S2,
                                       Strategy.S3, Strategy.S4]
    if not hw.has_host_offload:
        cands = [s for s in cands if not s.needs_host]
    if not cands:
        cands = [Strategy.S4]
    order = {Strategy.S4: 0, Strategy.S2: 1, Strategy.S3: 2,
             Strategy.S1: 3, Strategy.NONE: 4}
    best = min(cands, key=lambda s: (cost(s, w, hw, n_partitions),
                                     order[s]))
    return best


def all_costs(w: MoEWorkload, hw: HardwareSpec,
              n_partitions: int = 1) -> Dict[str, float]:
    return {s.value: cost(s, w, hw, n_partitions) for s in Strategy}
