"""Core types: memory-reuse strategies (Table II) and hardware specs."""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Optional, Tuple


class Strategy(enum.Enum):
    """Memory reusing strategies (paper Table II).

    Encodes how the overwritten ``T_DI`` (dispatched input) and ``T_M``
    (expert hidden) tensors are restored in the backward pass.
    """
    NONE = "none"   # keep activations on device (no reuse)
    S1 = "s1"       # T_DI: offload      T_M: offload
    S2 = "s2"       # T_DI: re-comm      T_M: offload
    S3 = "s3"       # T_DI: offload      T_M: recompute
    S4 = "s4"       # T_DI: re-comm      T_M: recompute

    @property
    def offloads(self) -> Tuple[str, ...]:
        return {"none": (), "s1": ("t_di", "t_m"), "s2": ("t_m",),
                "s3": ("t_di",), "s4": ()}[self.value]

    @property
    def saves(self) -> Tuple[str, ...]:
        return {"none": ("t_di", "t_m"), "s1": (), "s2": (),
                "s3": (), "s4": ()}[self.value]

    @property
    def needs_host(self) -> bool:
        return bool(self.offloads)


# Q-vectors from Table II: units of (v0_comp, v0_comm, v0_mem) per
# (forward, backward). q3 counts T_M copies as 4x (H = 4M convention);
# the perf model rescales for the actual H/M ratio.
Q_TABLE: Dict[Strategy, Tuple[Tuple[int, int, int], Tuple[int, int, int]]] = {
    Strategy.NONE: ((2, 2, 0), (4, 2, 0)),
    Strategy.S1:   ((2, 2, 5), (4, 2, 5)),
    Strategy.S2:   ((2, 2, 4), (4, 3, 4)),
    Strategy.S3:   ((2, 2, 1), (5, 2, 1)),
    Strategy.S4:   ((2, 2, 0), (5, 3, 0)),
}


@dataclasses.dataclass(frozen=True)
class Interference:
    """Slowdown factors (paper Fig. 3). mu: comm slowdown, eta: memcpy
    slowdown, sigma: compute slowdown (~1 on TPU: DMA-driven collectives)."""
    mu_comp: float = 0.85        # comm speed while compute runs
    mu_all: float = 0.70         # comm speed with compute + memcpy
    eta_all: float = 0.60        # memcpy speed with comm + compute
    eta_comp: float = 0.95       # memcpy speed with compute only
    sigma: float = 1.0


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """TPU v5e defaults (task brief constants)."""
    name: str = "tpu_v5e"
    flops: float = 197e12             # bf16 peak FLOP/s per chip
    hbm_bw: float = 819e9             # B/s
    ici_bw: float = 50e9              # B/s per link
    host_bw: float = 32e9             # PCIe-ish host link B/s (offload)
    hbm_bytes: float = 16e9
    has_host_offload: bool = True
    launch_overhead_s: float = 3e-6   # per fused op / collective issue
    interference: Interference = dataclasses.field(default=Interference())

    def mu(self, strategy: Strategy) -> float:
        i = self.interference
        return i.mu_all if strategy.needs_host else i.mu_comp

    def eta(self, strategy: Strategy) -> float:
        i = self.interference
        # S1/S2 copy while comm is also active -> eta_all
        return i.eta_all if strategy in (Strategy.S1, Strategy.S2) \
            else i.eta_comp


TPU_V5E = HardwareSpec()

# Rough public-datasheet numbers — the perf model only needs ratios of
# compute : interconnect : host bandwidth to rank (n, strategy) choices.
GPU_A100 = HardwareSpec(name="gpu-a100", flops=312e12, hbm_bw=2039e9,
                        ici_bw=300e9, host_bw=32e9, hbm_bytes=80e9,
                        has_host_offload=True)
CPU_HOST = HardwareSpec(name="cpu-host", flops=1e12, hbm_bw=50e9,
                        ici_bw=10e9, host_bw=10e9, hbm_bytes=16e9,
                        has_host_offload=False)

HW_SPECS: Dict[str, HardwareSpec] = {
    "tpu-v5e": TPU_V5E,
    "gpu-a100": GPU_A100,
    "cpu-host": CPU_HOST,
}


def resolve_hw(name: str = "auto") -> HardwareSpec:
    """Named :class:`HardwareSpec`, or ``"auto"`` to detect from the
    attached jax backend (tpu -> tpu-v5e, gpu -> gpu-a100, else cpu)."""
    if name != "auto":
        try:
            return HW_SPECS[name]
        except KeyError:
            raise KeyError(f"unknown hw {name!r}; one of "
                           f"{sorted(HW_SPECS)} or 'auto'") from None
    import jax  # lazy: keep this module importable without a backend

    platform = jax.devices()[0].platform
    return HW_SPECS.get({"tpu": "tpu-v5e", "gpu": "gpu-a100"}
                        .get(platform, "cpu-host"), CPU_HOST)
