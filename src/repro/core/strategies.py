"""Memory-reuse strategies S1–S4 as JAX remat/offload policies.

The per-chunk MoE function tags its residuals with
``checkpoint_name(x, "t_di")`` / ``"t_m"``. Wrapping the chunk in
``jax.checkpoint`` with the policies below yields the paper's exact
restore semantics:

* saved  -> resident in HBM (no reuse for that tensor)
* offloaded -> copied to ``pinned_host`` in forward, fetched in backward
* dropped -> rematerialized: ``t_di`` by re-running the dispatch
  All-to-All (re-communication), ``t_m`` by re-running GEMM1 (recompute)
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax

from repro.core.types import Strategy

NAMES = ("t_di", "t_m")


def host_offload_supported() -> bool:
    try:
        dev = jax.devices()[0]
        kinds = getattr(dev, "memory_kinds", None)
        if callable(kinds):
            kinds = kinds()
        return kinds is not None and "pinned_host" in tuple(kinds)
    except Exception:
        return False


@functools.lru_cache(maxsize=None)
def remat_policy(strategy: Strategy, allow_offload: Optional[bool] = None):
    """Return a jax.checkpoint policy, or None for Strategy.NONE-without-
    wrapper semantics handled by the caller."""
    if allow_offload is None:
        allow_offload = host_offload_supported()
    saves = strategy.saves
    offloads = strategy.offloads
    if offloads and not allow_offload:
        # capacity-aware degradation (§III-E: hardware capacities are an
        # input of the selector): offloaded tensors become device-saved.
        saves = tuple(sorted(set(saves) | set(offloads)))
        offloads = ()
    if offloads:
        return jax.checkpoint_policies.save_and_offload_only_these_names(
            names_which_can_be_saved=list(saves),
            names_which_can_be_offloaded=list(offloads),
            offload_src="device", offload_dst="pinned_host")
    return jax.checkpoint_policies.save_only_these_names(*saves)


def wrap_chunk(fn: Callable, strategy: Strategy,
               allow_offload: Optional[bool] = None) -> Callable:
    """Apply the strategy's remat policy to a per-chunk function."""
    if strategy == Strategy.NONE:
        # no reuse: keep all residuals (no checkpoint wrapper)
        return fn
    return jax.checkpoint(fn, policy=remat_policy(strategy, allow_offload),
                          prevent_cse=False)
