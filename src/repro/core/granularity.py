"""Adaptive pipeline-granularity configuration (paper Algorithm 1).

The searcher maintains a set S of disjoint batch-size ranges R_n, each
mapped one-to-one to an optimal partition count n (monotonicity
hypothesis: optimal n is non-decreasing in B), plus a hash-table cache in
front. ``find``/``insert`` are O(log |S|) (bisect over sorted ranges — the
paper's binary search tree).

``measure_fn(B, n) -> seconds`` is injected: wall-clock timing of a few
compiled steps on real hardware, the analytic pipeline simulator
(``core.pipeline_sim``) otherwise.
"""
from __future__ import annotations

import bisect
from typing import Callable, Dict, List, Optional, Sequence, Tuple


class GranularitySearcher:
    def __init__(self, measure_fn: Callable[[int, int], float],
                 candidates: Sequence[int] = (1, 2, 4, 8, 16, 32)):
        self.measure_fn = measure_fn
        self.candidates = tuple(sorted(candidates))
        # sorted disjoint ranges: list of [lo, hi, n]
        self._ranges: List[List[int]] = []
        self._keys: List[int] = []       # lo of each range, kept sorted
        self._by_n: Dict[int, List[int]] = {}
        self._cache: Dict[int, int] = {}
        self.search_calls = 0            # instrumentation (tests/benches)

    # -- Algorithm 1, lines 6 / find(S, B) ------------------------------
    def _find(self, b: int) -> Tuple[Optional[List[int]], int]:
        i = bisect.bisect_right(self._keys, b) - 1
        if i >= 0 and self._ranges[i][0] <= b <= self._ranges[i][1]:
            return self._ranges[i], self._ranges[i][2]
        return None, -1

    def _find_by_n(self, n: int) -> Optional[List[int]]:
        return self._by_n.get(n)

    # -- Algorithm 1, line 8 / searchBestGran(B) ------------------------
    def _search_best(self, b: int) -> int:
        self.search_calls += 1
        feas = [n for n in self.candidates if b // n >= 1]
        costs = {n: self.measure_fn(b, n) for n in feas}
        return min(costs, key=costs.get)

    # -- Algorithm 1 main ------------------------------------------------
    def best_n(self, b: int) -> int:
        if b in self._cache:                       # lines 3-5
            return self._cache[b]
        rng, n = self._find(b)                     # line 6
        if n == -1:                                # lines 7-16
            n = self._search_best(b)
            rng = self._find_by_n(n)
            if rng is None:                        # lines 10-12
                self._insert([b, b, n])
            else:                                  # lines 13-14: merge
                rng[0] = min(rng[0], b)
                rng[1] = max(rng[1], b)
                self._repair(rng)
        self._cache[b] = n                         # line 17
        return n

    # -- internals -------------------------------------------------------
    def _insert(self, rng: List[int]) -> None:
        i = bisect.bisect_left(self._keys, rng[0])
        self._ranges.insert(i, rng)
        self._repair(rng)

    def _repair(self, rng: List[int]) -> None:
        """Keep ranges disjoint under the monotonicity hypothesis: a
        merged range may swallow neighbours measured with other n; shrink
        neighbours (their n stays valid at their remaining extremes)."""
        self._ranges.sort(key=lambda r: r[0])
        out: List[List[int]] = []
        for r in self._ranges:
            if out and r[0] <= out[-1][1]:
                if r[2] == out[-1][2]:
                    out[-1][1] = max(out[-1][1], r[1])
                elif r is rng:                     # new data wins overlap
                    out[-1][1] = r[0] - 1
                    if out[-1][0] > out[-1][1]:
                        out.pop()
                    out.append(r)
                else:
                    r[0] = out[-1][1] + 1
                    if r[0] <= r[1]:
                        out.append(r)
            else:
                out.append(r)
        self._ranges = out
        # reindex: _find bisects _keys; _find_by_n is a dict hit. Both
        # rebuilt only here (insert/merge path — tied to a real search),
        # never on the hot lookup path.
        self._keys = [r[0] for r in out]
        self._by_n = {r[2]: r for r in out}

    def reset(self) -> None:
        """Drop learned ranges + cache: measurements are presumed stale
        (periodic retune under workload drift, §III-C online setting)."""
        self._ranges = []
        self._keys = []
        self._by_n = {}
        self._cache = {}

    @property
    def ranges(self) -> Tuple[Tuple[int, int, int], ...]:
        return tuple((r[0], r[1], r[2]) for r in self._ranges)
