"""Adaptive runtime configuration (paper §III-C + §III-E).

Resolves the two runtime knobs of MPipeMoE *before* jit (they are static
shape/structure choices):

* pipeline granularity ``n``  — Algorithm 1 over the injected measure
  function (wall-clock on hardware; the pipeline simulator otherwise);
* memory-reuse strategy       — Eq. 10 argmin, masked by hardware
  capacities (no host offload => S1–S3 unavailable).

Returns an updated ArchConfig; the training loop re-jits when the
resolved (n, strategy) changes (compilation cache keyed by them).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.granularity import GranularitySearcher
from repro.core.perf_model import MoEWorkload, select_strategy
from repro.core.pipeline_sim import simulate
from repro.core.strategies import host_offload_supported
from repro.core.types import HardwareSpec, Strategy


def moe_workload(cfg: ArchConfig, local_tokens: int, ep_size: int,
                 dtype_bytes: int = 2, dp: int = 16) -> MoEWorkload:
    m = cfg.moe
    return MoEWorkload(b=local_tokens, m=cfg.d_model, h=m.d_expert,
                       k=m.top_k, ep=ep_size, dtype_bytes=dtype_bytes,
                       gated=cfg.gated_ffn,
                       e_local=max(1, m.num_experts // ep_size), dp=dp)


def make_searcher(cfg: ArchConfig, ep_size: int, hw: HardwareSpec,
                  measure_fn: Optional[Callable] = None,
                  strategy: Strategy = Strategy.NONE, dp: int = 16
                  ) -> GranularitySearcher:
    if measure_fn is None:
        def measure_fn(b: int, n: int) -> float:
            return simulate(moe_workload(cfg, b, ep_size, dp=dp), hw, n,
                            strategy)
    return GranularitySearcher(measure_fn)


def resolve(cfg: ArchConfig, *, local_tokens: int, ep_size: int,
            hw: HardwareSpec, searcher: Optional[GranularitySearcher] = None,
            allow_offload: Optional[bool] = None, dp: int = 16
            ) -> ArchConfig:
    """Fill in adaptive (n, strategy) -> concrete values in cfg.moe."""
    if cfg.moe is None:
        return cfg
    m = cfg.moe
    w = moe_workload(cfg, local_tokens, ep_size, dp=dp)

    strategy = m.memory_reuse_strategy
    if strategy == "adaptive":
        if allow_offload is None:
            allow_offload = hw.has_host_offload and host_offload_supported()
        hw_eff = dataclasses.replace(hw, has_host_offload=allow_offload)
        strategy = select_strategy(w, hw_eff).value

    n = m.num_partitions
    if n == 0:
        searcher = searcher or make_searcher(cfg, ep_size, hw,
                                             strategy=Strategy(strategy),
                                             dp=dp)
        n = searcher.best_n(local_tokens)

    return dataclasses.replace(
        cfg, moe=dataclasses.replace(m, num_partitions=n,
                                     memory_reuse_strategy=strategy))
