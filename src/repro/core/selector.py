"""Adaptive runtime configuration (paper §III-C + §III-E).

Resolves the two runtime knobs of MPipeMoE *before* jit (they are static
shape/structure choices):

* pipeline granularity ``n``  — Algorithm 1 over the injected measure
  function (wall-clock on hardware; the pipeline simulator otherwise);
* memory-reuse strategy       — Eq. 10 argmin, masked by hardware
  capacities (no host offload => S1–S3 unavailable).

Returns an updated ArchConfig; the training loop re-jits when the
resolved (n, strategy) changes (compilation cache keyed by them).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Sequence

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.granularity import GranularitySearcher
from repro.core.perf_model import MoEWorkload, select_strategy
from repro.core.pipeline_sim import simulate
from repro.core.strategies import host_offload_supported
from repro.core.types import HardwareSpec, Strategy
from repro.obs import PID_RESOLVER, Recorder


def moe_workload(cfg: ArchConfig, local_tokens: int, ep_size: int,
                 dtype_bytes: int = 2, dp: int = 16) -> MoEWorkload:
    m = cfg.moe
    return MoEWorkload(b=local_tokens, m=cfg.d_model, h=m.d_expert,
                       k=m.top_k, ep=ep_size, dtype_bytes=dtype_bytes,
                       gated=cfg.gated_ffn,
                       e_local=max(1, m.num_experts // ep_size), dp=dp)


def make_searcher(cfg: ArchConfig, ep_size: int, hw: HardwareSpec,
                  measure_fn: Optional[Callable] = None,
                  strategy: Strategy = Strategy.NONE, dp: int = 16,
                  candidates: Optional[Sequence[int]] = None
                  ) -> GranularitySearcher:
    if measure_fn is None:
        def measure_fn(b: int, n: int) -> float:
            return simulate(moe_workload(cfg, b, ep_size, dp=dp), hw, n,
                            strategy)
    if candidates:
        return GranularitySearcher(measure_fn, candidates)
    return GranularitySearcher(measure_fn)


def resolve_strategy(cfg: ArchConfig, w: MoEWorkload, hw: HardwareSpec,
                     allow_offload: Optional[bool] = None) -> str:
    """Concrete strategy string for cfg.moe (Eq. 10 argmin when
    'adaptive', masked by hardware capacities — no host offload
    degrades the candidate set to the device-only strategies)."""
    strategy = cfg.moe.memory_reuse_strategy
    if strategy == "adaptive":
        if allow_offload is None:
            allow_offload = hw.has_host_offload and host_offload_supported()
        hw_eff = dataclasses.replace(hw, has_host_offload=allow_offload)
        strategy = select_strategy(w, hw_eff).value
    return strategy


def _resolve_with(cfg: ArchConfig, local_tokens: int, ep_size: int,
                  hw: HardwareSpec, dp: int,
                  allow_offload: Optional[bool],
                  searcher_for: Callable[[str], GranularitySearcher]
                  ) -> ArchConfig:
    """Shared resolution body: strategy via Eq. 10, n via Algorithm 1."""
    if cfg.moe is None:
        return cfg
    m = cfg.moe
    w = moe_workload(cfg, local_tokens, ep_size, dp=dp)
    strategy = resolve_strategy(cfg, w, hw, allow_offload)
    n = m.num_partitions
    if n == 0:
        n = searcher_for(strategy).best_n(local_tokens)
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(m, num_partitions=n,
                                     memory_reuse_strategy=strategy))


def resolve(cfg: ArchConfig, *, local_tokens: int, ep_size: int,
            hw: HardwareSpec, searcher: Optional[GranularitySearcher] = None,
            allow_offload: Optional[bool] = None, dp: int = 16
            ) -> ArchConfig:
    """Fill in adaptive (n, strategy) -> concrete values in cfg.moe."""
    def searcher_for(strategy: str) -> GranularitySearcher:
        return searcher or make_searcher(cfg, ep_size, hw,
                                         strategy=Strategy(strategy),
                                         dp=dp)

    return _resolve_with(cfg, local_tokens, ep_size, hw, dp,
                         allow_offload, searcher_for)


class Resolver:
    """Incremental ``resolve`` for the online controller (§III-C).

    One persistent :class:`GranularitySearcher` per resolved strategy, so
    revisited token counts hit Algorithm 1's hash/range caches instead of
    re-measuring — the property that makes runtime retuning affordable.

    ``measure_fn(b, n, strategy) -> seconds`` overrides the analytic
    simulator; the training runtime injects wall-clock timing of a few
    compiled candidate steps here when real hardware is attached.
    """

    def __init__(self, cfg: ArchConfig, *, ep_size: int, hw: HardwareSpec,
                 measure_fn: Optional[Callable[[int, int, Strategy], float]]
                 = None, dp: int = 16,
                 allow_offload: Optional[bool] = None,
                 candidates: Optional[Sequence[int]] = None,
                 obs: Optional[Recorder] = None):
        self.cfg = cfg
        self.ep_size = ep_size
        self.hw = hw
        self.measure_fn = measure_fn
        self.dp = dp
        self.allow_offload = allow_offload
        self.candidates = tuple(candidates) if candidates else None
        self._searchers: Dict[str, GranularitySearcher] = {}
        # telemetry (repro.obs): serve and train controllers thread the
        # same Recorder through, so resolver retunes land on one surface
        self.obs = obs if obs is not None else Recorder()
        reg = self.obs.registry
        self._m_retunes = reg.counter(
            "repro_retunes_total", "resolver (n, strategy) resolutions")
        self._m_retune_s = reg.histogram(
            "repro_retune_seconds", "wall time per resolver resolution")
        self._m_candidates = reg.counter(
            "repro_candidates_measured_total",
            "candidate (n, strategy) timings measured")
        self.obs.tracer.thread_name(PID_RESOLVER, 1, "retune")

    def searcher_for(self, strategy: str) -> GranularitySearcher:
        s = self._searchers.get(strategy)
        if s is None:
            if self.measure_fn is not None:
                sv = Strategy(strategy)

                def fn(b: int, n: int, _s=sv) -> float:
                    dt = self.measure_fn(b, n, _s)
                    # measured candidate timing: Algorithm 1's probe
                    self._m_candidates.inc()
                    self.obs.tracer.instant(
                        "candidate", pid=PID_RESOLVER,
                        args={"b": b, "n": n, "strategy": _s.value,
                              "seconds": dt})
                    return dt

                s = GranularitySearcher(
                    fn, self.candidates) if self.candidates else \
                    GranularitySearcher(fn)
            else:
                s = make_searcher(self.cfg, self.ep_size, self.hw,
                                  strategy=Strategy(strategy), dp=self.dp,
                                  candidates=self.candidates)
            self._searchers[strategy] = s
        return s

    @property
    def search_calls(self) -> int:
        return sum(s.search_calls for s in self._searchers.values())

    def resolve(self, local_tokens: int,
                refresh: bool = False) -> ArchConfig:
        """``refresh=True`` drops the strategy's learned measurements
        first (timer-triggered retune: the cached timings are presumed
        stale under workload drift, so a cache hit would be inert)."""
        def searcher_for(strategy: str) -> GranularitySearcher:
            s = self.searcher_for(strategy)
            if refresh:
                s.reset()
            return s

        t0 = time.perf_counter()
        with self.obs.tracer.span(
                "resolver.resolve", pid=PID_RESOLVER,
                args={"tokens": local_tokens, "refresh": refresh}) as sp:
            out = _resolve_with(self.cfg, local_tokens, self.ep_size,
                                self.hw, self.dp, self.allow_offload,
                                searcher_for)
            if out.moe is not None:
                sp["n"] = out.moe.num_partitions
                sp["strategy"] = out.moe.memory_reuse_strategy
        self._m_retunes.inc()
        self._m_retune_s.observe(time.perf_counter() - t0)
        return out
