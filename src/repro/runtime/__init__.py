from repro.runtime.train_loop import (AdaptiveController, AdaptiveOptions,
                                      TrainOptions, abstract_state,
                                      init_state, make_train_step, train)

__all__ = ["AdaptiveController", "AdaptiveOptions", "TrainOptions",
           "abstract_state", "init_state", "make_train_step", "train"]
