from repro.runtime.train_loop import (TrainOptions, abstract_state,
                                      init_state, make_train_step, train)

__all__ = ["TrainOptions", "abstract_state", "init_state",
           "make_train_step", "train"]
