"""Training runtime: step construction + fault-tolerant host loop.

Step semantics:
* bf16 compute / fp32 params (+ optimizer-dependent state);
* optional int8 gradient compression with error feedback (cross-pod);
* MoE aux losses folded into the objective by the model's loss_fn.

Fault tolerance (DESIGN §8): the loop checkpoints every
``ckpt_every`` steps (async, atomic), retries a failed step
(``max_retries``), restores from the latest checkpoint on unrecoverable
errors, and emits heartbeats a cluster monitor can watch for stragglers.
The data pipeline is seekable, so restart resumes at the exact batch.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.compression import compress_with_feedback
from repro.models.api import get_model
from repro.optim import get_optimizer, lr_schedule

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class TrainOptions:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    compress_grads: bool = False
    grad_accum: int = 1              # microbatching (PP-free memory lever)
    use_kernel: bool = False


def init_state(cfg: ArchConfig, key, opts: TrainOptions) -> Dict[str, Any]:
    model = get_model(cfg)
    params = model.init(cfg, key)
    opt_mod, ocfg = get_optimizer(cfg.optimizer, opts.lr)
    state = {"params": params, "opt": opt_mod.init(params, ocfg),
             "step": jnp.zeros((), jnp.int32)}
    if opts.compress_grads:
        state["grad_err"] = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def abstract_state(cfg: ArchConfig, opts: TrainOptions) -> Dict[str, Any]:
    model = get_model(cfg)
    aparams = model.abstract_params(cfg)
    opt_mod, ocfg = get_optimizer(cfg.optimizer, opts.lr)
    state = {"params": aparams,
             "opt": opt_mod.abstract_state(aparams, ocfg),
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    if opts.compress_grads:
        state["grad_err"] = jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), aparams)
    return state


def make_train_step(cfg: ArchConfig, opts: TrainOptions, dist=None
                    ) -> Callable:
    model = get_model(cfg)
    opt_mod, ocfg = get_optimizer(cfg.optimizer, opts.lr)

    def loss_of(params, batch):
        return model.loss_fn(params, batch, cfg, dist=dist,
                             use_kernel=opts.use_kernel)

    def train_step(state, batch):
        if opts.grad_accum > 1:
            def micro(carry, mb):
                (l, m), g = jax.value_and_grad(loss_of, has_aux=True)(
                    state["params"], mb)
                acc_g, acc_m = carry
                acc_g = jax.tree_util.tree_map(jnp.add, acc_g, g)
                acc_m = jax.tree_util.tree_map(jnp.add, acc_m, m)
                return (acc_g, acc_m), None
            zeros_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            zeros_m = {k: jnp.zeros((), jnp.float32)
                       for k in ("ce", "loss", "aux_loss", "z_loss")}
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((opts.grad_accum,
                                     x.shape[0] // opts.grad_accum)
                                    + x.shape[1:]), batch)
            (grads, metrics), _ = jax.lax.scan(micro, (zeros_g, zeros_m),
                                               mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g / opts.grad_accum, grads)
            metrics = jax.tree_util.tree_map(
                lambda m: m / opts.grad_accum, metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(state["params"], batch)

        new_state = dict(state)
        if opts.compress_grads:
            grads, new_err = compress_with_feedback(grads,
                                                    state.get("grad_err"))
            new_state["grad_err"] = new_err

        scale = lr_schedule(state["step"], warmup=opts.warmup,
                            total=opts.total_steps)
        params, opt = opt_mod.update(grads, state["opt"], state["params"],
                                     ocfg, lr_scale=scale)
        new_state.update(params=params, opt=opt, step=state["step"] + 1)
        gnorm = jax.tree_util.tree_reduce(
            lambda a, g: a + jnp.sum(g.astype(jnp.float32) ** 2), grads,
            jnp.zeros((), jnp.float32))
        metrics = dict(metrics, grad_norm=jnp.sqrt(gnorm), lr_scale=scale)
        return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Fault-tolerant host loop
# ---------------------------------------------------------------------------

def train(cfg: ArchConfig, *, steps: int, batch_source,
          opts: Optional[TrainOptions] = None, dist=None,
          checkpointer=None, ckpt_every: int = 100, max_retries: int = 2,
          heartbeat: Optional[Callable[[int, Dict], None]] = None,
          state=None, jit: bool = True):
    """Run ``steps`` training steps with checkpoint/restart semantics.

    ``batch_source.batch_at(step)`` must be deterministic (seekable).
    Returns (final_state, history list of metric dicts).
    """
    opts = opts or TrainOptions()
    if state is None:
        state = init_state(cfg, jax.random.PRNGKey(0), opts)
    start = 0
    if checkpointer is not None:
        restored = checkpointer.restore_latest(abstract=None)
        if restored is not None:
            state, start = restored["state"], int(restored["step"])
            log.info("restored checkpoint at step %d", start)

    step_fn = make_train_step(cfg, opts, dist)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    history = []
    step = start
    while step < steps:
        batch = {k: jnp.asarray(v)
                 for k, v in batch_source.batch_at(step).items()}
        attempt = 0
        while True:
            try:
                t0 = time.perf_counter()
                state, metrics = step_fn(state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                metrics["step_time_s"] = time.perf_counter() - t0
                break
            except Exception:                      # pragma: no cover
                attempt += 1
                log.exception("step %d failed (attempt %d)", step, attempt)
                if attempt > max_retries:
                    if checkpointer is not None:
                        restored = checkpointer.restore_latest(abstract=None)
                        if restored is not None:
                            state = restored["state"]
                            step = int(restored["step"])
                            log.warning("rolled back to step %d", step)
                            attempt = 0
                            continue
                    raise
        history.append({"step": step, **metrics})
        if heartbeat is not None:
            heartbeat(step, metrics)
        step += 1
        if checkpointer is not None and step % ckpt_every == 0:
            checkpointer.save(step, state)
    if checkpointer is not None:
        checkpointer.save(steps, state, block=True)
    return state, history
