"""Training runtime: step construction + fault-tolerant host loop.

Step semantics:
* bf16 compute / fp32 params (+ optimizer-dependent state);
* optional int8 gradient compression with error feedback (cross-pod);
* MoE aux losses folded into the objective by the model's loss_fn.

Fault tolerance (DESIGN §8): the loop checkpoints every
``ckpt_every`` steps (async, atomic), retries a failed step
(``max_retries``), restores from the latest checkpoint on unrecoverable
errors, and emits heartbeats a cluster monitor can watch for stragglers.
The data pipeline is seekable, so restart resumes at the exact batch.

Online adaptation (paper §III-C Algorithm 1 + §III-E): when the config
leaves ``num_partitions == 0`` or ``memory_reuse_strategy ==
"adaptive"``, an :class:`AdaptiveController` resolves the concrete
(n, strategy) at runtime — on every batch-shape change and, optionally,
every ``retune_every`` steps — through a persistent
``selector.Resolver``, and re-jits only when the resolved
(n, strategy, batch_shape) key is new. Revisited configurations hit the
compiled-step cache and are free.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.selector import Resolver
from repro.core.types import TPU_V5E, HardwareSpec, Strategy
from repro.obs import Recorder
from repro.distributed.compression import compress_with_feedback
from repro.models.api import get_model
from repro.optim import get_optimizer, lr_schedule

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class TrainOptions:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    compress_grads: bool = False
    grad_accum: int = 1              # microbatching (PP-free memory lever)
    use_kernel: bool = False


def init_state(cfg: ArchConfig, key, opts: TrainOptions) -> Dict[str, Any]:
    model = get_model(cfg)
    params = model.init(cfg, key)
    opt_mod, ocfg = get_optimizer(cfg.optimizer, opts.lr)
    state = {"params": params, "opt": opt_mod.init(params, ocfg),
             "step": jnp.zeros((), jnp.int32)}
    if opts.compress_grads:
        state["grad_err"] = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def abstract_state(cfg: ArchConfig, opts: TrainOptions) -> Dict[str, Any]:
    model = get_model(cfg)
    aparams = model.abstract_params(cfg)
    opt_mod, ocfg = get_optimizer(cfg.optimizer, opts.lr)
    state = {"params": aparams,
             "opt": opt_mod.abstract_state(aparams, ocfg),
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    if opts.compress_grads:
        state["grad_err"] = jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), aparams)
    return state


def make_train_step(cfg: ArchConfig, opts: TrainOptions, dist=None
                    ) -> Callable:
    model = get_model(cfg)
    opt_mod, ocfg = get_optimizer(cfg.optimizer, opts.lr)

    def loss_of(params, batch):
        return model.loss_fn(params, batch, cfg, dist=dist,
                             use_kernel=opts.use_kernel)

    def train_step(state, batch):
        if opts.grad_accum > 1:
            def micro(carry, mb):
                (l, m), g = jax.value_and_grad(loss_of, has_aux=True)(
                    state["params"], mb)
                acc_g, acc_m = carry
                acc_g = jax.tree_util.tree_map(jnp.add, acc_g, g)
                acc_m = jax.tree_util.tree_map(jnp.add, acc_m, m)
                return (acc_g, acc_m), None
            zeros_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((opts.grad_accum,
                                     x.shape[0] // opts.grad_accum)
                                    + x.shape[1:]), batch)
            # zero metric carry from the model's actual metrics pytree
            # (loss_fn implementations differ in their metric keys)
            mb0 = jax.tree_util.tree_map(lambda x: x[0], mbs)
            _, m_shapes = jax.eval_shape(loss_of, state["params"], mb0)
            zeros_m = jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), m_shapes)
            (grads, metrics), _ = jax.lax.scan(micro, (zeros_g, zeros_m),
                                               mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g / opts.grad_accum, grads)
            metrics = jax.tree_util.tree_map(
                lambda m: m / opts.grad_accum, metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(state["params"], batch)

        new_state = dict(state)
        if opts.compress_grads:
            grads, new_err = compress_with_feedback(grads,
                                                    state.get("grad_err"))
            new_state["grad_err"] = new_err

        scale = lr_schedule(state["step"], warmup=opts.warmup,
                            total=opts.total_steps)
        params, opt = opt_mod.update(grads, state["opt"], state["params"],
                                     ocfg, lr_scale=scale)
        new_state.update(params=params, opt=opt, step=state["step"] + 1)
        gnorm = jax.tree_util.tree_reduce(
            lambda a, g: a + jnp.sum(g.astype(jnp.float32) ** 2), grads,
            jnp.zeros((), jnp.float32))
        metrics = dict(metrics, grad_norm=jnp.sqrt(gnorm), lr_scale=scale)
        return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Online adaptive (n, strategy) controller
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AdaptiveOptions:
    """Knobs of the online controller (paper §III-C + §III-E).

    retune_every == 0 retunes only on batch-shape change; k > 0 also
    re-runs ``resolve`` every k steps (workload drift without shape
    drift — e.g. interference from a colocated job).
    ``measure``: "wallclock" times a few compiled candidate steps on the
    attached accelerator, "simulate" uses the analytic pipeline
    simulator, "auto" picks wallclock iff a non-CPU backend is attached.
    """
    retune_every: int = 0
    ep_size: int = 1
    dp: int = 1
    hw: HardwareSpec = TPU_V5E
    measure: str = "auto"            # auto | wallclock | simulate
    measure_fn: Optional[Callable[[int, int, Strategy], float]] = None
    measure_steps: int = 2
    allow_offload: Optional[bool] = None
    candidates: Optional[Sequence[int]] = None
    cache_size: int = 32             # LRU bound on kept compiled steps
    obs: Optional["Recorder"] = None  # telemetry recorder shared with
                                      # the resolver (None = private
                                      # metrics-only recorder)


class AdaptiveController:
    """Feedback loop between the granularity searcher, the perf model and
    the step function: resolves (n, strategy) online and keeps a
    compiled-step cache keyed by (n, strategy, batch_shape) so that
    re-jit happens at most once per distinct configuration.
    """

    def __init__(self, cfg: ArchConfig, opts: TrainOptions, dist=None,
                 aopts: Optional[AdaptiveOptions] = None, *,
                 jit: bool = True):
        if cfg.moe is None or not cfg.moe.pipeline:
            # with pipeline=False every candidate n lowers to the same
            # n=1 program — the granularity search would be meaningless
            raise ValueError("AdaptiveController needs a pipelined MoE "
                             "config (cfg.moe with pipeline=True)")
        self.cfg = cfg
        self.opts = opts
        self.dist = dist
        self.jit = jit
        self.aopts = aopts or AdaptiveOptions()
        if dist is not None:
            # derive the EP/DP extents from the live mesh unless the
            # caller set them: a 1-wide default under an 8-way EP mesh
            # would resolve (n, strategy) for the wrong workload
            if self.aopts.ep_size == 1:
                self.aopts = dataclasses.replace(self.aopts,
                                                 ep_size=dist.ep_size)
            if self.aopts.dp == 1:
                self.aopts = dataclasses.replace(self.aopts,
                                                 dp=dist.dp_size)
        measure_fn = self.aopts.measure_fn
        if measure_fn is None:
            mode = self.aopts.measure
            if mode == "auto":
                mode = ("wallclock" if jax.default_backend() != "cpu"
                        else "simulate")
            if mode == "wallclock":
                measure_fn = self._wallclock_measure
        self.obs = (self.aopts.obs if self.aopts.obs is not None
                    else Recorder())
        self.resolver = Resolver(cfg, ep_size=self.aopts.ep_size,
                                 hw=self.aopts.hw, measure_fn=measure_fn,
                                 dp=self.aopts.dp,
                                 allow_offload=self.aopts.allow_offload,
                                 candidates=self.aopts.candidates,
                                 obs=self.obs)
        self._step_cache: Dict[Tuple, Callable] = {}
        self._measure_cache: Dict[Tuple, Callable] = {}
        self._probe = None               # (state, batch) for wallclock
        self._last_shape = None
        self._last_retune = None
        self._last_refresh = None
        self.current: Optional[Tuple[int, str]] = None
        self.rejit_count = 0
        self.retune_count = 0

    def _cache_get(self, cache: Dict[Tuple, Callable], key: Tuple):
        """LRU: dicts iterate in insertion order; re-insert on hit."""
        fn = cache.pop(key, None)
        if fn is not None:
            cache[key] = fn
        return fn

    def _cache_put(self, cache: Dict[Tuple, Callable], key: Tuple, fn):
        cache[key] = fn
        while len(cache) > max(1, self.aopts.cache_size):
            cache.pop(next(iter(cache)))

    @staticmethod
    def _shape_key(batch) -> Tuple:
        return tuple(sorted((k, tuple(v.shape), str(v.dtype))
                            for k, v in batch.items()))

    @staticmethod
    def _local_tokens(batch) -> int:
        x = batch["tokens"] if "tokens" in batch else \
            next(iter(batch.values()))
        return int(x.shape[0]) * (int(x.shape[1]) if x.ndim > 1 else 1)

    def _cfg_with(self, n: int, strategy: str) -> ArchConfig:
        return dataclasses.replace(
            self.cfg, moe=dataclasses.replace(
                self.cfg.moe, num_partitions=n,
                memory_reuse_strategy=strategy))

    def _wallclock_measure(self, b: int, n: int,
                           strategy: Strategy) -> float:
        """Algorithm 1's measure function on real hardware: time a few
        compiled steps of candidate n against the live (state, batch).
        ``b`` equals the probe batch's token count by construction (the
        searcher is always queried at the current batch size)."""
        state, batch = self._probe
        # compiled candidates are cached across retunes (a periodic
        # refresh re-times them; only the timing is stale, not the
        # executable). The winner is still compiled once more with
        # donation for the step cache — the price of donating there.
        key = (n, strategy.value, self._shape_key(batch))
        fn = self._cache_get(self._measure_cache, key)
        if fn is None:
            fn = make_train_step(self._cfg_with(n, strategy.value),
                                 self.opts, self.dist)
            if self.jit:
                fn = jax.jit(fn)         # no donation: state is reused
            self._cache_put(self._measure_cache, key, fn)
        out = fn(state, batch)
        jax.block_until_ready(out)       # compile + warm up
        reps = max(1, self.aopts.measure_steps)
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(state, batch)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / reps

    def step_fn(self, state, batch, step: int
                ) -> Tuple[Callable, Dict[str, Any]]:
        """Step function + controller metrics for this batch."""
        shape = self._shape_key(batch)
        shape_changed = self.current is None or shape != self._last_shape
        # timer fires on its own clock, independent of shape churn — a
        # cyclic-shape trace must not starve drift re-measurement
        timer = (self.aopts.retune_every > 0
                 and self._last_refresh is not None
                 and step - self._last_refresh >= self.aopts.retune_every)
        info: Dict[str, Any] = {}
        if shape_changed or timer:
            t0 = time.perf_counter()
            self._probe = (state, batch)
            # a timer-triggered retune re-measures (refresh): cached
            # timings are exactly what workload drift invalidates
            rcfg = self.resolver.resolve(self._local_tokens(batch),
                                         refresh=timer)
            self._probe = None
            resolved = (rcfg.moe.num_partitions,
                        rcfg.moe.memory_reuse_strategy)
            if resolved != self.current:
                log.info("adaptive retune @%d: (n, strategy) %s -> %s",
                         step, self.current, resolved)
            self.current = resolved
            self._last_shape = shape
            self._last_retune = step
            if timer or self._last_refresh is None:
                self._last_refresh = step
            self.retune_count += 1
            info["retune_time_s"] = time.perf_counter() - t0
        n, strategy = self.current
        key = (n, strategy, shape)
        fn = self._cache_get(self._step_cache, key)
        if fn is None:
            fn = make_train_step(self._cfg_with(n, strategy), self.opts,
                                 self.dist)
            if self.jit:
                fn = jax.jit(fn, donate_argnums=(0,))
            self._cache_put(self._step_cache, key, fn)
            self.rejit_count += 1
        info.update(n=n, strategy=strategy)
        return fn, info


# ---------------------------------------------------------------------------
# Fault-tolerant host loop
# ---------------------------------------------------------------------------

def train(cfg: ArchConfig, *, steps: int, batch_source,
          opts: Optional[TrainOptions] = None, dist=None,
          checkpointer=None, ckpt_every: int = 100, max_retries: int = 2,
          heartbeat: Optional[Callable[[int, Dict], None]] = None,
          state=None, jit: bool = True, adaptive=None):
    """Run ``steps`` training steps with checkpoint/restart semantics.

    ``batch_source.batch_at(step)`` must be deterministic (seekable).
    ``adaptive`` selects the online (n, strategy) controller: ``None``
    auto-enables it when cfg.moe still carries adaptive placeholders
    (``num_partitions == 0`` or ``memory_reuse_strategy ==
    "adaptive"``); pass ``False`` to force the static path, an
    :class:`AdaptiveOptions` to tune it, or a pre-built
    :class:`AdaptiveController` (benchmarks/tests inspect its counters).
    Returns (final_state, history list of metric dicts).
    """
    opts = opts or TrainOptions()
    controller = None
    if isinstance(adaptive, AdaptiveController):
        controller = adaptive
    elif adaptive is None:
        if cfg.moe is not None and cfg.moe.pipeline and (
                cfg.moe.num_partitions == 0
                or cfg.moe.memory_reuse_strategy == "adaptive"):
            controller = AdaptiveController(cfg, opts, dist, jit=jit)
    elif adaptive:
        aopts = adaptive if isinstance(adaptive, AdaptiveOptions) else None
        controller = AdaptiveController(cfg, opts, dist, aopts, jit=jit)

    if state is None:
        state = init_state(cfg, jax.random.PRNGKey(0), opts)
    start = 0
    if checkpointer is not None:
        restored = checkpointer.restore_latest(abstract=None)
        if restored is not None:
            state, start = restored["state"], int(restored["step"])
            log.info("restored checkpoint at step %d", start)

    if controller is None:
        step_fn = make_train_step(cfg, opts, dist)
        if jit:
            step_fn = jax.jit(step_fn, donate_argnums=(0,))

    history = []
    step = start
    while step < steps:
        batch = {k: jnp.asarray(v)
                 for k, v in batch_source.batch_at(step).items()}
        attempt = 0
        while True:
            try:
                ainfo = {}
                if controller is not None:
                    step_fn, ainfo = controller.step_fn(state, batch, step)
                t0 = time.perf_counter()
                state, metrics = step_fn(state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                metrics["step_time_s"] = time.perf_counter() - t0
                metrics.update(ainfo)
                break
            except Exception:                      # pragma: no cover
                attempt += 1
                log.exception("step %d failed (attempt %d)", step, attempt)
                if attempt > max_retries:
                    if checkpointer is not None:
                        restored = checkpointer.restore_latest(abstract=None)
                        if restored is not None:
                            state = restored["state"]
                            step = int(restored["step"])
                            log.warning("rolled back to step %d", step)
                            attempt = 0
                            continue
                    raise
        history.append({"step": step, **metrics})
        if heartbeat is not None:
            heartbeat(step, metrics)
        step += 1
        if checkpointer is not None and step % ckpt_every == 0:
            checkpointer.save(step, state)
    if checkpointer is not None:
        checkpointer.save(steps, state, block=True)
    return state, history
