"""Encoder-decoder model (whisper-family). The conv/audio frontend is a
stub: the encoder consumes precomputed frame embeddings [B, T_enc, M].
Decoder layers: causal self-attention + cross-attention + FFN; cross K/V
is computed per layer from the encoder output (cached at prefill).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, AttentionConfig
from repro.models import blocks, kv_cache, module
from repro.models.layers import attention, embedding, ffn, norm, rope


def _enc_cfg(cfg: ArchConfig) -> ArchConfig:
    e = cfg.encoder
    return dataclasses.replace(
        cfg, kind="decoder", num_layers=e.num_layers, d_model=e.d_model,
        d_ff=e.d_ff, encoder=None, moe=None, block_pattern=("attn",),
        attn=AttentionConfig(num_heads=e.num_heads, num_kv_heads=e.num_heads,
                             qkv_bias=cfg.attn.qkv_bias),
        positional="sincos")


def specs_tree(cfg: ArchConfig):
    ecfg = _enc_cfg(cfg)
    enc_layer = {
        "mixer_norm": norm.specs(ecfg.d_model, cfg.norm),
        "mixer": attention.specs(ecfg),
        "ffn_norm": norm.specs(ecfg.d_model, cfg.norm),
        "ffn": ffn.specs(ecfg.d_model, ecfg.d_ff, cfg.gated_ffn),
    }
    roles = cfg.layer_roles()
    dec_layer = {f"l{i}": blocks.block_specs(cfg, r, cross=True)
                 for i, r in enumerate(roles)}
    return {
        "embed": embedding.specs(cfg),
        "enc_layers": module.stack(enc_layer, cfg.encoder.num_layers),
        "enc_norm": norm.specs(cfg.encoder.d_model, cfg.norm),
        "periods": module.stack(dec_layer, cfg.num_periods),
        "final_norm": norm.specs(cfg.d_model, cfg.norm),
    }


def init(cfg, key):
    return module.build(specs_tree(cfg), key)


def abstract_params(cfg):
    return module.abstract(specs_tree(cfg))


def param_axes(cfg):
    return module.axes_of(specs_tree(cfg))


def count_params(cfg, active_only: bool = False) -> int:
    return module.count(specs_tree(cfg))


def encode(params, frames, cfg: ArchConfig, dist=None):
    ecfg = _enc_cfg(cfg)
    dt = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(dt)
    x = x + rope.sincos_positions(x.shape[1], ecfg.d_model).astype(dt)[None]

    def body(x, lp):
        h = norm.apply(lp["mixer_norm"], x, cfg.norm)
        q, k, v = attention._proj_qkv(lp["mixer"], h, ecfg)
        o = attention.flash_attention(q, k, v, causal=False)
        x = x + jnp.einsum("bshe,hed->bsd", o,
                           lp["mixer"]["w_o"].astype(dt))
        h = norm.apply(lp["ffn_norm"], x, cfg.norm)
        x = x + ffn.apply(lp["ffn"], h, act=cfg.ffn_act, gated=cfg.gated_ffn)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return norm.apply(params["enc_norm"], x, cfg.norm)


def forward(params, batch, cfg: ArchConfig, *, mode: str = "train",
            cache: Optional[dict] = None, dist=None,
            use_kernel: bool = False):
    dt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    b = tokens.shape[0]
    roles = cfg.layer_roles()

    if mode == "decode":
        positions = jnp.broadcast_to(cache["pos"][None, None], (b, 1))
        x = embedding.embed(params["embed"], tokens, cfg,
                            positions=positions, dtype=dt)
        s = 1
        cross_kv_all = cache["cross"]          # precomputed at prefill
        enc_out = None
    else:
        enc_out = encode(params, batch["frames"], cfg, dist)
        s = tokens.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x = embedding.embed(params["embed"], tokens, cfg, dtype=dt)
        x = x + params["embed"]["pos"][positions[0]].astype(dt)[None]
        cross_kv_all = None

    aux0 = {"aux_loss": jnp.zeros((), jnp.float32),
            "z_loss": jnp.zeros((), jnp.float32)}

    def period_body(carry, xs):
        x, aux = carry
        pparams, pcache, pcross = xs
        new_pcache = {} if pcache is not None else None
        for i, role in enumerate(roles):
            lp = pparams[f"l{i}"]
            enc_kv = (pcross if pcross is not None else
                      attention.cross_kv(lp["cross"], enc_out, cfg=cfg))
            lcache = pcache[f"l{i}"] if pcache is not None else None
            x, a, nc = blocks.block_apply(
                lp, x, cfg=cfg, role=role, positions=positions, mode=mode,
                cache=lcache, dist=dist, enc_kv=enc_kv)
            aux = jax.tree_util.tree_map(jnp.add, aux, a)
            if new_pcache is not None:
                new_pcache[f"l{i}"] = nc if nc is not None else lcache
        return (x, aux), new_pcache

    layer_cache = cache["layers"] if cache is not None else None
    if layer_cache is not None:
        if mode == "decode":
            (x, aux), new_layers = jax.lax.scan(
                period_body, (x, aux0),
                (params["periods"], layer_cache,
                 {"k": cache["cross"]["k"], "v": cache["cross"]["v"]}))
            new_cross = cache["cross"]
        else:  # prefill: compute + store cross kv
            def prefill_body(carry, xs):
                pparams, pcache = xs
                lp0 = pparams["l0"]
                ck = attention.cross_kv(lp0["cross"], enc_out, cfg=cfg)
                (x2, aux2), npc = period_body(carry, (pparams, pcache, None))
                return (x2, aux2), (npc, {"k": ck["k"], "v": ck["v"]})
            (x, aux), (new_layers, new_cross) = jax.lax.scan(
                prefill_body, (x, aux0), (params["periods"], layer_cache))
            new_cross = jax.tree_util.tree_map(
                lambda t: t.astype(jnp.bfloat16) if t.dtype != jnp.int32
                else t, new_cross)
    else:
        (x, aux), _ = jax.lax.scan(
            lambda c, p: (period_body(c, (p, None, None))[0], None),
            (x, aux0), params["periods"])
        new_layers = new_cross = None

    x = norm.apply(params["final_norm"], x, cfg.norm)
    logits = embedding.logits(params["embed"], x, cfg)

    new_cache = None
    if cache is not None:
        new_pos = (cache["pos"] + 1 if mode == "decode"
                   else jnp.asarray(s, jnp.int32))
        new_cache = {"layers": new_layers, "pos": new_pos,
                     "cross": new_cross}
    return logits, aux, new_cache


def loss_fn(params, batch, cfg: ArchConfig, dist=None,
            use_kernel: bool = False):
    logits, aux, _ = forward(params, batch, cfg, mode="train", dist=dist)
    from repro.models.lm import cross_entropy
    ce = cross_entropy(logits, batch["labels"])
    loss = ce + aux["aux_loss"] + aux["z_loss"]
    return loss, {"ce": ce, "loss": loss, "aux_loss": aux["aux_loss"],
                  "z_loss": aux["z_loss"]}


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, abstract: bool = False):
    layers = kv_cache.init_cache(cfg, batch, max_len, dtype,
                                 abstract=abstract)
    cross = layers.pop("cross")
    pos = (jax.ShapeDtypeStruct((), jnp.int32) if abstract
           else jnp.zeros((), jnp.int32))
    return {"layers": layers, "pos": pos, "cross": cross}


def decode_step(params, cache, tokens, cfg: ArchConfig, dist=None):
    logits, _, new_cache = forward(params, {"tokens": tokens}, cfg,
                                   mode="decode", cache=cache, dist=dist)
    return logits[:, -1], new_cache


def prefill(params, batch, cfg: ArchConfig, max_len: int, dist=None,
            dtype=jnp.bfloat16):
    cache = init_cache(cfg, batch["tokens"].shape[0], max_len, dtype)
    logits, _, new_cache = forward(params, batch, cfg, mode="prefill",
                                   cache=cache, dist=dist)
    return logits, new_cache
