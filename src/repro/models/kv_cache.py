"""Decode caches for every mixer family.

Shapes are chosen by *role*: sliding-window attention layers allocate a
ring buffer of ``window`` slots (the gemma3/danube long-context path); MLA
layers cache only the compressed latent; SSM/xLSTM layers keep O(1)
recurrent state. ``abstract=True`` returns ShapeDtypeStructs (dry-run).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


def _mk(shape, dtype, abstract):
    if abstract:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jnp.zeros(shape, dtype)


def layer_cache(cfg: ArchConfig, role: Dict, batch: int, max_len: int,
                dtype=jnp.bfloat16, abstract: bool = False):
    a = cfg.attn
    mixer = role["mixer"]
    if mixer == "attn":
        if a.mla is not None:
            m = a.mla
            return {
                "c_kv": _mk((batch, max_len, m.kv_lora_rank), dtype,
                            abstract),
                "k_rope": _mk((batch, max_len, m.rope_head_dim), dtype,
                              abstract),
                "len": _mk((), jnp.int32, abstract),
            }
        window = 0 if (role["global_attn"] and a.global_period > 1) \
            else a.window
        t = min(window, max_len) if window > 0 else max_len
        kd = (batch, t, a.num_kv_heads, cfg.head_dim)
        return {"k": _mk(kd, dtype, abstract), "v": _mk(kd, dtype, abstract),
                "len": _mk((), jnp.int32, abstract)}
    if mixer == "mamba":
        m = cfg.mamba
        d_inner = m.expand * cfg.d_model
        return {"conv": _mk((batch, m.d_conv - 1, d_inner), dtype, abstract),
                "ssm": _mk((batch, d_inner, m.d_state), jnp.float32,
                           abstract)}
    if mixer == "mlstm":
        di = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
        nh = cfg.attn.num_heads
        dh = di // nh
        k = cfg.xlstm.conv1d_kernel
        return {"conv": _mk((batch, k - 1, di), dtype, abstract),
                "c": _mk((batch, nh, dh, dh), jnp.float32, abstract),
                "n": _mk((batch, nh, dh), jnp.float32, abstract),
                "m": _mk((batch, nh), jnp.float32, abstract)}
    if mixer == "slstm":
        nh = cfg.attn.num_heads
        dh = cfg.d_model // nh
        st = (batch, nh, dh)
        return {k_: _mk(st, jnp.float32, abstract)
                for k_ in ("c", "n", "m", "h")}
    raise ValueError(mixer)


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, abstract: bool = False):
    """Stacked cache tree: leading dim = num_periods (scanned)."""
    roles = cfg.layer_roles()
    per_period = {f"l{i}": layer_cache(cfg, role, batch, max_len, dtype,
                                       abstract=True)
                  for i, role in enumerate(roles)}
    n = cfg.num_periods

    def _stackify(sds):
        shape = (n,) + sds.shape
        if abstract:
            return jax.ShapeDtypeStruct(shape, sds.dtype)
        return jnp.zeros(shape, sds.dtype)

    stacked = jax.tree_util.tree_map(_stackify, per_period)
    if cfg.kind == "encdec":
        # cross-attention K/V cached once at prefill
        enc = cfg.encoder
        kd = (n, batch, enc.context_len, cfg.attn.num_kv_heads,
              cfg.head_dim)
        stacked = dict(stacked)
        stacked["cross"] = {"k": _mk(kd, dtype, abstract),
                            "v": _mk(kd, dtype, abstract)}
    return stacked


def cache_bytes(cache) -> int:
    leaves = jax.tree_util.tree_leaves(cache)
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in leaves)
