"""Decode caches for every mixer family.

Shapes are chosen by *role*: sliding-window attention layers allocate a
ring buffer of ``window`` slots (the gemma3/danube long-context path); MLA
layers cache only the compressed latent; SSM/xLSTM layers keep O(1)
recurrent state. ``abstract=True`` returns ShapeDtypeStructs (dry-run).

Paged layout (the serving engine, ``repro.serve``): instead of one dense
``[batch, max_len]`` block per sequence, K/V live in a global pool of
fixed-size pages ``[num_pages, page_size, kv_heads, head_dim]`` shared by
every in-flight sequence; a per-sequence page table maps logical page
index -> physical page. ``gather_pages``/``scatter_pages`` are the
page-granular access primitives; page 0 is reserved as a write sink for
masked (padding / inactive-slot) writes so jitted steps never branch on
occupancy. ``extract_pages``/``insert_pages`` round-trip physical pages
through host memory — the swap halves of the serving engine's
preempt-by-offload path. Under a serving mesh the pools are replicated
(one logical pool, one replica per device — see
``serve.paged_kv.PagedKVCache``); ``extract_pages`` reads the
replicated value and ``insert_pages(..., sharding=)`` writes it back
without collapsing the layout.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


def _mk(shape, dtype, abstract):
    if abstract:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jnp.zeros(shape, dtype)


def layer_cache(cfg: ArchConfig, role: Dict, batch: int, max_len: int,
                dtype=jnp.bfloat16, abstract: bool = False):
    a = cfg.attn
    mixer = role["mixer"]
    if mixer == "attn":
        if a.mla is not None:
            m = a.mla
            return {
                "c_kv": _mk((batch, max_len, m.kv_lora_rank), dtype,
                            abstract),
                "k_rope": _mk((batch, max_len, m.rope_head_dim), dtype,
                              abstract),
                "len": _mk((), jnp.int32, abstract),
            }
        window = 0 if (role["global_attn"] and a.global_period > 1) \
            else a.window
        t = min(window, max_len) if window > 0 else max_len
        kd = (batch, t, a.num_kv_heads, cfg.head_dim)
        return {"k": _mk(kd, dtype, abstract), "v": _mk(kd, dtype, abstract),
                "len": _mk((), jnp.int32, abstract)}
    if mixer == "mamba":
        m = cfg.mamba
        d_inner = m.expand * cfg.d_model
        return {"conv": _mk((batch, m.d_conv - 1, d_inner), dtype, abstract),
                "ssm": _mk((batch, d_inner, m.d_state), jnp.float32,
                           abstract)}
    if mixer == "mlstm":
        di = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
        nh = cfg.attn.num_heads
        dh = di // nh
        k = cfg.xlstm.conv1d_kernel
        return {"conv": _mk((batch, k - 1, di), dtype, abstract),
                "c": _mk((batch, nh, dh, dh), jnp.float32, abstract),
                "n": _mk((batch, nh, dh), jnp.float32, abstract),
                "m": _mk((batch, nh), jnp.float32, abstract)}
    if mixer == "slstm":
        nh = cfg.attn.num_heads
        dh = cfg.d_model // nh
        st = (batch, nh, dh)
        return {k_: _mk(st, jnp.float32, abstract)
                for k_ in ("c", "n", "m", "h")}
    raise ValueError(mixer)


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, abstract: bool = False):
    """Stacked cache tree: leading dim = num_periods (scanned)."""
    roles = cfg.layer_roles()
    per_period = {f"l{i}": layer_cache(cfg, role, batch, max_len, dtype,
                                       abstract=True)
                  for i, role in enumerate(roles)}
    n = cfg.num_periods

    def _stackify(sds):
        shape = (n,) + sds.shape
        if abstract:
            return jax.ShapeDtypeStruct(shape, sds.dtype)
        return jnp.zeros(shape, sds.dtype)

    stacked = jax.tree_util.tree_map(_stackify, per_period)
    if cfg.kind == "encdec":
        # cross-attention K/V cached once at prefill
        enc = cfg.encoder
        kd = (n, batch, enc.context_len, cfg.attn.num_kv_heads,
              cfg.head_dim)
        stacked = dict(stacked)
        stacked["cross"] = {"k": _mk(kd, dtype, abstract),
                            "v": _mk(kd, dtype, abstract)}
    return stacked


def cache_bytes(cache) -> int:
    leaves = jax.tree_util.tree_leaves(cache)
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in leaves)


# ---------------------------------------------------------------------------
# Paged KV (serving engine)
# ---------------------------------------------------------------------------

def paged_layer_pool(cfg: ArchConfig, role: Dict, num_pages: int,
                     page_size: int, dtype=jnp.bfloat16,
                     abstract: bool = False):
    """Page pool for one attention layer.

    Plain/GQA attention: K and V, each ``[num_pages, page_size,
    kv_heads, head_dim]``. MLA: the compressed latent is what gets
    paged — ``c_kv`` ``[num_pages, page_size, kv_lora_rank]`` plus the
    shared rotary key ``k_rope`` ``[num_pages, page_size,
    rope_head_dim]`` — the whole point of MLA's cache compression, and
    per-token far smaller than full K/V.
    """
    a = cfg.attn
    if role["mixer"] != "attn":
        raise NotImplementedError(
            f"paged KV supports attention layers only "
            f"(got mixer={role['mixer']!r})")
    if a.mla is not None:
        m = a.mla
        return {"ckv_pool": _mk((num_pages, page_size, m.kv_lora_rank),
                                dtype, abstract),
                "kr_pool": _mk((num_pages, page_size, m.rope_head_dim),
                               dtype, abstract)}
    kd = (num_pages, page_size, a.num_kv_heads, cfg.head_dim)
    return {"k_pool": _mk(kd, dtype, abstract),
            "v_pool": _mk(kd, dtype, abstract)}


def _stacked(per_period, n, abstract):
    def _stackify(sds):
        shape = (n,) + sds.shape
        if abstract:
            return jax.ShapeDtypeStruct(shape, sds.dtype)
        return jnp.zeros(shape, sds.dtype)

    return jax.tree_util.tree_map(_stackify, per_period)


def init_paged_pools(cfg: ArchConfig, num_pages: int, page_size: int,
                     dtype=jnp.bfloat16, abstract: bool = False):
    """Stacked paged pools: leading dim = num_periods (scanned), matching
    the parameter tree so ``lax.scan`` zips them per period. Covers
    exactly the attention layers — recurrent mixers keep O(1) state in
    the slot-indexed tree of :func:`init_state_slots` instead (disjoint
    ``l{i}`` key sets; a composite cache merges the two)."""
    roles = cfg.layer_roles()
    per_period = {f"l{i}": paged_layer_pool(cfg, role, num_pages, page_size,
                                            dtype, abstract=True)
                  for i, role in enumerate(roles)
                  if role["mixer"] == "attn"}
    return _stacked(per_period, cfg.num_periods, abstract)


def init_state_slots(cfg: ArchConfig, max_slots: int, dtype=jnp.bfloat16,
                     abstract: bool = False):
    """Slot-indexed recurrent state for the serving engine: for every
    non-attention layer, that mixer's per-sequence decode state
    (:func:`layer_cache`) batched over ``max_slots`` and stacked to
    ``[n_periods, max_slots, ...]``. The jitted decode step reads and
    writes all slots batchwise; chunked prefill slices one slot's row.
    Complement of :func:`init_paged_pools` over the layer roles."""
    roles = cfg.layer_roles()
    per_period = {f"l{i}": layer_cache(cfg, role, max_slots, 1, dtype,
                                       abstract=True)
                  for i, role in enumerate(roles)
                  if role["mixer"] != "attn"}
    return _stacked(per_period, cfg.num_periods, abstract)


def gather_pages(pool, page_table):
    """pool ``[P, ps, ...]``, page_table ``[B, NP]`` ->
    position-contiguous view ``[B, NP*ps, ...]`` per sequence."""
    g = pool[page_table]                       # [B, NP, ps, ...]
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def extract_pages(pools, page_ids):
    """Copy physical pages out of the stacked pools to host numpy.

    pools: per-period tree of ``[n_periods, P, ps, ...]`` leaves;
    ``page_ids``: sequence of physical page indices. Returns a matching
    tree of numpy arrays ``[n_periods, len(page_ids), ps, ...]`` — the
    swap-out half of preempt-by-offload (``repro.serve``). Works against
    replicated *and* page-sharded pools alike — the gather is a global-
    index read, so a DP shard's pages extract identically. The gather
    produces a fresh immutable buffer, so a zero-copy ``np.asarray`` view
    on CPU is safe (unlike the live page-table case, nothing mutates it).
    """
    idx = jnp.asarray(np.asarray(page_ids, np.int32))
    return jax.tree_util.tree_map(
        lambda leaf: np.asarray(leaf[:, idx]), pools)


def insert_pages(pools, page_ids, host, *, sharding=None,
                 out_sharding=None):
    """Write host page copies back into the stacked pools (swap-in).

    Inverse of :func:`extract_pages`: ``host`` leaves are
    ``[n_periods, len(page_ids), ps, ...]``; returns new pools with those
    physical pages overwritten. ``sharding`` (mesh-sharded serving)
    places the host copies before the scatter so the updated pools keep
    the pool's layout instead of pulling everything through one device.
    ``out_sharding`` re-pins the *result* — needed when the pool layout
    differs from the host copies' (DP-sharded pools: pages split over the
    ``data`` axis while an offloaded request's pages all belong to one
    shard, so the host copy enters replicated and the updated pool must
    come back out page-sharded).
    """
    idx = jnp.asarray(np.asarray(page_ids, np.int32))

    def one(leaf, h):
        h = jnp.asarray(h, leaf.dtype)
        if sharding is not None:
            h = jax.device_put(h, sharding)
        out = leaf.at[:, idx].set(h)
        if out_sharding is not None:
            out = jax.device_put(out, out_sharding)
        return out

    return jax.tree_util.tree_map(one, pools, host)


def copy_pages(pools, src_pages, dst_pages, *, out_sharding=None):
    """Device-side physical page duplication (copy-on-write).

    ``src_pages`` / ``dst_pages`` are equal-length sequences of physical
    page indices; returns new pools where every ``dst`` page holds a
    copy of its ``src`` page across all periods and leaves. The copy is
    a same-array gather+scatter, so it never leaves the device; under
    DP-sharded pools both indices belong to the same shard (the serving
    layer never shares pages across shards), so the move is shard-local.
    ``out_sharding`` re-pins the result like :func:`insert_pages`.
    """
    src = jnp.asarray(np.asarray(src_pages, np.int32))
    dst = jnp.asarray(np.asarray(dst_pages, np.int32))

    def one(leaf):
        out = leaf.at[:, dst].set(leaf[:, src])
        if out_sharding is not None:
            out = jax.device_put(out, out_sharding)
        return out

    return jax.tree_util.tree_map(one, pools)


def tree_bytes(tree) -> int:
    """Total bytes of a (host or device) array tree."""
    return sum(int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
               for x in jax.tree_util.tree_leaves(tree))


def scatter_pages(pool, page_table, positions, values, valid=None,
                  sink=0):
    """Write ``values[b, s]`` at absolute position ``positions[b, s]`` of
    sequence ``b``'s paged cache.

    pool ``[P, ps, ...]``; page_table ``[B, NP]``; positions ``[B, S]``
    int32; values ``[B, S, ...]``. Writes masked out by ``valid`` (or
    falling past the table) are redirected to the reserved ``sink`` page
    — scalar page 0 by default, or a per-sequence ``[B]`` array when
    each sequence has its own sink (the DP-sharded pools reserve local
    page 0 of *every* shard so masked writes stay shard-local instead of
    crossing to global page 0) — so the scatter stays branch-free under
    jit.
    """
    ps = pool.shape[1]
    np_ = page_table.shape[1]
    pidx = jnp.clip(positions // ps, 0, np_ - 1)
    page = jnp.take_along_axis(page_table, pidx, axis=1)       # [B, S]
    ok = positions < np_ * ps
    if valid is not None:
        ok = ok & valid
    sink = jnp.asarray(sink, page.dtype)
    page = jnp.where(ok, page, sink if sink.ndim == 0 else sink[:, None])
    off = positions % ps
    flat = values.reshape((-1,) + values.shape[2:]).astype(pool.dtype)
    return pool.at[page.reshape(-1), off.reshape(-1)].set(flat)
