"""Transformer blocks: per-role (mixer x ffn/moe) assembly, pre-norm."""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import attention, ffn, mamba, norm, xlstm
from repro.moe import layer as moe_layer


def block_specs(cfg: ArchConfig, role: Dict, cross: bool = False):
    mixer = role["mixer"]
    s = {"mixer_norm": norm.specs(cfg.d_model, cfg.norm)}
    if mixer == "attn":
        s["mixer"] = attention.specs(cfg)
    elif mixer == "mamba":
        s["mixer"] = mamba.specs(cfg)
    elif mixer == "mlstm":
        s["mixer"] = xlstm.mlstm_specs(cfg)
    elif mixer == "slstm":
        s["mixer"] = xlstm.slstm_specs(cfg)
    else:
        raise ValueError(mixer)
    if cross:
        s["cross_norm"] = norm.specs(cfg.d_model, cfg.norm)
        s["cross"] = attention.cross_specs(cfg)
    if mixer in ("mlstm", "slstm"):
        return s                              # xLSTM blocks embed their FFN
    if role["moe"]:
        s["ffn_norm"] = norm.specs(cfg.d_model, cfg.norm)
        s["moe"] = moe_layer.specs(cfg)
        if cfg.moe.dense_residual and cfg.d_ff:
            s["ffn"] = ffn.specs(cfg.d_model, cfg.d_ff, cfg.gated_ffn)
    elif cfg.d_ff:
        s["ffn_norm"] = norm.specs(cfg.d_model, cfg.norm)
        s["ffn"] = ffn.specs(cfg.d_model, cfg.d_ff, cfg.gated_ffn)
    return s


def block_apply(params, x, *, cfg: ArchConfig, role: Dict, positions,
                mode: str = "train", cache: Optional[dict] = None,
                dist=None, positions3=None, enc_kv=None, causal=True):
    mixer = role["mixer"]
    aux = {"aux_loss": jnp.zeros((), jnp.float32),
           "z_loss": jnp.zeros((), jnp.float32)}

    from repro.distributed.context import constrain
    # seq-parallel: residual stream + norms run sequence-sharded over TP;
    # attention/FFN boundaries gather (AR -> RS+AG, halves live bytes and
    # shrinks fp32 norm-backward chains by 1/tp)
    seq_ax = ("tp" if dist is not None and dist.seq_parallel
              and mode == "train" and x.shape[1] % max(1, getattr(
                  dist, "tp_size", 1)) == 0 else None)
    res_dims = ("dp", seq_ax) + (None,) * (x.ndim - 2)
    x = constrain(dist, x, res_dims)
    h = norm.apply(params["mixer_norm"], x, cfg.norm)
    if mixer == "attn":
        if causal:
            mix, new_cache = attention.apply(
                params["mixer"], h, cfg=cfg, positions=positions,
                is_global=role["global_attn"], mode=mode, cache=cache,
                positions3=positions3, dist=dist)
        else:                                  # encoder self-attention
            q, k, v = attention._proj_qkv(params["mixer"], h, cfg)
            out = attention.flash_attention(q, k, v, causal=False)
            mix = jnp.einsum("bshe,hed->bsd", out,
                             params["mixer"]["w_o"].astype(h.dtype))
            new_cache = None
    elif mixer == "mamba":
        mix, new_cache = mamba.apply(params["mixer"], h, cfg=cfg, mode=mode,
                                     cache=cache)
    elif mixer == "mlstm":
        mix, new_cache = xlstm.mlstm_apply(params["mixer"], h, cfg=cfg,
                                           mode=mode, cache=cache)
    elif mixer == "slstm":
        mix, new_cache = xlstm.slstm_apply(params["mixer"], h, cfg=cfg,
                                           mode=mode, cache=cache)
    else:
        raise ValueError(mixer)
    x = constrain(dist, x + mix, res_dims)

    if enc_kv is not None:                     # enc-dec cross attention
        h = norm.apply(params["cross_norm"], x, cfg.norm)
        x = x + attention.apply_cross(params["cross"], h, enc_kv, cfg=cfg)

    if mixer in ("mlstm", "slstm"):
        return x, aux, new_cache

    if role["moe"]:
        h = norm.apply(params["ffn_norm"], x, cfg.norm)
        moe_out, moe_aux = moe_layer.apply(params["moe"], h, cfg=cfg,
                                           dist=dist, mode=mode)
        if cfg.moe.dense_residual and cfg.d_ff:
            moe_out = moe_out + ffn.apply(params["ffn"], h, act=cfg.ffn_act,
                                          gated=cfg.gated_ffn, dist=dist)
        x = x + moe_out
        aux = {k: aux[k] + moe_aux[k] for k in aux}
    elif cfg.d_ff:
        h = norm.apply(params["ffn_norm"], x, cfg.norm)
        x = x + ffn.apply(params["ffn"], h, act=cfg.ffn_act,
                          gated=cfg.gated_ffn, dist=dist)
    return constrain(dist, x, res_dims), aux, new_cache
