"""Decoder-only language model: init / forward / loss / decode.

Depth is executed as ``lax.scan`` over *layer periods* (the repeating
heterogeneous pattern — e.g. Jamba's 8-layer mamba/attn block, gemma3's
5:1 local:global). HLO size is O(period), not O(depth): an 80-layer model
compiles as fast as a 2-period one — essential for the 512-device dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import blocks, kv_cache, module
from repro.models.layers import embedding, norm


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def specs_tree(cfg: ArchConfig):
    roles = cfg.layer_roles()
    period = {f"l{i}": blocks.block_specs(cfg, role)
              for i, role in enumerate(roles)}
    return {
        "embed": embedding.specs(cfg),
        "periods": module.stack(period, cfg.num_periods),
        "final_norm": norm.specs(cfg.d_model, cfg.norm),
    }


def init(cfg: ArchConfig, key):
    return module.build(specs_tree(cfg), key)


def abstract_params(cfg: ArchConfig):
    return module.abstract(specs_tree(cfg))


def param_axes(cfg: ArchConfig):
    return module.axes_of(specs_tree(cfg))


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    total = module.count(specs_tree(cfg))
    if active_only and cfg.moe is not None:
        from repro.moe import experts as E
        per_layer = module.count(E.specs(cfg))
        n_moe = cfg.num_periods * sum(r["moe"] for r in cfg.layer_roles())
        inactive = 1.0 - cfg.moe.top_k / cfg.moe.num_experts
        total -= int(n_moe * per_layer * inactive)
    return total


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _remat_wrap(fn, cfg: ArchConfig):
    if cfg.remat_policy == "nothing":
        return fn
    if cfg.remat_policy == "full":
        return jax.checkpoint(fn, prevent_cse=False)
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False)
    raise ValueError(cfg.remat_policy)


def forward(params, batch, cfg: ArchConfig, *, mode: str = "train",
            cache: Optional[dict] = None, dist=None,
            use_kernel: bool = False):
    """Returns (logits, aux, new_cache)."""
    dt = jnp.dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    b = tokens.shape[0]
    # paged (serving engine) caches carry a page table + per-sequence
    # lengths instead of a dense [B, max_len] block + scalar pos
    paged = cache is not None and "page_table" in cache

    if mode == "decode":
        if paged:
            positions = cache["lens"][:, None]           # per-slot [B, 1]
        else:
            pos0 = cache["pos"]
            positions = jnp.broadcast_to(pos0[None, None], (b, 1))
    else:
        positions = None  # filled after embeds are known

    x = embedding.embed(params["embed"], tokens, cfg,
                        positions=positions if mode == "decode" else None,
                        dtype=dt)
    if batch.get("embeds") is not None:
        x = jnp.concatenate([batch["embeds"].astype(dt), x], axis=1)
    s = x.shape[1]

    if mode != "decode":
        base = jnp.arange(s)[None, :]
        if paged:                          # chunked prefill at an offset
            positions = cache["lens"][:, None] + base
        else:
            positions = jnp.broadcast_to(base, (b, s))
        if cfg.positional == "learned":
            x = x + params["embed"]["pos"][positions[0]].astype(dt)[None]
    positions3 = batch.get("positions3")

    roles = cfg.layer_roles()
    shared_kv = ({"page_table": cache["page_table"], "lens": cache["lens"],
                  "write_valid": cache.get("write_valid"),
                  "write_sink": cache.get("write_sink"),
                  # trace-static decode attention selector (str) + pool
                  # layout flag (bool) — merged into per-layer caches as
                  # plain Python values, invisible to the scanned pytree
                  "attn_kernel": cache.get("attn_kernel"),
                  "kv_sharded": cache.get("kv_sharded")}
                 if paged else None)
    # serving caches for recurrent mixers are slot-indexed [slots, ...]
    # state (no paging); chunked prefill (B == 1) works on one slot's
    # row, selected by ``cache["slot"]`` ``[1]``
    slot = cache.get("slot") if paged else None

    def period_body(carry, xs):
        x, aux = carry
        pparams, pcache = xs
        new_pcache = {} if pcache is not None else None
        for i, role in enumerate(roles):
            lcache = pcache.get(f"l{i}") if pcache is not None else None
            recurrent = (shared_kv is not None and lcache is not None
                         and role["mixer"] != "attn")
            full = lcache
            if recurrent and slot is not None:   # chunked prefill: B == 1
                lcache = jax.tree_util.tree_map(
                    lambda f: jax.lax.dynamic_slice_in_dim(f, slot[0], 1,
                                                           axis=0), full)
            if shared_kv is not None and lcache is not None:
                lcache = dict(lcache, **{k: v for k, v in shared_kv.items()
                                         if v is not None})
            x, a, nc = blocks.block_apply(
                pparams[f"l{i}"], x, cfg=cfg, role=role,
                positions=positions, mode=mode, cache=lcache, dist=dist,
                positions3=positions3)
            aux = jax.tree_util.tree_map(jnp.add, aux, a)
            if new_pcache is not None:
                if recurrent and nc is not None:
                    if slot is not None:
                        # write the one slot's updated row back in place
                        nc = jax.tree_util.tree_map(
                            lambda f, n: jax.lax.dynamic_update_slice_in_dim(
                                f, n.astype(f.dtype), slot[0], axis=0),
                            full, nc)
                    else:
                        # batched decode over all slots: freeze the state
                        # of inactive (finished / mid-prefill) slots —
                        # the garbage computed for them is finite but
                        # must never stick
                        act = shared_kv.get("write_valid")
                        if act is not None:
                            keep = act[:, 0]
                            nc = {k_: jnp.where(
                                keep.reshape((-1,) + (1,) * (v.ndim - 1)),
                                v.astype(full[k_].dtype), full[k_])
                                for k_, v in nc.items()}
                new_pcache[f"l{i}"] = nc if nc is not None else lcache
        return (x, aux), new_pcache

    aux0 = {"aux_loss": jnp.zeros((), jnp.float32),
            "z_loss": jnp.zeros((), jnp.float32)}
    layer_cache = cache["layers"] if cache is not None else None
    body = _remat_wrap(period_body, cfg) if mode == "train" else period_body
    if layer_cache is not None:
        (x, aux), new_layers = jax.lax.scan(
            body, (x, aux0), (params["periods"], layer_cache))
    else:
        (x, aux), _ = jax.lax.scan(
            lambda c, p: (body(c, (p, None))[0], None),
            (x, aux0), params["periods"])
        new_layers = None

    x = norm.apply(params["final_norm"], x, cfg.norm)
    logits = embedding.logits(params["embed"], x, cfg)
    if dist is not None:
        logits = dist.constrain(logits, ("dp", None, "tp"))

    new_cache = None
    if cache is not None:
        if paged:
            # page_table / lens are host-managed by the serving engine;
            # only the device pools flow through the step
            new_cache = {"layers": new_layers}
        else:
            new_pos = (cache["pos"] + 1 if mode == "decode"
                       else jnp.asarray(s, jnp.int32))
            new_cache = {"layers": new_layers, "pos": new_pos}
    return logits, aux, new_cache


# ---------------------------------------------------------------------------
# Loss / steps
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels):
    """Sharding-friendly CE: the label logit is extracted with a masked
    reduction (fusible; partial-sums over a model-sharded vocab become one
    tiny [B,S] all-reduce) instead of take_along_axis (whose backward is a
    scatter-add that forced an 8 GiB all-gather of d(logits))."""
    valid = labels >= 0
    lab = jnp.where(valid, labels, 0)
    x = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(x.max(axis=-1, keepdims=True))
    shifted = x - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    label_logit = jnp.sum(
        jnp.where(vocab_iota == lab[..., None], shifted, 0.0), axis=-1)
    nll = lse - label_logit
    denom = jnp.maximum(valid.sum(), 1)
    return jnp.where(valid, nll, 0.0).sum() / denom


def loss_fn(params, batch, cfg: ArchConfig, dist=None,
            use_kernel: bool = False):
    logits, aux, _ = forward(params, batch, cfg, mode="train", dist=dist,
                             use_kernel=use_kernel)
    labels = batch["labels"]
    # logits cover (embeds + tokens); labels align with the LAST S_text
    # positions (stub-embeds positions carry label -1 = masked anyway)
    logits = logits[:, -labels.shape[1]:]
    ce = cross_entropy(logits, labels)
    loss = ce + aux["aux_loss"] + aux["z_loss"]
    return loss, {"ce": ce, "aux_loss": aux["aux_loss"],
                  "z_loss": aux["z_loss"], "loss": loss}


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, abstract: bool = False):
    layers = kv_cache.init_cache(cfg, batch, max_len, dtype,
                                 abstract=abstract)
    cross = None
    if isinstance(layers, dict) and "cross" in layers:
        cross = layers.pop("cross")
    out = {"layers": layers,
           "pos": (jax.ShapeDtypeStruct((), jnp.int32) if abstract
                   else jnp.zeros((), jnp.int32))}
    if cross is not None:
        out["cross"] = cross
    return out


def decode_step(params, cache, tokens, cfg: ArchConfig, dist=None):
    logits, _, new_cache = forward(params, {"tokens": tokens}, cfg,
                                   mode="decode", cache=cache, dist=dist)
    return logits[:, -1], new_cache


def prefill(params, batch, cfg: ArchConfig, max_len: int, dist=None,
            dtype=jnp.bfloat16):
    cache = init_cache(cfg, batch["tokens"].shape[0], max_len, dtype)
    logits, _, new_cache = forward(params, batch, cfg, mode="prefill",
                                   cache=cache, dist=dist)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Paged KV path (serving engine, repro.serve)
#
# Both steps return *logits* (the last real position's row), leaving the
# token choice — greedy argmax or the masked temperature/top-k/top-p
# sampler in repro.serve.sampling — to the engine's jitted step bodies,
# so one compiled program serves every per-request sampling setting.
#
# ``dist`` (mesh-sharded serving) threads the engine's DistContext down
# to the MoE layers: prefill chunks then run pipelined_moe's sharded
# layout (tokens split over EP, real All-to-Alls) and decode the
# replicated psum-combine layout — selected by mode alone, no separate
# code path.
# ---------------------------------------------------------------------------

def init_paged_cache(cfg: ArchConfig, num_pages: int, page_size: int,
                     dtype=jnp.bfloat16, abstract: bool = False):
    """Global page pools shared by every in-flight sequence (stacked over
    periods like :func:`init_cache`)."""
    return kv_cache.init_paged_pools(cfg, num_pages, page_size, dtype,
                                     abstract=abstract)


def decode_step_paged(params, pools, page_table, lens, tokens,
                      cfg: ArchConfig, active=None, dist=None,
                      write_sink=None, attn_kernel=None,
                      kv_sharded=False):
    """One decode step over the whole continuous batch.

    pools: paged cache tree; page_table ``[slots, NP]``; lens ``[slots]``
    (tokens cached per slot); tokens ``[slots, 1]``; ``active`` masks
    finished / mid-prefill slots so their KV writes land in the reserved
    sink page — page 0, or per-slot ``write_sink`` ``[slots]`` when each
    DP shard reserves its own sink. ``attn_kernel`` (trace-static:
    ``"pallas"`` or ``"gather"``/None) selects the fused paged-attention
    kernel vs the gather baseline; ``kv_sharded`` tells the kernel the
    pools are page-sharded over the dp axis. Returns (last-token logits
    ``[slots, vocab]``, new pools).
    """
    cache = {"layers": pools, "page_table": page_table, "lens": lens,
             "attn_kernel": attn_kernel, "kv_sharded": kv_sharded}
    if active is not None:
        cache["write_valid"] = active[:, None]
    if write_sink is not None:
        cache["write_sink"] = write_sink
    logits, _, new_cache = forward(params, {"tokens": tokens}, cfg,
                                   mode="decode", cache=cache, dist=dist)
    return logits[:, -1], new_cache["layers"]


def prefill_chunk_paged(params, pools, page_table, pos0, tokens, valid_len,
                        cfg: ArchConfig, dist=None, write_sink=None,
                        slot=None):
    """One chunked-prefill step for a single sequence.

    tokens ``[1, C]`` (bucket-padded); page_table ``[1, NP]``; pos0
    ``[1]`` = tokens already prefilled; valid_len scalar = real (unpadded)
    tokens in this chunk; ``write_sink`` ``[1]`` = the sink page masked
    writes redirect to (the request's DP shard's own sink under
    ``kv_sharding="dp"``; page 0 otherwise); ``slot`` ``[1]`` = the
    request's slot index — required when the model has recurrent mixers,
    whose slot-indexed state rows this chunk reads and writes in place
    (attention-only models have no per-slot state in the pools and may
    omit it). Pad positions' KV writes are masked and their logits
    discarded. Returns (logits at the last real token ``[1, vocab]``,
    new pools).
    """
    c = tokens.shape[1]
    write_valid = jnp.arange(c)[None, :] < valid_len
    cache = {"layers": pools, "page_table": page_table, "lens": pos0,
             "write_valid": write_valid}
    if write_sink is not None:
        cache["write_sink"] = write_sink
    if slot is not None:
        cache["slot"] = slot
    logits, _, new_cache = forward(params, {"tokens": tokens}, cfg,
                                   mode="prefill", cache=cache, dist=dist)
    last = jax.lax.dynamic_slice_in_dim(
        logits, jnp.maximum(valid_len - 1, 0), 1, axis=1)
    return last[:, 0], new_cache["layers"]
