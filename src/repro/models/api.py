"""Model API dispatch: decoder-only LM vs encoder-decoder."""
from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.models import encdec, lm


def get_model(cfg: ArchConfig):
    return encdec if cfg.kind == "encdec" else lm
