"""Model API dispatch: decoder-only LM vs encoder-decoder."""
from __future__ import annotations

from typing import Tuple

from repro.configs.base import ArchConfig
from repro.models import encdec, lm


def get_model(cfg: ArchConfig):
    return encdec if cfg.kind == "encdec" else lm


def supports_paged(cfg: ArchConfig) -> Tuple[bool, str]:
    """Can ``cfg`` run the paged-KV serving path (``repro.serve``)?

    The paged decode/prefill steps (``lm.decode_step_paged`` /
    ``lm.prefill_chunk_paged``) cover decoder-only, token-input models
    whose every mixer is plain attention — MLA latent caches and SSM /
    xLSTM recurrent state are not paged (they are O(1) per sequence and
    gain nothing from paging). Returns (ok, reason-if-not).
    """
    if cfg.kind != "decoder":
        return False, "paged serving requires a decoder-only model"
    if cfg.frontend != "none":
        return False, f"frontend {cfg.frontend!r} not supported by engine"
    if cfg.attn.mla is not None:
        return False, "MLA latent cache is not paged"
    if cfg.attn.mrope:
        return False, "m-rope positions not supported by engine"
    bad = {r["mixer"] for r in cfg.layer_roles()} - {"attn"}
    if bad:
        return False, f"non-attention mixers not paged: {sorted(bad)}"
    if cfg.positional not in ("rope", "learned", "none"):
        return False, f"positional {cfg.positional!r} not supported"
    return True, ""
