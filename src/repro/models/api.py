"""Model API dispatch: decoder-only LM vs encoder-decoder, plus the
single place that decides whether (and how) a config can be served."""
from __future__ import annotations

from typing import Optional, Tuple

from repro.configs.base import ArchConfig
from repro.models import encdec, lm


def get_model(cfg: ArchConfig):
    return encdec if cfg.kind == "encdec" else lm


def serving_support(cfg: ArchConfig) -> Tuple[Optional[str], str]:
    """Capability query for the serving engine (``repro.serve``):
    which :class:`~repro.serve.state_cache.StateCache` kind does ``cfg``
    need — or why can it not be served at all?

    Returns ``(cache_kind, reason)``:

    * ``("paged", "")`` — every mixer is attention: paged KV pools
      (full K/V per token, or the compressed MLA latent);
    * ``("constant", "")`` — no attention mixers at all (pure SSM /
      xLSTM): slot-indexed O(1) recurrent state, nothing to page;
    * ``("composite", "")`` — mixed mixers (jamba): a paged sub-cache
      for the attention layers plus a constant-state sub-cache for the
      rest;
    * ``(None, reason)`` — not servable. The refusals live here and
      only here (one stable reason string per cause): encoder-decoder
      models, non-token frontends (vision/audio), m-rope positions, and
      unknown mixers/positional schemes.
    """
    if cfg.kind != "decoder":
        return None, "serving requires a decoder-only model"
    if cfg.frontend != "none":
        return None, f"frontend {cfg.frontend!r} not supported by engine"
    if cfg.attn.mrope:
        return None, "m-rope positions not supported by engine"
    if cfg.positional not in ("rope", "learned", "none"):
        return None, f"positional {cfg.positional!r} not supported"
    mixers = {r["mixer"] for r in cfg.layer_roles()}
    unknown = mixers - {"attn", "mamba", "mlstm", "slstm"}
    if unknown:
        return None, f"unknown mixers: {sorted(unknown)}"
    if mixers == {"attn"}:
        return "paged", ""
    if "attn" not in mixers:
        return "constant", ""
    return "composite", ""
