"""Minimal functional parameter system (no flax).

Models declare parameter *specs* (shape + logical axes + init); ``build``
materializes a pytree of arrays, ``axes_of`` extracts the parallel tree of
logical-axis tuples consumed by ``repro.distributed.sharding``. Stacking a
spec tree with ``stack`` adds a leading "layers" axis so homogeneous layer
periods can be scanned (`lax.scan`) with O(1) HLO size in depth.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]      # logical axis names (len == ndim)
    init: str = "normal"                 # normal | zeros | ones | embed
    scale: float = 0.0                   # 0 => fan-in default for "normal"
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def _fan_in(shape: Tuple[int, ...]) -> int:
    # convention: last dim is fan-out; product of the rest is fan-in
    if len(shape) <= 1:
        return max(1, shape[0] if shape else 1)
    return int(np.prod(shape[:-1]))


def _materialize(spec: Spec, key) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        scale = spec.scale or 1.0
        return scale * jax.random.normal(key, spec.shape, spec.dtype)
    if spec.init == "normal":
        scale = spec.scale or (1.0 / np.sqrt(_fan_in(spec.shape)))
        return scale * jax.random.normal(key, spec.shape, spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def build(spec_tree, key) -> Any:
    """Materialize a pytree of Specs into arrays (deterministic per-path)."""
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    arrs = [_materialize(s, k) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def abstract(spec_tree) -> Any:
    """ShapeDtypeStruct tree — for dry-runs (no allocation)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        spec_tree, is_leaf=is_spec)


def axes_of(spec_tree) -> Any:
    return jax.tree_util.tree_map(lambda s: s.axes, spec_tree,
                                  is_leaf=is_spec)


def stack(spec_tree, n: int, axis_name: str = "layers") -> Any:
    """Add a leading stacked-layers dim to every spec in the tree."""
    def _s(s: Spec) -> Spec:
        return Spec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale,
                    s.dtype)
    return jax.tree_util.tree_map(_s, spec_tree, is_leaf=is_spec)


def count(spec_tree) -> int:
    leaves = jax.tree_util.tree_leaves(spec_tree, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


def cast_tree(params, dtype):
    """Cast floating leaves to a compute dtype (params stay fp32 at rest)."""
    def _c(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(_c, params)
