"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE splits the head_dim rotary sections across (temporal, height,
width) position components; text tokens use identical (t,t,t) ids so the
scheme degrades gracefully to 1-D RoPE on pure text.
"""
from __future__ import annotations

import jax.numpy as jnp

MROPE_SECTIONS = (0.25, 0.375, 0.375)   # t/h/w fractions of head_dim//2


def _freqs(head_dim: int, theta: float, dtype=jnp.float32):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=dtype) / half))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    half = x.shape[-1] // 2
    freqs = _freqs(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [...,S,half]
    cos = jnp.cos(angles)[..., None, :]                        # [...,S,1,half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


def apply_mrope(x, positions3, theta: float = 10_000.0):
    """M-RoPE. x: [B, S, H, D]; positions3: [3, B, S] (t, h, w)."""
    half = x.shape[-1] // 2
    freqs = _freqs(x.shape[-1], theta)                         # [half]
    # split the frequency bands into t/h/w sections
    n_t = int(half * MROPE_SECTIONS[0])
    n_h = int(half * MROPE_SECTIONS[1])
    sec = jnp.zeros((half,), jnp.int32)
    sec = sec.at[n_t:n_t + n_h].set(1).at[n_t + n_h:].set(2)
    # pos_per_band: [B, S, half] selecting t/h/w position per band
    pos = jnp.take_along_axis(
        positions3.transpose(1, 2, 0).astype(jnp.float32),     # [B,S,3]
        jnp.broadcast_to(sec[None, None, :], x.shape[:2] + (half,)),
        axis=-1)
    angles = pos * freqs                                       # [B,S,half]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


def text_positions3(positions):
    """Degenerate (t,t,t) M-RoPE ids for text-only sequences."""
    return jnp.stack([positions, positions, positions], axis=0)


def sincos_positions(seq_len: int, d_model: int, dtype=jnp.float32):
    """Fixed sinusoidal table (whisper encoder)."""
    pos = jnp.arange(seq_len, dtype=dtype)[:, None]
    half = d_model // 2
    div = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=dtype) / half)
    ang = pos * div[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
