"""RMSNorm / LayerNorm (pre-norm convention, fp32 statistics)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.module import Spec


def specs(d: int, kind: str):
    if kind == "rmsnorm":
        return {"scale": Spec((d,), ("embed",), "ones")}
    if kind == "layernorm":
        return {"scale": Spec((d,), ("embed",), "ones"),
                "bias": Spec((d,), ("embed",), "zeros")}
    raise ValueError(kind)


def apply(params, x, kind: str, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        # stats in fp32 (stability); the normalized value is cast back to
        # the compute dtype BEFORE the scale so backward keeps one fp32
        # [B,S,M] intermediate instead of a chain of them
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = (x32 * (var + eps) ** -0.5).astype(dtype)
        return y * params["scale"].astype(dtype)
    if kind == "layernorm":
        mean = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = ((x32 - mean) * (var + eps) ** -0.5).astype(dtype)
        return (y * params["scale"].astype(dtype)
                + params["bias"].astype(dtype))
    raise ValueError(kind)
