"""Dense feed-forward layers (gated SwiGLU-style or plain MLP).

The tensor names deliberately follow the paper's Fig. 1 dataflow: the
input is ``T_DI``-shaped, the post-GEMM1 hidden is ``T_M``, the output is
``T_DO``. ``checkpoint_name`` tags on ``t_m`` let remat policies drop or
offload exactly the tensors the paper's strategies S1–S4 manage.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.models.module import Spec

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def specs(d_model: int, d_ff: int, gated: bool):
    s = {
        "w_up": Spec((d_model, d_ff), ("embed", "mlp")),
        "w_down": Spec((d_ff, d_model), ("mlp_c", "embed_out")),
    }
    if gated:
        s["w_gate"] = Spec((d_model, d_ff), ("embed", "mlp"))
    return s


def apply(params, x, *, act: str = "silu", gated: bool = True, dist=None):
    from repro.distributed.context import constrain
    dt = x.dtype
    h = jnp.einsum("...d,df->...f", x, params["w_up"].astype(dt))
    h = constrain(dist, h, ("dp",) + (None,) * (h.ndim - 2) + ("tp",))
    if gated:
        g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(dt))
        g = constrain(dist, g, ("dp",) + (None,) * (g.ndim - 2) + ("tp",))
        h = _ACTS[act](g) * h
    else:
        h = _ACTS[act](h)
    h = checkpoint_name(h, "t_m")
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(dt))
