"""Attention: GQA/MHA with RoPE/M-RoPE, sliding windows, MLA, and
memory-efficient (flash-style) computation.

Design notes
------------
* ``flash_attention`` is a pure-jnp blockwise online-softmax attention:
  scores never materialize beyond one (q_block x kv_block) tile per step,
  and the per-q-block body is wrapped in ``jax.checkpoint`` so backward
  recomputes tiles (classic FlashAttention backward). This is the XLA
  fallback; the Pallas kernel in ``repro.kernels.flash_attention`` is the
  TPU fast path and is numerically checked against this implementation.
* Decode attends over the whole cache with a single query: [B,H,T] scores
  are cheap; when the cache's T dim is sharded over the "model" mesh axis
  GSPMD turns the softmax/PV reductions into all-reduces (flash-decode).
* MLA (DeepSeek-V2) caches only the compressed ``c_kv``+``k_rope`` and
  uses the *absorbed* formulation at decode time (q projected into the
  latent space) so the full K/V are never expanded against a long cache.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.module import Spec
from repro.models.layers import rope as rope_lib

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def specs(cfg: ArchConfig, cross: bool = False):
    a = cfg.attn
    d, hd = cfg.d_model, cfg.head_dim
    if a.mla is not None and not cross:
        m = a.mla
        qk_dim = m.nope_head_dim + m.rope_head_dim
        s = {
            "w_dkv": Spec((d, m.kv_lora_rank), ("embed", "kv_lora")),
            "w_kr": Spec((d, m.rope_head_dim), ("embed", None)),
            "w_uk": Spec((m.kv_lora_rank, a.num_heads, m.nope_head_dim),
                         ("kv_lora", "heads", "head_dim")),
            "w_uv": Spec((m.kv_lora_rank, a.num_heads, m.v_head_dim),
                         ("kv_lora", "heads", "head_dim")),
            "w_o": Spec((a.num_heads, m.v_head_dim, d),
                        ("heads", "head_dim", "embed_out")),
        }
        if m.q_lora_rank:
            s["w_dq"] = Spec((d, m.q_lora_rank), ("embed", "kv_lora"))
            s["w_uq"] = Spec((m.q_lora_rank, a.num_heads, qk_dim),
                             ("kv_lora", "heads", "head_dim"))
        else:
            s["w_q"] = Spec((d, a.num_heads, qk_dim),
                            ("embed", "heads", "head_dim"))
        return s
    s = {
        "w_q": Spec((d, a.num_heads, hd), ("embed", "heads", "head_dim")),
        "w_k": Spec((d, a.num_kv_heads, hd),
                    ("embed", "kv_heads", "head_dim")),
        "w_v": Spec((d, a.num_kv_heads, hd),
                    ("embed", "kv_heads", "head_dim")),
        "w_o": Spec((a.num_heads, hd, d),
                    ("heads", "head_dim", "embed_out")),
    }
    if a.qkv_bias:
        s["b_q"] = Spec((a.num_heads, hd), ("heads", "head_dim"), "zeros")
        s["b_k"] = Spec((a.num_kv_heads, hd), ("kv_heads", "head_dim"),
                        "zeros")
        s["b_v"] = Spec((a.num_kv_heads, hd), ("kv_heads", "head_dim"),
                        "zeros")
    return s


# ---------------------------------------------------------------------------
# Flash attention (pure jnp, blockwise online softmax)
# ---------------------------------------------------------------------------

def _gqa_expand(q, num_kv: int):
    """[B,S,Hq,D] -> [B,S,Kv,G,D]."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, num_kv, hq // num_kv, d)


def flash_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_block: int = 512, kv_block: int = 512,
                    q_offset: int = 0):
    """Blockwise attention. q:[B,Sq,Hq,D] k,v:[B,Sk,Kv,D] -> [B,Sq,Hq,D].

    ``q_offset``: absolute position of q[0] (for chunked prefill).
    Sq/Sk are padded up to block multiples internally.
    """
    b, sq, hq, d = q.shape
    sk, kv_heads = k.shape[1], k.shape[2]
    g = hq // kv_heads
    scale = d ** -0.5

    qb = min(q_block, sq)
    kb = min(kv_block, sk)
    sq_p, sk_p = -(-sq // qb) * qb, -(-sk // kb) * kb
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    nq, nk = sq_p // qb, sk_p // kb

    qp = _gqa_expand(qp, kv_heads)                    # [B,Sq,K,G,D]
    qp = qp.reshape(b, nq, qb, kv_heads, g, d)
    kp = kp.reshape(b, nk, kb, kv_heads, d)
    vp = vp.reshape(b, nk, kb, kv_heads, d)

    q_pos = q_offset + jnp.arange(sq_p).reshape(nq, qb)
    k_pos = jnp.arange(sk_p).reshape(nk, kb)
    k_valid = (jnp.arange(sk_p) < sk).reshape(nk, kb)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def q_chunk_body(qc, qpos):
        # qc: [B,qb,K,G,D]; qpos: [qb]
        # kv_step is itself rematted: the scan transpose then saves only
        # the (small) running o/m/l carry per step and recomputes the
        # [qb,kb] score tile in backward — true FlashAttention backward.
        # Without this, scan-transpose stacks every score tile
        # (O(S^2/nq) memory + traffic).
        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_step(carry, inputs):
            o, m, l = carry
            kc, vc, kpos, kval = inputs
            s_ = jnp.einsum("bqkgd,btkd->bkgqt", qc, kc,
                            preferred_element_type=jnp.float32) * scale
            mask = kval[None, :]
            if causal:
                mask = mask & (qpos[:, None] >= kpos[None, :])
            if window > 0:
                mask = mask & (qpos[:, None] - kpos[None, :] < window)
            s_ = jnp.where(mask[None, None, None], s_, NEG_INF)
            m_new = jnp.maximum(m, s_.max(axis=-1))
            p = jnp.exp(s_ - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bkgqt,btkd->bkgqd", p,
                            vc.astype(jnp.float32))
            o_new = o * alpha[..., None] + pv
            return (o_new, m_new, l_new), None

        o0 = jnp.zeros((b, kv_heads, g, qb, d), jnp.float32)
        m0 = jnp.full((b, kv_heads, g, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv_heads, g, qb), jnp.float32)
        (o, m, l), _ = jax.lax.scan(
            kv_step, (o0, m0, l0),
            (kp.transpose(1, 0, 2, 3, 4), vp.transpose(1, 0, 2, 3, 4),
             k_pos, k_valid))
        o = o / jnp.maximum(l[..., None], 1e-30)
        return o.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # [B,qb,K,G,D]

    out = jax.lax.map(lambda args: q_chunk_body(*args),
                      (qp.transpose(1, 0, 2, 3, 4, 5), q_pos))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq_p, hq, d)
    return out[:, :sq]


def decode_attention(q, k, v, cache_len, *, window: int = 0,
                     ring: bool = False):
    """Single-step attention over a cache.

    q: [B,1,Hq,D]; k,v: [B,T,Kv,D]; cache_len: int32 scalar or [B] vector
    — number of valid entries (per sequence in the paged/continuous-
    batching path). If ``ring`` the cache is a ring buffer of size
    ``window`` (all slots valid once full; positions are implicit).
    """
    b, t, kv_heads, d = k.shape
    hq = q.shape[2]
    g = hq // kv_heads
    qe = q.reshape(b, kv_heads, g, d)
    s_ = jnp.einsum("bkgd,btkd->bkgt", qe, k,
                    preferred_element_type=jnp.float32) * (d ** -0.5)
    cl = jnp.atleast_1d(jnp.asarray(cache_len))[:, None]     # [B or 1, 1]
    idx = jnp.arange(t)[None, :]
    if ring:
        valid = idx < jnp.minimum(cl, t)
    else:
        valid = idx < cl
        if window > 0:
            valid = valid & (idx >= cl - window)
    s_ = jnp.where(valid[:, None, None, :], s_, NEG_INF)
    m = s_.max(axis=-1, keepdims=True)
    p = jnp.exp(s_ - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgt,btkd->bkgd", p / jnp.maximum(l, 1e-30),
                     v.astype(jnp.float32))
    return out.reshape(b, 1, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full layers
# ---------------------------------------------------------------------------

def _proj_qkv(params, x, cfg: ArchConfig):
    a = cfg.attn
    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"].astype(x.dtype))
    k = jnp.einsum("bsd,dhe->bshe", x, params["w_k"].astype(x.dtype))
    v = jnp.einsum("bsd,dhe->bshe", x, params["w_v"].astype(x.dtype))
    if a.qkv_bias:
        q = q + params["b_q"].astype(x.dtype)
        k = k + params["b_k"].astype(x.dtype)
        v = v + params["b_v"].astype(x.dtype)
    return q, k, v


def _rope_qk(q, k, cfg: ArchConfig, positions, *, is_global: bool,
             positions3=None):
    a = cfg.attn
    if cfg.positional != "rope" or a.rope_theta == 0.0:
        return q, k
    theta = a.rope_theta
    if not is_global and a.rope_local_theta:
        theta = a.rope_local_theta
    if a.mrope:
        p3 = (positions3 if positions3 is not None
              else rope_lib.text_positions3(positions))
        return (rope_lib.apply_mrope(q, p3, theta),
                rope_lib.apply_mrope(k, p3, theta))
    return (rope_lib.apply_rope(q, positions, theta),
            rope_lib.apply_rope(k, positions, theta))


def _attn_shard_hint(q, k, v, cfg: ArchConfig, dist):
    """Constrain q/k/v [B,S,H,D] shardings: heads over TP when divisible;
    otherwise fall back to sequence-parallel attention (q seq-sharded,
    K/V gathered — e.g. arctic's 56 heads on a 16-way axis). Without the
    explicit constraint GSPMD replicates attention across the TP axis.

    Returns (q, k, v, seq_fallback): when seq-sharded, the caller must
    run flash with a SINGLE q chunk — the q-chunk ``lax.map`` axis is
    sequential, so splitting S into (nq, qb) would strip the sharding
    and reintroduce per-tile all-reduces (observed: 64s of collective on
    arctic train before this fix)."""
    if dist is None or dist.tp_axis is None:
        return q, k, v, False
    from repro.distributed.context import constrain
    tp = dist.tp_size
    if q.shape[2] % tp == 0:
        q = constrain(dist, q, ("dp", None, "tp", None))
        if k.shape[2] % tp == 0:
            k = constrain(dist, k, ("dp", None, "tp", None))
            v = constrain(dist, v, ("dp", None, "tp", None))
        return q, k, v, False
    if q.shape[1] % tp == 0:
        q = constrain(dist, q, ("dp", "tp", None, None))
        return q, k, v, True
    return q, k, v, False


def apply(params, x, *, cfg: ArchConfig, positions, is_global: bool = True,
          mode: str = "train", cache: Optional[dict] = None,
          positions3=None, q_block: int = 512, kv_block: int = 512,
          dist=None):
    """Self-attention layer. Returns (out, new_cache)."""
    a = cfg.attn
    if cache is not None and "ckv_pool" in cache:
        return _apply_mla_paged(params, x, cfg=cfg, positions=positions,
                                mode=mode, cache=cache, dist=dist)
    if cache is not None and "k_pool" in cache:
        return _apply_paged(params, x, cfg=cfg, positions=positions,
                            is_global=is_global, mode=mode, cache=cache,
                            dist=dist)
    if a.mla is not None:
        return _apply_mla(params, x, cfg=cfg, positions=positions,
                          mode=mode, cache=cache)
    window = 0 if (is_global and a.global_period > 1) else a.window

    if mode in ("train", "prefill"):
        q, k, v = _proj_qkv(params, x, cfg)
        q, k = _rope_qk(q, k, cfg, positions, is_global=is_global,
                        positions3=positions3)
        if mode == "prefill" and cache is not None:
            new_cache = _fill_cache(cache, k, v, window)
        else:
            new_cache = None
        g = a.num_heads // a.num_kv_heads
        if g > 1:
            # GQA: repeat KV to full heads so the head dim stays shardable
            # over TP (a 5-D [B,S,Kv,G,D] grouping would force GSPMD to
            # replicate attention across the model axis)
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        q, k, v, seq_fallback = _attn_shard_hint(q, k, v, cfg, dist)
        if seq_fallback:
            q_block = q.shape[1]          # one q chunk: S stays sharded
        out = flash_attention(q, k, v, causal=True, window=window,
                              q_block=q_block, kv_block=kv_block)
    else:  # decode
        q, k, v = _proj_qkv(params, x, cfg)
        pos = cache["len"]
        q, k = _rope_qk(q, k, cfg, jnp.full((1,), pos)[None, :],
                        is_global=is_global, positions3=positions3)
        new_cache = _append_cache(cache, k, v, window)
        ring = window > 0 and new_cache["k"].shape[1] == window
        out = decode_attention(q, new_cache["k"], new_cache["v"],
                               new_cache["len"], window=window, ring=ring)

    out = jnp.einsum("bshe,hed->bsd", out, params["w_o"].astype(x.dtype))
    return out, new_cache


def _fill_cache(cache, k, v, window: int):
    t = cache["k"].shape[1]
    s = k.shape[1]
    if window > 0 and t == window and s >= window:
        # ring alignment: absolute position p lives at slot p % window
        k = jnp.roll(k[:, -window:], s % window, axis=1)
        v = jnp.roll(v[:, -window:], s % window, axis=1)
    kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, 0, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, 0, 0, 0))
    return {"k": kc, "v": vc, "len": jnp.asarray(s, jnp.int32)}


def _append_cache(cache, k, v, window: int):
    t = cache["k"].shape[1]
    pos = cache["len"]
    if window > 0 and t == window:        # ring buffer
        slot = pos % jnp.asarray(t, jnp.int32)
    else:
        slot = pos
    kc = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    return {"k": kc, "v": vc, "len": pos + 1}


# ---------------------------------------------------------------------------
# Paged KV (serving engine)
# ---------------------------------------------------------------------------

def _apply_paged(params, x, *, cfg: ArchConfig, positions, is_global: bool,
                 mode: str, cache: dict, dist=None):
    """Attention over a paged KV pool (``repro.serve``).

    ``cache``: ``k_pool``/``v_pool`` ``[P, ps, Kv, D]``, ``page_table``
    ``[B, NP]``, ``lens`` ``[B]`` (tokens already cached per sequence)
    and optionally ``write_valid`` ``[B, S]`` (mask for padding /
    inactive-slot writes) plus ``write_sink`` ``[B]`` (the reserved page
    those masked writes are redirected to — page 0 by default; the
    DP-sharded pools hand each slot its own shard's sink so masked
    traffic never crosses shards).

    Decode (S == 1) runs every slot of the continuous batch with its own
    cache length; chunked prefill (S > 1) requires B == 1 and attends the
    chunk against the gathered pages with ``q_offset = lens[0]``. The
    gathered view is position-contiguous, so sliding windows degrade to
    plain masking (no ring buffers) — paged pools always hold full
    positions.

    ``cache["attn_kernel"]`` (a trace-static string the engine threads
    through ``decode_step_paged``) picks the decode score path:
    ``"pallas"`` runs the fused page-walking kernel in
    ``repro.kernels.paged_attention`` directly on the pools (no
    materialized gather; bit-identical outputs), anything else keeps the
    ``gather_pages`` baseline. Prefill always gathers — the kernel is
    single-query.

    Mesh-sharded serving (``dist``): the pools are replicated, so this
    layer's math is device-local; the only hint GSPMD needs is to keep
    the decode batch sharded over the dp axes (dropped automatically
    when the slot count does not divide — ``DistContext.constrain``).
    """
    from repro.distributed.context import constrain
    from repro.models import kv_cache as KV

    a = cfg.attn
    if a.mla is not None:
        raise NotImplementedError("paged KV path does not support MLA")
    window = 0 if (is_global and a.global_period > 1) else a.window
    s = x.shape[1]

    q, k, v = _proj_qkv(params, x, cfg)
    q, k = _rope_qk(q, k, cfg, positions, is_global=is_global)
    if dist is not None and s == 1:
        q = constrain(dist, q, ("dp", None, None, None))

    valid = cache.get("write_valid")
    sink = cache.get("write_sink")
    sink = 0 if sink is None else sink
    k_pool = KV.scatter_pages(cache["k_pool"], cache["page_table"],
                              positions, k, valid, sink=sink)
    v_pool = KV.scatter_pages(cache["v_pool"], cache["page_table"],
                              positions, v, valid, sink=sink)
    new_cache = {"k_pool": k_pool, "v_pool": v_pool}

    if s == 1 and cache.get("attn_kernel") == "pallas":
        from repro.kernels.paged_attention import paged_decode_attention
        out = paged_decode_attention(
            q, k_pool, v_pool, cache["page_table"], cache["lens"] + 1,
            window=window, dist=dist,
            kv_sharded=bool(cache.get("kv_sharded")))
        out = jnp.einsum("bshe,hed->bsd", out, params["w_o"].astype(x.dtype))
        return out, new_cache

    kf = KV.gather_pages(k_pool, cache["page_table"])   # [B, NP*ps, Kv, D]
    vf = KV.gather_pages(v_pool, cache["page_table"])
    if s == 1:
        out = decode_attention(q, kf, vf, cache["lens"] + 1, window=window,
                               ring=False)
    else:
        assert x.shape[0] == 1, "paged chunked prefill runs one sequence"
        g = a.num_heads // a.num_kv_heads
        if g > 1:
            # match the dense prefill path: KV repeated to full heads
            kf = jnp.repeat(kf, g, axis=2)
            vf = jnp.repeat(vf, g, axis=2)
        out = flash_attention(q, kf, vf, causal=True, window=window,
                              q_offset=cache["lens"][0])

    out = jnp.einsum("bshe,hed->bsd", out, params["w_o"].astype(x.dtype))
    return out, new_cache


def _apply_mla_paged(params, x, *, cfg: ArchConfig, positions, mode: str,
                     cache: dict, dist=None):
    """MLA over paged *latent* pools (``repro.serve``): pages hold the
    compressed ``c_kv`` ``[P, ps, kv_lora_rank]`` and shared rotary key
    ``k_rope`` ``[P, ps, rope_head_dim]`` instead of full K/V — the
    scatter/gather primitives are trailing-dim generic, so the page
    allocator is untouched; only the per-token payload shrinks.

    The attention itself is the absorbed formulation (q projected into
    the latent space) for decode *and* chunked prefill: the gathered
    latents are never expanded to per-head K/V against the whole cache.
    Causality is one mask — key position ``t`` is visible to the query
    at absolute position ``positions[b, s]`` iff ``t <= positions``
    (decode passes ``lens`` so the just-written token is included).
    Padding/inactive-slot writes redirect to the sink page exactly like
    the plain paged path; their query rows read finite garbage that the
    engine discards.
    """
    from repro.distributed.context import constrain
    from repro.models import kv_cache as KV

    a, m = cfg.attn, cfg.attn.mla
    dt = x.dtype
    s = x.shape[1]
    q_nope, q_rope = _mla_q(params, x, cfg)
    q_rope = rope_lib.apply_rope(q_rope, positions, a.rope_theta)

    c_kv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(dt))
    k_rope = jnp.einsum("bsd,dr->bsr", x, params["w_kr"].astype(dt))
    k_rope = rope_lib.apply_rope(k_rope[:, :, None, :], positions,
                                 a.rope_theta)[:, :, 0, :]
    if s == 1:
        if dist is not None:
            q_nope = constrain(dist, q_nope, ("dp", None, None, None))
            q_rope = constrain(dist, q_rope, ("dp", None, None, None))
    else:
        assert x.shape[0] == 1, "paged chunked prefill runs one sequence"

    valid = cache.get("write_valid")
    sink = cache.get("write_sink")
    sink = 0 if sink is None else sink
    ckv_pool = KV.scatter_pages(cache["ckv_pool"], cache["page_table"],
                                positions, c_kv, valid, sink=sink)
    kr_pool = KV.scatter_pages(cache["kr_pool"], cache["page_table"],
                               positions, k_rope, valid, sink=sink)
    new_cache = {"ckv_pool": ckv_pool, "kr_pool": kr_pool}

    q_abs = jnp.einsum("bshe,rhe->bshr", q_nope,
                       params["w_uk"].astype(dt))
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    if s == 1 and cache.get("attn_kernel") == "pallas":
        # fused page walk over the latent pools (bit-identical to the
        # gathered einsums below); decode positions == lens
        from repro.kernels.paged_attention import paged_mla_decode
        ctx = paged_mla_decode(
            q_abs, q_rope, ckv_pool, kr_pool, cache["page_table"],
            cache["lens"], scale=scale, dist=dist,
            kv_sharded=bool(cache.get("kv_sharded")))
    else:
        ckv_all = KV.gather_pages(ckv_pool, cache["page_table"])  # [B,T,r]
        kr_all = KV.gather_pages(kr_pool, cache["page_table"])    # [B,T,e]
        s_ = (jnp.einsum("bshr,btr->bhst", q_abs, ckv_all.astype(dt),
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshe,bte->bhst", q_rope, kr_all.astype(dt),
                           preferred_element_type=jnp.float32))
        s_ = s_ * scale
        t = ckv_all.shape[1]
        mask = jnp.arange(t)[None, None, :] <= positions[:, :, None]
        s_ = jnp.where(mask[:, None, :, :], s_, NEG_INF)
        p = jax.nn.softmax(s_, axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", p, ckv_all.astype(jnp.float32))
    out = jnp.einsum("bshr,rhe->bshe", ctx.astype(dt),
                     params["w_uv"].astype(dt))
    out = jnp.einsum("bshe,hed->bsd", out, params["w_o"].astype(dt))
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def _mla_q(params, x, cfg: ArchConfig):
    m = cfg.attn.mla
    if m.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, params["w_dq"].astype(x.dtype))
        q = jnp.einsum("bsr,rhe->bshe", cq, params["w_uq"].astype(x.dtype))
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"].astype(x.dtype))
    return q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]


def _apply_mla(params, x, *, cfg: ArchConfig, positions, mode: str,
               cache: Optional[dict]):
    a, m = cfg.attn, cfg.attn.mla
    dt = x.dtype
    q_nope, q_rope = _mla_q(params, x, cfg)
    if mode == "decode":
        positions = jnp.full((1, 1), cache["len"])
    q_rope = rope_lib.apply_rope(q_rope, positions, a.rope_theta)

    c_kv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"].astype(dt))
    k_rope = jnp.einsum("bsd,dr->bsr", x, params["w_kr"].astype(dt))
    k_rope = rope_lib.apply_rope(k_rope[:, :, None, :], positions,
                                 a.rope_theta)[:, :, 0, :]

    if mode in ("train", "prefill"):
        # expanded (naive) path — fine when S is the full sequence
        k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, params["w_uk"].astype(dt))
        v = jnp.einsum("bsr,rhe->bshe", c_kv, params["w_uv"].astype(dt))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      k_nope.shape[:3] + (m.rope_head_dim,))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        # pad v to qk dim for flash (slice back after)
        qk_dim = m.nope_head_dim + m.rope_head_dim
        vpad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_dim - m.v_head_dim)))
        out = flash_attention(q, k, vpad, causal=True)[..., :m.v_head_dim]
        new_cache = None
        if mode == "prefill" and cache is not None:
            ckv_c = jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0))
            kr_c = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
                (0, 0, 0))
            new_cache = {"c_kv": ckv_c, "k_rope": kr_c,
                         "len": jnp.asarray(c_kv.shape[1], jnp.int32)}
    else:
        # absorbed decode: never expand K/V against the cache
        pos = cache["len"]
        ckv_c = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, pos, 0))
        kr_c = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
            (0, pos, 0))
        new_cache = {"c_kv": ckv_c, "k_rope": kr_c, "len": pos + 1}
        # q_nope -> latent space: [B,1,H,dc]
        q_abs = jnp.einsum("bshe,rhe->bshr", q_nope,
                           params["w_uk"].astype(dt))
        s_ = (jnp.einsum("bshr,btr->bhst", q_abs, ckv_c.astype(dt),
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshe,bte->bhst", q_rope, kr_c.astype(dt),
                           preferred_element_type=jnp.float32))
        s_ = s_ * ((m.nope_head_dim + m.rope_head_dim) ** -0.5)
        valid = jnp.arange(ckv_c.shape[1]) < (pos + 1)
        s_ = jnp.where(valid[None, None, None, :], s_, NEG_INF)
        p = jax.nn.softmax(s_, axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", p, ckv_c.astype(jnp.float32))
        out = jnp.einsum("bshr,rhe->bshe", ctx.astype(dt),
                         params["w_uv"].astype(dt))

    out = jnp.einsum("bshe,hed->bsd", out, params["w_o"].astype(dt))
    return out, new_cache


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_specs(cfg: ArchConfig):
    return specs(cfg, cross=True)


def apply_cross(params, x, enc_kv, *, cfg: ArchConfig):
    """enc_kv: dict with precomputed k,v over encoder output."""
    a = cfg.attn
    q = jnp.einsum("bsd,dhe->bshe", x, params["w_q"].astype(x.dtype))
    if a.qkv_bias:
        q = q + params["b_q"].astype(x.dtype)
    out = flash_attention(q, enc_kv["k"].astype(x.dtype),
                          enc_kv["v"].astype(x.dtype), causal=False)
    return jnp.einsum("bshe,hed->bsd", out, params["w_o"].astype(x.dtype))


def cross_kv(params, enc_out, *, cfg: ArchConfig):
    k = jnp.einsum("bsd,dhe->bshe", enc_out,
                   params["w_k"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhe->bshe", enc_out,
                   params["w_v"].astype(enc_out.dtype))
    if cfg.attn.qkv_bias:
        k = k + params["b_k"].astype(enc_out.dtype)
        v = v + params["b_v"].astype(enc_out.dtype)
    return {"k": k, "v": v}
