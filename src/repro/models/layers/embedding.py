"""Token embedding + output head (optionally tied)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.module import Spec


def specs(cfg: ArchConfig):
    s = {"tok": Spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                     "embed", 1.0)}
    if cfg.positional == "learned":
        s["pos"] = Spec((cfg.max_position, cfg.d_model), (None, "embed"),
                        "embed", 0.02)
    if not cfg.tie_embeddings:
        s["head"] = Spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return s


def embed(params, tokens, cfg: ArchConfig, positions=None, dtype=None):
    x = params["tok"][tokens]
    if dtype is not None:
        x = x.astype(dtype)
    if cfg.tie_embeddings:
        x = x * (cfg.d_model ** 0.5)          # gemma-style scaling
    if cfg.positional == "learned" and positions is not None:
        x = x + params["pos"][positions].astype(x.dtype)
    return x


def logits(params, x, cfg: ArchConfig):
    if cfg.tie_embeddings:
        w = params["tok"].astype(x.dtype)
        return jnp.einsum("bsd,vd->bsv", x, w)
    return jnp.einsum("bsd,dv->bsv", x, params["head"].astype(x.dtype))
