"""Mamba (S6) selective-state-space mixer — TPU-adapted.

The CUDA reference implements the selective scan as a fused kernel over
registers/shared memory. The TPU-native adaptation is a two-level scan:
an outer ``lax.scan`` over sequence chunks carrying the SSM state
[B, d_inner, d_state] (so compile size is O(1) in sequence length and the
live working set is one chunk), and an inner ``associative_scan`` inside
the chunk (parallel prefix over the diagonal recurrence — maps onto the
VPU). The chunk body is rematerialized in backward.

Decode keeps a recurrent cache: conv window (d_conv-1 columns) + SSM state.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.module import Spec


def _dims(cfg: ArchConfig):
    m = cfg.mamba
    d_inner = m.expand * cfg.d_model
    dt_rank = m.dt_rank or -(-cfg.d_model // 16)
    return d_inner, dt_rank


def specs(cfg: ArchConfig):
    m = cfg.mamba
    d, (d_inner, dt_rank) = cfg.d_model, _dims(cfg)
    return {
        "w_in": Spec((d, 2 * d_inner), ("embed", "inner")),
        "conv_w": Spec((m.d_conv, d_inner), (None, "inner_c")),
        "conv_b": Spec((d_inner,), ("inner_c",), "zeros"),
        "w_x": Spec((d_inner, dt_rank + 2 * m.d_state), ("inner_c", None)),
        "w_dt": Spec((dt_rank, d_inner), (None, "inner_c")),
        "b_dt": Spec((d_inner,), ("inner_c",), "zeros"),
        "a_log": Spec((d_inner, m.d_state), ("inner_c", None), "ones"),
        "d_skip": Spec((d_inner,), ("inner_c",), "ones"),
        "w_out": Spec((d_inner, d), ("inner_c", "embed_out")),
    }


def _ssm_scan_chunked(x, dt, b_mat, c_mat, a, h0, chunk: int):
    """Selective scan. x,dt:[B,S,DI]; b_mat,c_mat:[B,S,N]; a:[DI,N].

    Returns y:[B,S,DI] and final state h:[B,DI,N].
    """
    bsz, s, di = x.shape
    n = b_mat.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    # discretize: a_bar = exp(dt*A) (diag), b_bar*x = dt * B * x
    def chunk_body(h, args):
        xc, dtc, bc, cc = args                     # [B,c,DI],[B,c,DI],[B,c,N]
        a_bar = jnp.exp(dtc[..., None] * a)        # [B,c,DI,N]
        bx = (dtc * xc)[..., None] * bc[:, :, None, :]   # [B,c,DI,N]

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        # prefix over the chunk, seeded with the carried state
        a_all = jnp.concatenate(
            [jnp.ones((bsz, 1, di, n), a_bar.dtype), a_bar], axis=1)
        b_all = jnp.concatenate([h[:, None], bx], axis=1)
        a_pre, h_all = jax.lax.associative_scan(combine, (a_all, b_all),
                                                axis=1)
        hs = h_all[:, 1:]                           # [B,c,DI,N]
        y = jnp.einsum("bcdn,bcn->bcd", hs, cc)
        return h_all[:, -1], y

    chunk_body = jax.checkpoint(chunk_body, prevent_cse=False)
    xs = (x.reshape(bsz, nc, chunk, di).transpose(1, 0, 2, 3),
          dt.reshape(bsz, nc, chunk, di).transpose(1, 0, 2, 3),
          b_mat.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3),
          c_mat.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3))
    h_final, ys = jax.lax.scan(chunk_body, h0, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, s, di)
    return y, h_final


def _conv1d_causal(x, w, b, *, state: Optional[jax.Array] = None,
                   valid_len=None):
    """Depthwise causal conv. x:[B,S,DI]; w:[K,DI]; state:[B,K-1,DI].

    ``valid_len`` (int32 scalar, serving prefill): the chunk is padded
    past ``valid_len`` real tokens, so the carried state is the window
    ending at the last *real* token — ``xp[:, vl:vl+K-1]`` (``xp`` =
    prior state ++ chunk, so a short chunk correctly overlaps into the
    prior state) — not the static tail, which would capture padding.
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    if k == 1:
        new_state = pad
    elif valid_len is None:
        new_state = xp[:, -(k - 1):]
    else:
        new_state = jax.lax.dynamic_slice_in_dim(xp, valid_len, k - 1,
                                                 axis=1)
    return out + b[None, None], new_state


def apply(params, x, *, cfg: ArchConfig, mode: str = "train",
          cache: Optional[dict] = None, chunk: int = 128):
    """Mamba mixer. Returns (out, new_cache)."""
    m = cfg.mamba
    d_inner, dt_rank = _dims(cfg)
    dt_ = x.dtype

    xz = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(dt_))
    xin, z = jnp.split(xz, 2, axis=-1)

    # serving chunked prefill: the chunk is bucket-padded at the end
    # (``write_valid`` marks real tokens) and the recurrent state must
    # carry across chunks — read it from the cache in every cached mode
    # (a fresh cache holds zeros, so whole-prompt dense prefill is
    # unchanged) and freeze it through the padding.
    valid = cache.get("write_valid") if mode == "prefill" \
        and cache is not None else None
    vl = None if valid is None else \
        jnp.sum(valid[0].astype(jnp.int32))        # serving prefill: B==1
    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = _conv1d_causal(xin, params["conv_w"].astype(dt_),
                                  params["conv_b"].astype(dt_),
                                  state=conv_state, valid_len=vl)
    xc = jax.nn.silu(xc)

    proj = jnp.einsum("bse,ef->bsf", xc, params["w_x"].astype(dt_))
    dt_r = proj[..., :dt_rank]
    b_mat = proj[..., dt_rank:dt_rank + m.d_state].astype(jnp.float32)
    c_mat = proj[..., dt_rank + m.d_state:].astype(jnp.float32)
    dt_full = jnp.einsum("bsr,re->bse", dt_r, params["w_dt"].astype(dt_))
    dt_full = jax.nn.softplus(dt_full.astype(jnp.float32)
                              + params["b_dt"].astype(jnp.float32))
    if valid is not None:
        # dt=0 at padding => a_bar = exp(0) = 1, bx = 0: the SSM state
        # passes through padded positions untouched (their y is garbage
        # but discarded — padding always trails the real tokens)
        dt_full = jnp.where(valid[..., None], dt_full, 0.0)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))      # [DI,N] negative

    bsz = x.shape[0]
    h0 = (cache["ssm"].astype(jnp.float32) if cache is not None else
          jnp.zeros((bsz, d_inner, m.d_state), jnp.float32))

    if mode == "decode":                      # single step, closed form
        a_bar = jnp.exp(dt_full[:, 0, :, None] * a)
        bx = (dt_full[:, 0] * xc.astype(jnp.float32)[:, 0])[..., None] \
            * b_mat[:, 0, None, :]
        h = a_bar * h0 + bx
        y = jnp.einsum("bdn,bn->bd", h, c_mat[:, 0])[:, None]
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "ssm": h.astype(cache["ssm"].dtype)}
    else:
        y, h = _ssm_scan_chunked(xc.astype(jnp.float32), dt_full, b_mat,
                                 c_mat, a, h0, chunk)
        new_cache = None
        if mode == "prefill" and cache is not None:
            new_cache = {"conv": new_conv[:, -(m.d_conv - 1):].astype(
                cache["conv"].dtype),
                "ssm": h.astype(cache["ssm"].dtype)}

    y = y.astype(dt_) + xc * params["d_skip"].astype(dt_)[None, None]
    y = y * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(dt_)), \
        new_cache
