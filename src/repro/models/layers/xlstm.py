"""xLSTM blocks (sLSTM + mLSTM) — TPU-adapted.

mLSTM: matrix-memory cell with exponential gating. The recurrence is a
decayed linear attention, so we use the *chunkwise-parallel* form (the
TPU-native analogue of the paper's fused CUDA kernel): intra-chunk work is
a masked [L,L] matmul on the MXU, inter-chunk state [dk,dv] is carried by
a ``lax.scan``. Log-space stabilization (running max ``m``) follows the
xLSTM paper.

sLSTM: scalar-memory cell with recurrent (block-diagonal per-head) gate
connections — inherently sequential, implemented as a ``lax.scan`` over
time (compile size O(1) in sequence length).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.module import Spec

NEG_INF = -1e30


def _dims(cfg: ArchConfig):
    d = cfg.d_model
    di = int(cfg.xlstm.proj_factor_mlstm * d)
    heads = cfg.attn.num_heads
    return d, di, heads, di // heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_specs(cfg: ArchConfig):
    d, di, nh, _ = _dims(cfg)
    k = cfg.xlstm.conv1d_kernel
    return {
        "w_up": Spec((d, 2 * di), ("embed", "inner")),
        "conv_w": Spec((k, di), (None, "inner_c")),
        "conv_b": Spec((di,), ("inner_c",), "zeros"),
        # block-diagonal per head (official xLSTM): [nh, dh, dh]
        "w_q": Spec((nh, di // nh, di // nh), (None, None, "inner")),
        "w_k": Spec((nh, di // nh, di // nh), (None, None, "inner")),
        "w_v": Spec((nh, di // nh, di // nh), (None, None, "inner")),
        "w_if": Spec((di, 2 * nh), ("inner_c", None), "normal", 0.02),
        "b_if": Spec((2 * nh,), (None,), "zeros"),
        "gn_scale": Spec((di,), ("inner_c",), "ones"),
        "skip": Spec((di,), ("inner_c",), "ones"),
        "w_down": Spec((di, d), ("inner_c", "embed_out")),
    }


def _group_norm(x, scale, nh):
    """Per-head group norm. x: [B,S,DI]."""
    b, s, di = x.shape
    xh = x.reshape(b, s, nh, di // nh).astype(jnp.float32)
    mu = xh.mean(axis=-1, keepdims=True)
    var = xh.var(axis=-1, keepdims=True)
    xh = (xh - mu) * (var + 1e-6) ** -0.5
    return (xh.reshape(b, s, di) * scale.astype(jnp.float32)).astype(x.dtype)


def _mlstm_chunk(carry, args, dh):
    """One chunk of the stabilized chunkwise mLSTM recurrence.

    carry: (C [B,H,dk,dv], n [B,H,dk], m [B,H])
    args:  q,k,v [B,L,H,dh]; lgi, lgf [B,L,H] (log input / log forget gate)
    """
    c_prev, n_prev, m_prev = carry
    q, k, v, lgi, lgf = args
    b, l, h, _ = q.shape
    q = q.astype(jnp.float32) * (dh ** -0.5)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)

    lf_cum = jnp.cumsum(lgf, axis=1)                       # [B,L,H]
    # intra log-coeffs: lf_cum[i] - lf_cum[j] + lgi[j], j<=i
    log_d = (lf_cum[:, :, None] - lf_cum[:, None, :]
             + lgi[:, None, :, :])                         # [B,L,L,H]
    mask = jnp.tril(jnp.ones((l, l), bool))
    log_d = jnp.where(mask[None, :, :, None], log_d, NEG_INF)
    # stabilizer per step
    m_intra = log_d.max(axis=2)                            # [B,L,H]
    m_inter = m_prev[:, None] + lf_cum                     # [B,L,H]
    m_i = jnp.maximum(m_inter, m_intra)

    d_mat = jnp.exp(log_d - m_i[:, :, None])               # [B,L,L,H]
    scores = jnp.einsum("blhd,bthd->blth", q, k) * d_mat
    intra = jnp.einsum("blth,bthd->blhd", scores, v)
    inter_coeff = jnp.exp(m_inter - m_i)                   # [B,L,H]
    inter = jnp.einsum("blhd,bhde->blhe", q, c_prev) * inter_coeff[..., None]

    # normalizer: q · (decayed running sum of i_j k_j)
    n_intra = jnp.einsum("blth,bthd->blhd", d_mat, k)
    n_i = (jnp.einsum("blhd,bhd->blh", q, n_prev) * inter_coeff
           + jnp.einsum("blhd,blhd->blh", q, n_intra))
    denom = jnp.maximum(jnp.abs(n_i), jnp.exp(-m_i))
    h_out = (intra + inter) / denom[..., None]

    # chunk-final state update
    m_last = m_i[:, -1]                                    # [B,H]
    decay_prev = jnp.exp(m_prev + lf_cum[:, -1] - m_last)  # [B,H]
    w_j = jnp.exp(lf_cum[:, -1:, :] - lf_cum + lgi - m_last[:, None])
    c_new = (c_prev * decay_prev[..., None, None]
             + jnp.einsum("blh,blhd,blhe->bhde", w_j, k, v))
    n_new = (n_prev * decay_prev[..., None]
             + jnp.einsum("blh,blhd->bhd", w_j, k))
    return (c_new, n_new, m_last), h_out


def mlstm_apply(params, x, *, cfg: ArchConfig, mode: str = "train",
                cache: Optional[dict] = None, chunk: int = 64):
    d, di, nh, dh = _dims(cfg)
    dt = x.dtype
    from repro.models.layers.mamba import _conv1d_causal

    xz = jnp.einsum("bsd,de->bse", x, params["w_up"].astype(dt))
    xm, z = jnp.split(xz, 2, axis=-1)
    # serving chunked prefill: carry conv + cell state across chunks
    # (fresh caches hold zeros, so whole-prompt dense prefill is
    # unchanged) and freeze the recurrence through the chunk's trailing
    # bucket padding (``write_valid``)
    valid = cache.get("write_valid") if mode == "prefill" \
        and cache is not None else None
    vl = None if valid is None else \
        jnp.sum(valid[0].astype(jnp.int32))        # serving prefill: B==1
    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = _conv1d_causal(xm, params["conv_w"].astype(dt),
                                  params["conv_b"].astype(dt),
                                  state=conv_state, valid_len=vl)
    xc = jax.nn.silu(xc)
    b, s, _ = x.shape
    xch = xc.reshape(b, s, nh, dh)
    q = jnp.einsum("bshe,hef->bshf", xch, params["w_q"].astype(dt))
    k = jnp.einsum("bshe,hef->bshf", xch, params["w_k"].astype(dt))
    v = xm.reshape(b, s, nh, dh)                   # value skips the conv
    gates = (jnp.einsum("bse,eg->bsg", xc, params["w_if"].astype(dt))
             .astype(jnp.float32) + params["b_if"].astype(jnp.float32))
    lgi, lgf_raw = gates[..., :nh], gates[..., nh:]
    lgf = jax.nn.log_sigmoid(lgf_raw)
    if valid is not None:
        # padded steps contribute nothing (input gate -> -inf) and decay
        # nothing (forget gate -> log 1 = 0): the chunk-final (C, n, m)
        # equals the state at the last real token exactly — the running
        # stabilizer m stops moving once lf_cum freezes
        lgi = jnp.where(valid[..., None], lgi, NEG_INF)
        lgf = jnp.where(valid[..., None], lgf, 0.0)

    if mode == "decode":
        c0 = cache["c"].astype(jnp.float32)
        n0 = cache["n"].astype(jnp.float32)
        m0 = cache["m"].astype(jnp.float32)
        (c1, n1, m1), h = _mlstm_chunk((c0, n0, m0),
                                       (q, k, v, lgi, lgf), dh)
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "c": c1.astype(cache["c"].dtype),
                     "n": n1.astype(cache["n"].dtype),
                     "m": m1.astype(cache["m"].dtype)}
        hseq = h
    else:
        l = min(chunk, s)
        assert s % l == 0
        nc = s // l
        if cache is not None:        # chunked prefill resumes mid-prompt
            c0 = cache["c"].astype(jnp.float32)
            n0 = cache["n"].astype(jnp.float32)
            m0 = cache["m"].astype(jnp.float32)
        else:
            c0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
            n0 = jnp.zeros((b, nh, dh), jnp.float32)
            m0 = jnp.zeros((b, nh), jnp.float32)
        body = jax.checkpoint(
            lambda carry, args: _mlstm_chunk(carry, args, dh),
            prevent_cse=False)
        xs = tuple(t.reshape(b, nc, l, *t.shape[2:]).transpose(
            1, 0, *range(2, t.ndim + 1)) for t in (q, k, v, lgi, lgf))
        (c1, n1, m1), hs = jax.lax.scan(body, (c0, n0, m0), xs)
        hseq = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, nh, dh)
        new_cache = None
        if mode == "prefill" and cache is not None:
            kq = cfg.xlstm.conv1d_kernel - 1
            new_cache = {"conv": new_conv[:, -kq:].astype(
                cache["conv"].dtype),
                "c": c1.astype(cache["c"].dtype),
                "n": n1.astype(cache["n"].dtype),
                "m": m1.astype(cache["m"].dtype)}

    hseq = hseq.reshape(b, s, di).astype(dt)
    hseq = _group_norm(hseq, params["gn_scale"], nh)
    hseq = hseq + xc * params["skip"].astype(dt)[None, None]
    hseq = hseq * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", hseq, params["w_down"].astype(dt)), \
        new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_specs(cfg: ArchConfig):
    d = cfg.d_model
    nh = cfg.attn.num_heads
    dh = d // nh
    dff = int(cfg.xlstm.proj_factor_slstm * d)
    return {
        "w_gates": Spec((d, 4 * d), ("embed", "inner")),
        "r_gates": Spec((nh, dh, 4 * dh), (None, None, None),
                        "normal", 0.02),
        "b_gates": Spec((4 * d,), ("inner_c",), "zeros"),
        "gn_scale": Spec((d,), ("embed",), "ones"),
        "w_ffn_up": Spec((d, 2 * dff), ("embed", "mlp")),
        "w_ffn_down": Spec((dff, d), ("mlp_c", "embed_out")),
    }


def slstm_apply(params, x, *, cfg: ArchConfig, mode: str = "train",
                cache: Optional[dict] = None):
    d = cfg.d_model
    nh = cfg.attn.num_heads
    dh = d // nh
    dt = x.dtype
    b, s, _ = x.shape

    wx = (jnp.einsum("bsd,dg->bsg", x, params["w_gates"].astype(dt))
          .astype(jnp.float32) + params["b_gates"].astype(jnp.float32))
    wx = wx.reshape(b, s, 4, nh, dh)
    r = params["r_gates"].astype(jnp.float32)      # [nh, dh, 4*dh]

    # serving chunked prefill: the chunk's trailing bucket padding must
    # not advance the recurrence — the scan carries the old state
    # through padded steps (their h output is garbage and discarded)
    valid = cache.get("write_valid") if mode == "prefill" \
        and cache is not None else None
    valid_seq = (jnp.ones((s, b), bool) if valid is None
                 else valid.transpose(1, 0))

    def step(carry, inputs):
        wxt, vt = inputs
        c, n, m, h = carry                          # [B,nh,dh] each
        rec = jnp.einsum("bhe,hef->bhf", h, r).reshape(b, nh, 4, dh)
        zt = wxt[:, 0] + rec[:, :, 0]
        it = wxt[:, 1] + rec[:, :, 1]
        ft = wxt[:, 2] + rec[:, :, 2]
        ot = wxt[:, 3] + rec[:, :, 3]
        lf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(lf + m, it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(lf + m - m_new)
        c_new = f_ * c + i_ * jnp.tanh(zt)
        n_new = f_ * n + i_
        h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
        keep = vt[:, None, None]
        new = tuple(jnp.where(keep, nw, old) for nw, old in
                    zip((c_new, n_new, m_new, h_new), carry))
        return new, h_new

    if cache is not None:            # decode, or chunked prefill resume
        carry0 = tuple(cache[k_].astype(jnp.float32)
                       for k_ in ("c", "n", "m", "h"))
    else:
        z0 = jnp.zeros((b, nh, dh), jnp.float32)
        carry0 = (z0, z0, z0, z0)

    carry1, hs = jax.lax.scan(step, carry0,
                              (wx.transpose(1, 0, 2, 3, 4), valid_seq))
    hseq = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(dt)

    new_cache = None
    if mode == "decode" or (mode == "prefill" and cache is not None):
        names = ("c", "n", "m", "h")
        new_cache = {k_: v_.astype(cache[k_].dtype)
                     for k_, v_ in zip(names, carry1)}

    hseq = _group_norm(hseq, params["gn_scale"], nh)
    up = jnp.einsum("bsd,df->bsf", hseq, params["w_ffn_up"].astype(dt))
    g, u = jnp.split(up, 2, axis=-1)
    hseq = jax.nn.gelu(g, approximate=True) * u
    return jnp.einsum("bsf,fd->bsd", hseq,
                      params["w_ffn_down"].astype(dt)), new_cache
