"""Adafactor (factored second moment, no first moment) — the memory-lean
optimizer for the 100B+ configs: state is O(rows + cols) per matrix
instead of O(rows x cols) (~0.5 bytes/param amortized vs 8 for Adam).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    decay: float = 0.8          # beta2_t = 1 - step^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0


def _factored(shape) -> bool:
    return len(shape) >= 2


def init(params, cfg: AdafactorConfig):
    def state_like(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"factored": jax.tree_util.tree_map(state_like, params),
            "count": jnp.zeros((), jnp.int32)}


def abstract_state(abstract_params, cfg: AdafactorConfig):
    def like(p):
        if _factored(p.shape):
            return {"vr": jax.ShapeDtypeStruct(p.shape[:-1], jnp.float32),
                    "vc": jax.ShapeDtypeStruct(p.shape[:-2] + p.shape[-1:],
                                               jnp.float32)}
        return {"v": jax.ShapeDtypeStruct(p.shape, jnp.float32)}
    return {"factored": jax.tree_util.tree_map(like, abstract_params),
            "count": jax.ShapeDtypeStruct((), jnp.int32)}


def update(grads, state, params, cfg: AdafactorConfig, lr_scale=1.0):
    count = state["count"] + 1
    beta2 = 1.0 - count.astype(jnp.float32) ** (-cfg.decay)

    def upd(g, s, p):
        g = g.astype(jnp.float32)
        g2 = g * g + cfg.eps
        if _factored(p.shape):
            vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(axis=-2)
            denom = vr.mean(axis=-1, keepdims=True)
            r = (vr / jnp.maximum(denom, cfg.eps))[..., None]
            u = g * jax.lax.rsqrt(jnp.maximum(r, cfg.eps)) \
                * jax.lax.rsqrt(jnp.maximum(vc[..., None, :], cfg.eps))
            new_s = {"vr": vr, "vc": vc}
        else:
            v = beta2 * s["v"] + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(jnp.maximum(v, cfg.eps))
            new_s = {"v": v}
        # update clipping (RMS)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms / cfg.clip_threshold)
        step = cfg.lr * lr_scale * u
        if cfg.weight_decay:
            step = step + cfg.lr * lr_scale * cfg.weight_decay \
                * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype), new_s

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["factored"])
    out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_s = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_p, {"factored": new_s, "count": count}
