"""AdamW (pure JAX) with optional 8-bit moment quantization.

Moment trees mirror the parameter tree, so GSPMD shards optimizer state
exactly like parameters (ZeRO-style when params are FSDP-sharded). The
8-bit variant stores m/v as int8 with a per-block fp32 scale (block =
last dim) — the 400B-class models (arctic, jamba) cannot fit fp32 Adam on
a single pod (DESIGN §8).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    quantize_moments: bool = False   # int8 moments + per-row scales


class Q8(NamedTuple):
    q: jax.Array       # int8 payload
    scale: jax.Array   # fp32 per-last-dim-block scale


def _quantize(x):
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    return Q8((x / scale).round().astype(jnp.int8), scale)


def _dequantize(q8: Q8):
    return q8.q.astype(jnp.float32) * q8.scale


def init(params, cfg: AdamWConfig):
    def zeros_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _quantize(z) if cfg.quantize_moments and p.ndim >= 2 else z
    return {
        "m": jax.tree_util.tree_map(zeros_like, params),
        "v": jax.tree_util.tree_map(zeros_like, params),
        "count": jnp.zeros((), jnp.int32),
    }


def abstract_state(abstract_params, cfg: AdamWConfig):
    def like(p):
        if cfg.quantize_moments and len(p.shape) >= 2:
            return Q8(jax.ShapeDtypeStruct(p.shape, jnp.int8),
                      jax.ShapeDtypeStruct(p.shape[:-1] + (1,), jnp.float32))
        return jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(like, abstract_params),
        "v": jax.tree_util.tree_map(like, abstract_params),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def update(grads, state, params, cfg: AdamWConfig, lr_scale=1.0):
    count = state["count"] + 1
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m_f = _dequantize(m) if isinstance(m, Q8) else m
        v_f = _dequantize(v) if isinstance(v, Q8) else v
        m_new = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_new = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        mhat = m_new / b1c
        vhat = v_new / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - cfg.lr * lr_scale * step
                 ).astype(p.dtype)
        if isinstance(m, Q8):
            m_new, v_new = _quantize(m_new), _quantize(v_new)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}
