"""Optimizers (pure JAX, partition-spec aware) + LR schedules."""
from __future__ import annotations

from typing import Tuple

from repro.optim import adafactor, adamw
from repro.optim.adafactor import AdafactorConfig
from repro.optim.adamw import AdamWConfig


def get_optimizer(name: str, lr: float = 1e-3):
    """Returns (module, config) for 'adamw' | 'adamw8bit' | 'adafactor'."""
    if name == "adamw":
        return adamw, AdamWConfig(lr=lr)
    if name == "adamw8bit":
        return adamw, AdamWConfig(lr=lr, quantize_moments=True)
    if name == "adafactor":
        return adafactor, AdafactorConfig(lr=lr)
    raise ValueError(name)


def lr_schedule(step, *, base_lr: float = 1.0, warmup: int = 100,
                total: int = 10_000, min_ratio: float = 0.1):
    """Linear warmup + cosine decay multiplier (applied as lr_scale)."""
    import jax.numpy as jnp
    warm = jnp.minimum(step / max(warmup, 1), 1.0)
    import numpy as np
    progress = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(np.pi * progress))
    return base_lr * warm * (min_ratio + (1 - min_ratio) * cos)


def state_shardings(opt_module, ocfg, abstract_params, param_shardings,
                    mesh):
    """Sharding tree for the optimizer state, mirrored from parameter
    shardings (ZeRO: moments live wherever their param shard lives)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    astate = opt_module.abstract_state(abstract_params, ocfg)
    flat_sh, treedef = jax.tree_util.tree_flatten(param_shardings)

    def match(sh, leaf):
        nd = len(leaf.shape)
        entries = list(sh.spec) + [None] * max(0, nd - len(sh.spec))
        entries = entries[:nd]
        fixed = []
        for dim, e in zip(leaf.shape, entries):
            ext = 1
            if e is not None:
                axes = (e,) if isinstance(e, str) else e
                for a in axes:
                    ext *= mesh.shape[a]
            fixed.append(e if (ext > 1 and dim % ext == 0) else None)
        return NamedSharding(mesh, P(*fixed))

    out = {}
    for key, sub in astate.items():
        if key == "count":
            out[key] = NamedSharding(mesh, P())
            continue
        flat_state = treedef.flatten_up_to(sub)
        mapped = [jax.tree_util.tree_map(lambda l, s=s: match(s, l), st)
                  for s, st in zip(flat_sh, flat_state)]
        out[key] = jax.tree_util.tree_unflatten(treedef, mapped)
    return out


__all__ = ["adafactor", "adamw", "AdafactorConfig", "AdamWConfig",
           "get_optimizer", "lr_schedule", "state_shardings"]
