"""H2O-Danube 1.8B: llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; hf] — 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000, SWA window 4096.
"""
from repro.configs.base import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    source="arXiv:2401.16818; hf",
    num_layers=24,
    d_model=2560,
    d_ff=6912,
    vocab_size=32000,
    attn=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=80,
                         window=4096, rope_theta=10_000.0),
    block_pattern=("attn",),
    ffn_act="silu",
    gated_ffn=True,
    norm="rmsnorm",
    max_position=524288,             # window cache => long ctx OK
)
