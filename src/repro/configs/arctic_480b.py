"""Snowflake Arctic (480B): dense-MoE hybrid, 128 experts top-2 + dense
residual on every layer.

[hf:Snowflake/snowflake-arctic-base; hf] — 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000, MoE 128e top-2.
"""
from repro.configs.base import ArchConfig, AttentionConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    source="hf:Snowflake/snowflake-arctic-base; hf",
    num_layers=35,
    d_model=7168,
    d_ff=4864,                       # dense residual FFN hidden
    vocab_size=32000,
    attn=AttentionConfig(num_heads=56, num_kv_heads=8, head_dim=128,
                         rope_theta=10_000.0),
    moe=MoEConfig(num_experts=128, top_k=2, d_expert=4864,
                  dense_residual=True, moe_period=1),
    block_pattern=("attn",),
    ffn_act="silu",
    gated_ffn=True,
    norm="rmsnorm",
    max_position=4096,
    optimizer="adafactor",           # 480B: fp32 Adam does not fit
)
