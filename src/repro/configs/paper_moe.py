"""The paper's own evaluation models (Table III): MoE layers sized from
GPT-3 S/XL and BERT-L FFNs, 64 experts, top-1 routing (paper §IV-A sets
k=1). We embed them in a small decoder stack (MoE every other layer) so
the end-to-end drivers have a real model to train.
"""
from repro.configs.base import ArchConfig, AttentionConfig, MoEConfig


def _paper(name: str, d_model: int, d_hidden: int) -> ArchConfig:
    return ArchConfig(
        name=name,
        family="moe",
        source="MPipeMoE Table III",
        num_layers=12,
        d_model=d_model,
        d_ff=d_hidden,
        vocab_size=50304,
        attn=AttentionConfig(num_heads=max(8, d_model // 128),
                             num_kv_heads=max(8, d_model // 128)),
        moe=MoEConfig(num_experts=64, top_k=1, d_expert=d_hidden,
                      moe_period=2, moe_offset=1),
        block_pattern=("attn",),
        ffn_act="gelu",
        gated_ffn=False,
        norm="layernorm",
        positional="learned",
        max_position=8192,
    )


MOE_GPT3_S = _paper("moe-gpt3-s", 768, 3072)
MOE_GPT3_XL = _paper("moe-gpt3-xl", 2048, 8192)
MOE_BERT_L = _paper("moe-bert-l", 1024, 4096)
