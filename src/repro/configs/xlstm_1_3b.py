"""xLSTM-1.3B: sLSTM + mLSTM blocks (xLSTM[7:1]), no FFN (d_ff=0).

[arXiv:2405.04517; unverified] — 48L d_model=2048 4H d_ff=0 vocab=50304.
One sLSTM block per 8 (offset 0), seven mLSTM blocks. Blocks carry their
own up/down projections (proj_factor), so there is no separate FFN.
"""
from repro.configs.base import ArchConfig, AttentionConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    source="arXiv:2405.04517; unverified",
    num_layers=48,
    d_model=2048,
    d_ff=0,                          # xLSTM blocks replace the FFN
    vocab_size=50304,
    attn=AttentionConfig(num_heads=4, num_kv_heads=4),   # mLSTM heads
    xlstm=XLSTMConfig(slstm_period=8, slstm_offset=0,
                      proj_factor_mlstm=2.0, conv1d_kernel=4),
    block_pattern=("mlstm",),        # overridden per-layer by slstm_period
    norm="layernorm",
    positional="none",               # recurrence carries position
    max_position=524288,
)
