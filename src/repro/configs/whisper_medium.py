"""Whisper-medium: encoder-decoder audio model, conv frontend stubbed.

[arXiv:2212.04356; unverified] — 24L d_model=1024 16H (kv=16) d_ff=4096
vocab=51865. The conv frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, 1500, 1024) for the encoder.
"""
from repro.configs.base import ArchConfig, AttentionConfig, EncoderConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    kind="encdec",
    source="arXiv:2212.04356; unverified",
    num_layers=24,                   # decoder layers
    d_model=1024,
    d_ff=4096,
    vocab_size=51865,
    attn=AttentionConfig(num_heads=16, num_kv_heads=16, qkv_bias=True),
    encoder=EncoderConfig(num_layers=24, context_len=1500,
                          d_model=1024, num_heads=16, d_ff=4096),
    block_pattern=("attn",),
    ffn_act="gelu",
    gated_ffn=False,
    norm="layernorm",
    positional="learned",
    max_position=32768,              # decoder positions (shape-driven)
    frontend="audio_stub",
)
