"""Architecture / shape configuration schema.

Every assigned architecture is expressed as an ``ArchConfig``; the four
input shapes are ``ShapeConfig``s. ``reduced()`` produces a same-family
tiny config for CPU smoke tests; the full config is only ever touched by
the dry-run (ShapeDtypeStruct — no allocation).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings for layers that carry an MoE FFN."""
    num_experts: int
    top_k: int
    d_expert: int                   # hidden dim of each expert FFN
    num_shared_experts: int = 0     # DeepSeek-style always-on experts
    d_shared: int = 0               # hidden dim of the shared expert(s)
    dense_residual: bool = False    # Arctic-style parallel dense FFN
    capacity_factor: float = 1.25
    moe_period: int = 1             # every `moe_period`-th layer is MoE
    moe_offset: int = 0             # which index within the period
    gate_bias: bool = False
    aux_loss_weight: float = 1e-2
    z_loss_weight: float = 1e-3
    # --- MPipeMoE knobs (the paper's technique) -----------------------
    pipeline: bool = True           # micro-batch pipelining on/off
    num_partitions: int = 0         # 0 = adaptive (Algorithm 1)
    memory_reuse_strategy: str = "adaptive"  # none|s1|s2|s3|s4|adaptive
    pipeline_unroll: bool = True    # unrolled chunks (overlap) vs lax.scan


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0            # 0 = no q compression
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 => ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_period: int = 8           # 1 sLSTM every `period` blocks
    slstm_offset: int = 0
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 4.0 / 3.0
    conv1d_kernel: int = 4


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder tower for enc-dec models (whisper)."""
    num_layers: int = 24
    context_len: int = 1500         # whisper: 30s audio -> 1500 frames
    d_model: int = 1024
    num_heads: int = 16
    d_ff: int = 4096


@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int = 16
    num_kv_heads: int = 16
    head_dim: int = 0               # 0 => d_model // num_heads
    qkv_bias: bool = False
    window: int = 0                 # 0 = full; >0 = sliding-window size
    # local:global interleave (gemma3 "5:1"): period 6, global at offset 5
    global_period: int = 1          # 1 = every layer uses `window` as-is
    global_offset: int = 0
    rope_theta: float = 10_000.0
    rope_local_theta: float = 0.0   # gemma3 uses different theta locally
    mrope: bool = False             # qwen2-vl multimodal rotary
    mla: Optional[MLAConfig] = None
    logit_softcap: float = 0.0


# ---------------------------------------------------------------------------
# Main config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    kind: str = "decoder"           # decoder | encdec
    source: str = ""                # citation tag from the assignment

    num_layers: int = 12
    d_model: int = 768
    d_ff: int = 3072                # dense FFN hidden (0 = no FFN)
    vocab_size: int = 32000

    attn: AttentionConfig = field(default_factory=AttentionConfig)
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encoder: Optional[EncoderConfig] = None

    # Layer mixer pattern, repeated every `len(block_pattern)` layers.
    # entries: "attn" | "mamba" | "mlstm" | "slstm"
    block_pattern: Tuple[str, ...] = ("attn",)

    ffn_act: str = "silu"           # silu | gelu | relu
    gated_ffn: bool = True          # SwiGLU-style (2 up-proj) vs plain MLP
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    tie_embeddings: bool = False
    positional: str = "rope"        # rope | learned | sincos | none
    max_position: int = 131072
    frontend: str = "none"          # none | audio_stub | vision_stub

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat_policy: str = "nothing"   # layer-level remat: nothing|full|dots

    # large-model memory knobs (see DESIGN §8)
    optimizer: str = "adamw"        # adamw | adafactor | adamw8bit

    # ---------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.attn.head_dim or self.d_model // self.attn.num_heads

    @property
    def period(self) -> int:
        """Length of the repeating layer pattern (for scan-over-layers).

        Must account for block_pattern and the MoE period simultaneously.
        """
        p = len(self.block_pattern)
        if self.moe is not None:
            p = _lcm(p, self.moe.moe_period)
        if self.xlstm is not None:
            p = _lcm(p, self.xlstm.slstm_period)
        if self.attn.global_period > 1:
            p = _lcm(p, self.attn.global_period)
        return p

    @property
    def num_periods(self) -> int:
        assert self.num_layers % self.period == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"layer period {self.period}")
        return self.num_layers // self.period

    def layer_roles(self) -> Tuple[dict, ...]:
        """Per-layer-in-period role descriptors (mixer kind, moe?, global?)."""
        roles = []
        for i in range(self.period):
            mixer = self.block_pattern[i % len(self.block_pattern)]
            if self.xlstm is not None:
                mixer = ("slstm" if i % self.xlstm.slstm_period ==
                         self.xlstm.slstm_offset else "mlstm")
            is_moe = (self.moe is not None
                      and i % self.moe.moe_period == self.moe.moe_offset)
            is_global = (self.attn.global_period <= 1
                         or i % self.attn.global_period
                         == self.attn.global_offset)
            roles.append(dict(mixer=mixer, moe=is_moe, global_attn=is_global))
        return tuple(roles)

    # ---------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        attn = replace(
            self.attn,
            num_heads=max(2, min(self.attn.num_heads, 4)),
            num_kv_heads=max(1, min(self.attn.num_kv_heads, 2)),
            head_dim=16,
            window=min(self.attn.window, 32) if self.attn.window else 0,
            mla=replace(self.attn.mla, kv_lora_rank=16, rope_head_dim=8,
                        nope_head_dim=16, v_head_dim=16, q_lora_rank=0)
            if self.attn.mla else None,
        )
        moe = None
        if self.moe is not None:
            moe = replace(
                self.moe,
                num_experts=4,
                top_k=min(self.moe.top_k, 2),
                d_expert=32,
                d_shared=32 if self.moe.num_shared_experts else 0,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                num_partitions=2,
            )
        enc = (replace(self.encoder, num_layers=2, context_len=16,
                       d_model=64, num_heads=4, d_ff=128)
               if self.encoder else None)
        return replace(
            self,
            name=self.name + "-reduced",
            num_layers=2 * self.period,
            d_model=64,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            attn=attn,
            moe=moe,
            encoder=enc,
            mamba=replace(self.mamba, d_state=8, d_conv=4, expand=2)
            if self.mamba else None,
            max_position=4096,
            param_dtype="float32",
            compute_dtype="float32",
        )

    # ---------------------------------------------------------------
    def param_count(self) -> int:
        """Exact parameter count (from the model's spec tree)."""
        from repro.models.api import get_model  # lazy; avoids cycles
        return get_model(self).count_params(self)

    def active_param_count(self) -> int:
        from repro.models.api import get_model
        return get_model(self).count_params(self, active_only=True)


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Archs allowed to run long_500k (sub-quadratic / windowed path exists).
LONG_CONTEXT_OK = frozenset({
    "jamba-1.5-large-398b",   # hybrid: mamba + 1:7 attention
    "xlstm-1.3b",             # SSM
    "gemma3-12b",             # 5:1 local:global, ring-buffer window cache
    "h2o-danube-1.8b",        # sliding-window attention
})


def applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is (arch, shape) a well-defined cell? Returns (ok, reason)."""
    if shape.name == "long_500k" and arch.name not in LONG_CONTEXT_OK:
        return False, "pure full-attention arch at 500k ctx (DESIGN §5)"
    return True, ""


def pad_to(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple
