"""DeepSeek-V2-Lite (16B): MLA attention + fine-grained MoE with shared
experts.

[arXiv:2405.04434; hf] — 27L d_model=2048 16H (kv=16 via MLA) d_ff=1408
vocab=102400, MoE 64e top-6 with 2 shared experts, MLA kv_lora_rank=512.
"""
from repro.configs.base import (ArchConfig, AttentionConfig, MLAConfig,
                                MoEConfig)

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    source="arXiv:2405.04434; hf",
    num_layers=27,
    d_model=2048,
    d_ff=0,                          # all FFNs are MoE (+shared experts)
    vocab_size=102400,
    attn=AttentionConfig(
        num_heads=16, num_kv_heads=16,
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                      rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
        rope_theta=10_000.0),
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408,
                  num_shared_experts=2, d_shared=1408, moe_period=1),
    block_pattern=("attn",),
    ffn_act="silu",
    gated_ffn=True,
    norm="rmsnorm",
    max_position=163840,
)
