"""Qwen2-VL 2B: VLM decoder backbone with M-RoPE; vision frontend stubbed.

[arXiv:2409.12191; hf] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936. The vision tower is a STUB: input_specs() provides
precomputed patch embeddings merged into the token sequence.
"""
from repro.configs.base import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    source="arXiv:2409.12191; hf",
    num_layers=28,
    d_model=1536,
    d_ff=8960,
    vocab_size=151936,
    attn=AttentionConfig(num_heads=12, num_kv_heads=2, head_dim=128,
                         qkv_bias=True, mrope=True, rope_theta=1_000_000.0),
    block_pattern=("attn",),
    ffn_act="silu",
    gated_ffn=True,
    norm="rmsnorm",
    tie_embeddings=True,
    max_position=131072,
    frontend="vision_stub",
)
