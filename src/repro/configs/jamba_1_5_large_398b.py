"""Jamba-1.5-Large (398B): hybrid Mamba+attention 1:7, MoE 16e top-2.

[arXiv:2403.19887; hf] — 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536. Each 8-layer block has 1 attention layer (index 4); every
second layer carries the MoE FFN.
"""
from repro.configs.base import (ArchConfig, AttentionConfig, MambaConfig,
                                MoEConfig)

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887; hf",
    num_layers=72,
    d_model=8192,
    d_ff=24576,                      # dense FFN on non-MoE layers
    vocab_size=65536,
    attn=AttentionConfig(num_heads=64, num_kv_heads=8, head_dim=128,
                         rope_theta=0.0),   # Jamba: no positional encoding
    # n=4 + scan chunks: the EXPERIMENTS §Perf optimum for this arch
    # (memory -39%, collective -53% vs adaptive n=16 unrolled; the huge
    # d_expert makes the layer compute-bound, so coarse chunks lose no
    # overlap while scan-mode buffer reuse wins on memory)
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=24576,
                  moe_period=2, moe_offset=1, num_partitions=4,
                  pipeline_unroll=False),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    block_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    positional="none",               # mamba mixers carry position implicitly
    ffn_act="silu",
    gated_ffn=True,
    norm="rmsnorm",
    max_position=262144,
    optimizer="adafactor",           # 398B: fp32 Adam does not fit 256xv5e
)
