"""Gemma-3 12B: dense, 5:1 local:global attention interleave, 128k ctx.

[hf:google/gemma-3-1b-pt; unverified] — 48L d_model=3840 16H (GQA kv=8)
d_ff=15360 vocab=262144. Local layers use a 1024-token sliding window
(ring-buffer KV cache); every 6th layer is global full attention.
"""
from repro.configs.base import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    source="hf:google/gemma-3-1b-pt; unverified",
    num_layers=48,
    d_model=3840,
    d_ff=15360,
    vocab_size=262144,
    attn=AttentionConfig(num_heads=16, num_kv_heads=8, head_dim=256,
                         window=1024, global_period=6, global_offset=5,
                         rope_theta=1_000_000.0, rope_local_theta=10_000.0),
    block_pattern=("attn",),
    ffn_act="gelu",
    gated_ffn=True,
    norm="rmsnorm",
    tie_embeddings=True,
    max_position=131072,
)
