"""Qwen1.5-110B: dense decoder with QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf] — 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064.
"""
from repro.configs.base import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B; hf",
    num_layers=80,
    d_model=8192,
    d_ff=49152,
    vocab_size=152064,
    attn=AttentionConfig(num_heads=64, num_kv_heads=8, head_dim=128,
                         qkv_bias=True, rope_theta=1_000_000.0),
    block_pattern=("attn",),
    ffn_act="silu",
    gated_ffn=True,
    norm="rmsnorm",
    max_position=32768,
    optimizer="adafactor",           # 110B: fit fp32 state on 256xv5e
)
