"""Config registry: ``get_config("<arch-id>")`` and the shape table."""
from repro.configs.base import (SHAPES, ArchConfig, AttentionConfig,
                                EncoderConfig, MambaConfig, MLAConfig,
                                MoEConfig, ShapeConfig, XLSTMConfig,
                                applicable)

from repro.configs.jamba_1_5_large_398b import CONFIG as _jamba
from repro.configs.whisper_medium import CONFIG as _whisper
from repro.configs.gemma3_12b import CONFIG as _gemma3
from repro.configs.qwen1_5_110b import CONFIG as _qwen15
from repro.configs.h2o_danube_1_8b import CONFIG as _danube
from repro.configs.llama3_8b import CONFIG as _llama3
from repro.configs.xlstm_1_3b import CONFIG as _xlstm
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.deepseek_v2_lite_16b import CONFIG as _dsv2
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2vl
from repro.configs.paper_moe import MOE_BERT_L, MOE_GPT3_S, MOE_GPT3_XL

ARCHS = {c.name: c for c in [
    _jamba, _whisper, _gemma3, _qwen15, _danube, _llama3, _xlstm, _arctic,
    _dsv2, _qwen2vl, MOE_GPT3_S, MOE_GPT3_XL, MOE_BERT_L,
]}

# The ten assigned architectures (the paper's own three are extras).
ASSIGNED = (
    "jamba-1.5-large-398b", "whisper-medium", "gemma3-12b", "qwen1.5-110b",
    "h2o-danube-1.8b", "llama3-8b", "xlstm-1.3b", "arctic-480b",
    "deepseek-v2-lite-16b", "qwen2-vl-2b",
)


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)


__all__ = [
    "ARCHS", "ASSIGNED", "SHAPES", "ArchConfig", "AttentionConfig",
    "EncoderConfig", "MambaConfig", "MLAConfig", "MoEConfig", "ShapeConfig",
    "XLSTMConfig", "applicable", "get_config", "list_archs",
]
