#!/usr/bin/env python
"""Measure line coverage of ``src/repro/serve`` + ``src/repro/obs`` +
``src/repro/kernels/paged_attention`` with the stdlib only.

CI enforces a pytest-cov line-coverage floor on the serving stack
(``--cov=repro.serve --cov=repro.obs
--cov=repro.kernels.paged_attention --cov-fail-under=N`` in the tier-1
job). This tool
reproduces that measurement without pytest-cov — containers that cannot
install it can still re-derive the floor before bumping it:

    PYTHONPATH=src python tools/serve_coverage.py
    PYTHONPATH=src python tools/serve_coverage.py -- tests/test_serving.py -q

Everything after ``--`` is passed to pytest verbatim; the default runs
the serve-facing non-slow test files. Executable lines come from the
compiled code objects' ``co_lines()`` tables (close to coverage.py's
line set — a couple of points of skew is expected, which is why the CI
floor sits a few points under the measured value), hits from a
``sys.settrace`` hook that only stays live inside ``repro/serve``
frames.
"""
from __future__ import annotations

import os
import sys
import threading
import types

PACKAGE_RELS = (os.path.join("src", "repro", "serve"),
                os.path.join("src", "repro", "obs"),
                os.path.join("src", "repro", "kernels",
                             "paged_attention"))

DEFAULT_TESTS = ["tests/test_serving.py", "tests/test_preemption.py",
                 "tests/test_sampling.py", "tests/test_kv_sharding.py",
                 "tests/test_serving_sharded.py",
                 "tests/test_state_cache.py", "tests/test_obs.py",
                 "tests/test_paged_attention.py",
                 "tests/test_prefix_cache.py",
                 "tests/test_cancel.py", "tests/test_ingress.py",
                 "-m", "not slow", "-q"]


def executable_lines(path: str) -> set:
    with open(path) as f:
        code = compile(f.read(), path, "exec")
    lines, stack = set(), [code]
    while stack:
        co = stack.pop()
        lines.update(ln for _, _, ln in co.co_lines() if ln)
        stack.extend(c for c in co.co_consts
                     if isinstance(c, types.CodeType))
    return lines


def main() -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    files = sorted(
        os.path.join(root, rel, f)
        for rel in PACKAGE_RELS
        for f in os.listdir(os.path.join(root, rel))
        if f.endswith(".py"))
    want = {f: executable_lines(f) for f in files}

    hits: dict = {f: set() for f in files}

    def tracer(frame, event, arg):
        fn = frame.f_code.co_filename
        if fn not in hits:
            return None                      # stay out of foreign frames
        if event == "line":
            hits[fn].add(frame.f_lineno)
        return tracer

    argv = sys.argv[1:]
    pytest_args = argv[argv.index("--") + 1:] if "--" in argv \
        else DEFAULT_TESTS

    import pytest
    threading.settrace(tracer)
    sys.settrace(tracer)
    try:
        rc = pytest.main(pytest_args)
    finally:
        sys.settrace(None)
        threading.settrace(None)
    if rc != 0:
        print(f"error: pytest exited {rc} — the coverage table below "
              f"reflects a partial/broken run; do NOT derive a floor "
              f"from it", file=sys.stderr)

    total_want = total_hit = 0
    print(f"\n{'file':<44} {'lines':>6} {'hit':>6} {'cov':>7}")
    for f in files:
        w, h = want[f], hits[f] & want[f]
        total_want += len(w)
        total_hit += len(h)
        pct = 100.0 * len(h) / max(len(w), 1)
        print(f"{os.path.relpath(f, root):<44} {len(w):>6} {len(h):>6} "
              f"{pct:>6.1f}%")
    pct = 100.0 * total_hit / max(total_want, 1)
    print(f"{'TOTAL':<44} {total_want:>6} {total_hit:>6} {pct:>6.1f}%")
    return int(rc)


if __name__ == "__main__":
    sys.exit(main())
