#!/usr/bin/env python
"""Docs CI gate: every CLI command shown in README.md / docs/*.md must
parse (``--help`` smoke), and every relative markdown link must point at
a file that exists.

    PYTHONPATH=src python tools/check_docs.py [--root DIR]

Command extraction: fenced code blocks are scanned for lines invoking
``python -m <module> ...``, ``python <script>.py ...`` or
``python -m pytest ...``. Each distinct target is run once with
``--help`` (pytest with ``--version``) and must exit 0. Flags shown in
the docs are also cross-checked against the target's ``--help`` text,
so renaming a CLI flag without updating the docs fails CI.

Two pinned surfaces on top of the generic extraction:

* ``REQUIRED_DOCS`` — the documentation tier itself; deleting (or
  forgetting to add) one of these files fails the gate;
* ``REQUIRED_FLAGS`` — load-bearing CLI flags (the ``--devices``
  mesh-sharded serving surface and the ``--kv-sharding`` DP-sharded-KV
  surface) that must BOTH exist in the target's ``--help`` AND be shown
  in at least one documented command, so the flag cannot silently drop
  out of either side.
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

# Match EVERY fence opener (any info string) so a ```python block is
# consumed as one block rather than leaving its closer to re-open an
# anonymous fence that swallows the following prose; extract_commands
# then scans only shell-ish blocks. Flags are read from the first
# physical line of a command only (trailing backslashes are stripped,
# continuation lines are NOT joined) — pinned by tests/test_check_docs.py.
FENCE = re.compile(r"```([^\n]*)\n(.*?)```", re.DOTALL)
SHELL_INFOS = ("", "bash", "sh", "console")
CMD = re.compile(r"python\s+(-m\s+[\w.]+|\S+\.py)((?:\s+\S+)*)")
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

REQUIRED_DOCS = ("README.md", "docs/architecture.md", "docs/serving.md",
                 "docs/distributed.md", "docs/observability.md",
                 "benchmarks/trajectory/README.md")
REQUIRED_FLAGS = {
    "benchmarks/serving.py": ("--devices", "--smoke", "--overload",
                              "--kv-sharding", "--compare-arch",
                              "--obs-overhead", "--attn-kernel-compare",
                              "--prefix-cache-compare",
                              "--ingress-loadgen"),
    "-m repro.launch.serve": ("--devices", "--engine", "--kv-sharding",
                              "--arch", "--metrics-port", "--trace-out",
                              "--attn-kernel", "--prefix-cache",
                              "--http-port", "--shed-policy"),
}


def md_files(root: str):
    out = [os.path.join(root, "README.md"),
           os.path.join(root, "benchmarks", "trajectory", "README.md")]
    docs = os.path.join(root, "docs")
    if os.path.isdir(docs):
        out += sorted(os.path.join(docs, f) for f in os.listdir(docs)
                      if f.endswith(".md"))
    return [f for f in out if os.path.exists(f)]


def extract_commands(text: str):
    """(target, flags) pairs from fenced code blocks."""
    cmds = []
    for info, block in FENCE.findall(text):
        if info.strip() not in SHELL_INFOS:
            continue                  # ```python etc. are not commands
        for line in block.splitlines():
            line = line.strip().rstrip("\\").strip()
            m = CMD.search(line)
            if m:
                target = " ".join(m.group(1).split())
                flags = [a for a in m.group(2).split()
                         if a.startswith("--")]
                cmds.append((target, flags))
    return cmds


def check_commands(root: str, files) -> list:
    errors = []
    by_target = {}
    for f in files:
        for target, flags in extract_commands(open(f).read()):
            by_target.setdefault(target, {"flags": set(), "where": f})
            by_target[target]["flags"].update(flags)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    help_texts = {}
    for target, info in sorted(by_target.items()):
        argv = [sys.executable] + target.split()
        argv += ["--version"] if target == "-m pytest" else ["--help"]
        r = subprocess.run(argv, cwd=root, env=env, capture_output=True,
                           text=True, timeout=600)
        if r.returncode != 0:
            errors.append(f"{info['where']}: `python {target} --help` "
                          f"exited {r.returncode}:\n{r.stderr[-800:]}")
            continue
        print(f"ok: python {target} --help")
        help_texts[target] = r.stdout
        if target == "-m pytest":
            continue
        for flag in sorted(info["flags"]):
            bare = flag.split("=")[0]
            if bare not in ("--help",) and bare not in r.stdout:
                errors.append(f"{info['where']}: `python {target}` help "
                              f"does not mention documented flag {bare}")
    errors += check_required_flags(by_target, help_texts)
    return errors


def check_required_flags(by_target: dict, help_texts: dict) -> list:
    """Pinned CLI surfaces: each required flag must appear in the
    target's --help AND in at least one documented command."""
    errors = []
    for target, flags in sorted(REQUIRED_FLAGS.items()):
        if target not in by_target:
            errors.append(f"required CLI `python {target}` is not shown "
                          f"in any documented command")
            continue
        if target not in help_texts:
            continue      # --help itself failed; already reported above
        documented = {f.split("=")[0] for f in by_target[target]["flags"]}
        for flag in flags:
            if flag not in help_texts.get(target, ""):
                errors.append(f"`python {target}` --help does not offer "
                              f"required flag {flag}")
            elif flag not in documented:
                errors.append(f"required flag {flag} of `python {target}` "
                              f"is not shown in any documented command")
            else:
                print(f"ok: required flag {target} {flag}")
    return errors


def check_required_docs(root: str) -> list:
    return [f"required doc is missing: {rel}" for rel in REQUIRED_DOCS
            if not os.path.exists(os.path.join(root, rel))]


def check_links(files) -> list:
    errors = []
    for f in files:
        base = os.path.dirname(os.path.abspath(f))
        for link in LINK.findall(open(f).read()):
            if link.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = os.path.normpath(os.path.join(base, link.split("#")[0]))
            if not os.path.exists(path):
                errors.append(f"{f}: broken link -> {link}")
            else:
                print(f"ok: {f} -> {link}")
    return errors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    args = ap.parse_args()
    files = md_files(args.root)
    if not files:
        print("no markdown files found", file=sys.stderr)
        return 1
    print(f"checking {len(files)} files: "
          f"{[os.path.relpath(f, args.root) for f in files]}")
    errors = (check_required_docs(args.root)
              + check_commands(args.root, files) + check_links(files))
    if errors:
        print("\n--- doc check failures ---", file=sys.stderr)
        for e in errors:
            print(e, file=sys.stderr)
        return 1
    print("docs OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
