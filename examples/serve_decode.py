"""Serving example: prefill a batch of prompts, then batched decode with
per-family KV caches (full / ring-buffer / MLA-compressed / SSM state).

    PYTHONPATH=src python examples/serve_decode.py --arch deepseek-v2-lite-16b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.api import get_model
from repro.models.kv_cache import cache_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite-16b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(cfg, key)

    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.frontend == "audio_stub":
        e = cfg.encoder
        batch["frames"] = 0.02 * jax.random.normal(
            key, (args.batch, e.context_len, e.d_model))

    t0 = time.perf_counter()
    logits, cache = model.prefill(
        params, batch, cfg, max_len=args.prompt_len + args.gen,
        dtype=jnp.float32)
    print(f"prefill {args.prompt_len} tokens x {args.batch} seqs: "
          f"{(time.perf_counter()-t0)*1e3:.0f}ms  "
          f"cache={cache_bytes(cache['layers'])/2**20:.2f}MiB")

    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t, cfg))
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.perf_counter() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"greedy-decoded {args.gen} tokens/seq in {dt*1e3:.0f}ms "
          f"({args.gen*args.batch/dt:.1f} tok/s on CPU)")
    print("sample token ids:", toks[0][:12].tolist())


if __name__ == "__main__":
    main()
