"""MPipeMoE memory-reuse strategies side by side (paper Table II/Fig 13):
same math, different residual placement — shown via gradients equality +
the analytic memory/cost models for the full-size layer.

    PYTHONPATH=src python examples/memory_strategies.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (MoEMemory, Strategy, TPU_V5E, all_costs,
                        moe_workload, select_strategy)
from repro.models import lm


def main():
    base = get_config("moe-gpt3-xl")
    w = moe_workload(base, local_tokens=16384, ep_size=16)
    costs = all_costs(w, TPU_V5E)
    print("Eq.10 costs for MoE-GPT3-XL, B=16k tokens/device, EP=16:")
    for s, c in costs.items():
        print(f"  {s:5s} {c*1e6:9.1f} us")
    print("selector picks:", select_strategy(w, TPU_V5E).value)

    mm = MoEMemory(b=16384, m=base.d_model, h=base.moe.d_expert, e=64,
                   n=8)
    t = mm.totals()
    print(f"\nEq.1-6 memory (fp32 words x4 bytes):")
    print(f"  model states {t['model_states']/2**20:8.1f} MiB")
    print(f"  activations  {t['activations']/2**20:8.1f} MiB "
          f"-> reused {t['act_reused']/2**20:.1f} MiB")
    print(f"  temp buffers {t['temp_buffers']/2**20:8.1f} MiB "
          f"-> reused {t['buf_reused']/2**20:.1f} MiB")
    print(f"  phi = {t['phi']:.1%} total saving (paper reports up to 47%)")

    # strategies are math-identical: verify on the reduced model
    print("\ngradient equality across strategies (reduced model):")
    cfg0 = get_config("moe-gpt3-s").reduced()
    cfg0 = dataclasses.replace(cfg0, compute_dtype="float32")
    key, k2 = jax.random.PRNGKey(0), jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0,
                                          cfg0.vocab_size),
             "labels": jax.random.randint(k2, (2, 32), 0,
                                          cfg0.vocab_size)}
    ref = None
    for strat in ("none", "s1", "s2", "s3", "s4"):
        cfg = dataclasses.replace(
            cfg0, moe=dataclasses.replace(cfg0.moe, num_partitions=2,
                                          memory_reuse_strategy=strat))
        params = lm.init(cfg, key)
        g = jax.grad(lambda p: lm.loss_fn(p, batch, cfg)[0])(params)
        gn = float(jax.tree_util.tree_reduce(
            lambda a, x: a + jnp.sum(x * x), g, 0.0))
        ref = ref or gn
        print(f"  {strat:5s} |grad|^2 = {gn:.6f} "
              f"(diff vs none: {abs(gn-ref):.2e})")


if __name__ == "__main__":
    main()
