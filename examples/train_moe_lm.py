"""End-to-end driver: train a ~100M-param MoE LM for a few hundred steps
with checkpoint/restart, the paper's pipelined MoE, and metrics logging.

    PYTHONPATH=src python examples/train_moe_lm.py [--steps 200]

On CPU this uses a narrowed (but structurally full: 12 layers, 16
experts) model; on a real TPU pod the same script scales via --arch and
the production mesh (see src/repro/launch/train.py).
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config, AttentionConfig
from repro.ckpt import Checkpointer
from repro.data import SyntheticTokens
from repro.runtime import AdaptiveOptions, TrainOptions, train


def hundred_m_config():
    base = get_config("moe-gpt3-s")
    cfg = dataclasses.replace(
        base,
        name="moe-gpt3-s-100m",
        num_layers=4,
        d_model=256, d_ff=1024,
        vocab_size=50304,
        attn=AttentionConfig(num_heads=8, num_kv_heads=8, head_dim=32),
        moe=dataclasses.replace(base.moe, num_experts=16, d_expert=1024,
                                num_partitions=2,
                                memory_reuse_strategy="s4"),
        max_position=2048,
        compute_dtype="float32",
    )
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--adaptive", action="store_true",
                    help="resolve (n, strategy) online instead of the "
                         "fixed n=2/s4 of this example")
    ap.add_argument("--retune-every", type=int, default=0)
    args = ap.parse_args()

    cfg = hundred_m_config()
    adaptive = None
    if args.adaptive:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, num_partitions=0, memory_reuse_strategy="adaptive"))
        adaptive = AdaptiveOptions(retune_every=args.retune_every)
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.1f}M "
          f"(active {cfg.active_param_count()/1e6:.1f}M)")

    ds = SyntheticTokens(cfg, batch=args.batch, seq=args.seq, seed=0)
    ck = Checkpointer(args.ckpt_dir, keep=2)
    opts = TrainOptions(lr=1e-3, warmup=20, total_steps=args.steps)

    def heartbeat(step, metrics):
        if step % 20 == 0:
            extra = (f" n={metrics['n']} strat={metrics['strategy']}"
                     if "n" in metrics else "")
            print(f"step {step:4d} loss={metrics['loss']:.4f} "
                  f"ce={metrics['ce']:.4f} "
                  f"t={metrics['step_time_s']*1e3:.0f}ms{extra}")

    state, hist = train(cfg, steps=args.steps, batch_source=ds, opts=opts,
                        checkpointer=ck, ckpt_every=50,
                        heartbeat=heartbeat, adaptive=adaptive)
    first = sum(h["loss"] for h in hist[:10]) / 10
    last = sum(h["loss"] for h in hist[-10:]) / 10
    print(f"done: loss {first:.3f} -> {last:.3f} "
          f"({len(ck.list_steps())} checkpoints kept)")


if __name__ == "__main__":
    main()
