"""Quickstart: build an MoE model with MPipeMoE, run a few train steps,
inspect the adaptive runtime choices. Runs on CPU in under a minute.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax

from repro.configs import get_config
from repro.core import TPU_V5E, MoEMemory, all_costs, moe_workload, resolve
from repro.data import SyntheticTokens
from repro.runtime import TrainOptions, train


def main():
    # 1) pick a config (the paper's MoE-GPT3-S layer, reduced for CPU)
    cfg = get_config("moe-gpt3-s").reduced()

    # 2) let MPipeMoE resolve pipeline granularity + reuse strategy for
    #    the target hardware (Algorithm 1 + the Eq. 10 performance model)
    full = get_config("moe-gpt3-s")
    resolved = resolve(full, local_tokens=8192, ep_size=16, hw=TPU_V5E)
    print("adaptive granularity n =", resolved.moe.num_partitions)
    print("adaptive strategy     =", resolved.moe.memory_reuse_strategy)
    w = moe_workload(full, 8192, 16)
    print("per-strategy Eq.10 costs (us):",
          {k: round(v * 1e6, 1) for k, v in all_costs(w, TPU_V5E).items()})
    mm = MoEMemory(b=8192, m=full.d_model, h=full.moe.d_expert, e=64,
                   n=resolved.moe.num_partitions)
    print(f"Eq.6 memory saving ratio phi = {mm.phi:.1%}")

    # 3) train the reduced model for 30 steps on synthetic data
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_partitions=2,
                                     memory_reuse_strategy="s4"))
    ds = SyntheticTokens(cfg, batch=8, seq=32, seed=0)
    state, hist = train(cfg, steps=30, batch_source=ds,
                        opts=TrainOptions(lr=3e-3, warmup=5,
                                          total_steps=30))
    print(f"step  0: loss={hist[0]['loss']:.3f}")
    print(f"step 29: loss={hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
